"""End-to-end training driver: ~100M-parameter llama-family model for a few
hundred steps with Chameleon, checkpointing, eval, and loss-scale dynamics.

    PYTHONPATH=src python examples/train_e2e.py --steps 300

On this CPU container a full 100M run takes hours; ``--preset small``
(default) trains a ~20M model with the identical pipeline; ``--preset 100m``
selects the full deliverable configuration (run it on real hardware or
overnight).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.common.config import ChameleonConfig, ModelConfig, TrainConfig  # noqa: E402
from repro.data.synthetic import SyntheticTokens  # noqa: E402
from repro.runtime.trainer import Trainer  # noqa: E402

PRESETS = {
    "tiny": ModelConfig(name="tiny-llama", family="dense", num_layers=4,
                        d_model=256, num_heads=8, num_kv_heads=4,
                        d_ff=688, vocab_size=4096, dtype="float32",
                        param_dtype="float32"),
    "small": ModelConfig(name="llama-20m", family="dense", num_layers=8,
                         d_model=384, num_heads=8, num_kv_heads=4,
                         d_ff=1024, vocab_size=8192, dtype="float32",
                         param_dtype="float32"),
    "100m": ModelConfig(name="llama-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4,
                        d_ff=2048, vocab_size=32000, dtype="float32",
                        param_dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"model {cfg.name}: {cfg.param_count():,} params")
    tcfg = TrainConfig(steps=args.steps, checkpoint_every=50,
                       checkpoint_dir=f"/tmp/train_e2e_{args.preset}",
                       eval_every=args.eval_every, warmup_steps=20,
                       learning_rate=3e-4)
    data = SyntheticTokens(cfg.vocab_size, args.seq, args.batch).start()
    try:
        tr = Trainer(cfg, tcfg, ChameleonConfig(enabled=True), data=data)
        if args.resume and tr.resume():
            print(f"resumed at step {tr.step}")
        t0 = time.time()
        rep = tr.train(args.steps)
        dt = time.time() - t0
        tok_s = args.steps * args.batch * args.seq / dt
        print(f"\n{args.steps} steps in {dt:.0f}s  ({tok_s:,.0f} tok/s)")
        print(f"loss: {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}")
        print(f"evals: {rep.eval_losses}")
        print(f"straggler events: {len(tr.straggler.events)}")
        print(f"chameleon: {tr.rt.stats()}")
    finally:
        data.stop()


if __name__ == "__main__":
    main()
