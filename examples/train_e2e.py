"""End-to-end training driver: ~100M-parameter llama-family model for a few
hundred steps with Chameleon, checkpointing, eval, and loss-scale dynamics.

    PYTHONPATH=src python examples/train_e2e.py --steps 300

On this CPU container a full 100M run takes hours; ``--preset small``
(default) trains a ~20M model with the identical pipeline; ``--preset 100m``
selects the full deliverable configuration (run it on real hardware or
overnight).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.common.config import ChameleonConfig, ModelConfig, TrainConfig  # noqa: E402
from repro.data.synthetic import SyntheticTokens  # noqa: E402
from repro.runtime.trainer import Trainer  # noqa: E402

PRESETS = {
    "tiny": ModelConfig(name="tiny-llama", family="dense", num_layers=4,
                        d_model=256, num_heads=8, num_kv_heads=4,
                        d_ff=688, vocab_size=4096, dtype="float32",
                        param_dtype="float32"),
    "small": ModelConfig(name="llama-20m", family="dense", num_layers=8,
                         d_model=384, num_heads=8, num_kv_heads=4,
                         d_ff=1024, vocab_size=8192, dtype="float32",
                         param_dtype="float32"),
    "100m": ModelConfig(name="llama-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4,
                        d_ff=2048, vocab_size=32000, dtype="float32",
                        param_dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--budget-gib", type=float, default=16.0,
                    help="HBM budget; small values force swap policies "
                         "(and thus policy_swap-lane trace traffic)")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON on exit")
    ap.add_argument("--metrics-out", default="",
                    help="append repro.obs metrics snapshots (JSONL)")
    ap.add_argument("--with-serve", action="store_true",
                    help="after training, run a short over-subscribed "
                         "serving burst in-process so the trace also "
                         "carries kv_spill-lane spans")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"model {cfg.name}: {cfg.param_count():,} params")
    tcfg = TrainConfig(steps=args.steps, checkpoint_every=args.checkpoint_every,
                       checkpoint_dir=f"/tmp/train_e2e_{args.preset}",
                       eval_every=args.eval_every, warmup_steps=20,
                       learning_rate=3e-4)
    cham = ChameleonConfig(enabled=True,
                           hbm_budget_bytes=int(args.budget_gib * 2 ** 30))
    data = SyntheticTokens(cfg.vocab_size, args.seq, args.batch).start()
    tr = None
    try:
        tr = Trainer(cfg, tcfg, cham, data=data,
                     metrics_out=args.metrics_out or None,
                     metrics_every=max(args.steps // 4, 1))
        if args.resume and tr.resume():
            print(f"resumed at step {tr.step}")
        t0 = time.time()
        rep = tr.train(args.steps)
        dt = time.time() - t0
        tok_s = args.steps * args.batch * args.seq / dt
        print(f"\n{args.steps} steps in {dt:.0f}s  ({tok_s:,.0f} tok/s)")
        print(f"loss: {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}")
        print(f"evals: {rep.eval_losses}")
        print(f"straggler events: {len(tr.straggler.events)}")
        print(f"chameleon: {tr.rt.stats()}")
        if args.with_serve:
            serve_burst(cfg, tr)
    finally:
        data.stop()
        if tr is not None:
            export_obs(args, tr.rt)


def serve_burst(cfg, tr):
    """Over-subscribed serving burst on the freshly trained weights: more
    admitted requests than HBM-resident slots, so preempted decode state
    spills through the host pool and the trace picks up kv_spill-lane
    spans in the same file as the training lanes."""
    import numpy as np  # noqa: E402

    from repro.runtime.server import Server  # noqa: E402

    srv = Server(cfg, tr.params, max_batch=2, max_len=64, max_active=4)
    rng = np.random.RandomState(0)
    for _ in range(4):
        srv.submit(rng.randint(0, cfg.vocab_size, size=8), max_new_tokens=6)
    results = srv.run_until_done(max_ticks=200)
    print(f"serve burst: {len(results)} requests, "
          f"{srv.n_preemptions} preemptions, "
          f"{srv.hostmem.kvspill.n_spills} spills")


def export_obs(args, rt):
    from repro import obs  # noqa: E402

    if args.metrics_out:
        obs.metrics().write_jsonl(args.metrics_out)
        print(f"metrics: {args.metrics_out}")
    if args.trace_out:
        counters = {"overlap_efficiency": [
            (h["t"], h["efficiency"]) for h in rt.overlap_history
            if h["efficiency"] is not None]}
        counters.update(obs.ledger().counter_tracks())
        obs.export_chrome_trace(args.trace_out, obs.tracer(),
                                counters=counters,
                                meta={"preset": args.preset,
                                      "steps": args.steps})
        print(f"trace: {args.trace_out} "
              f"({obs.tracer().stats()['retained']} events)")


if __name__ == "__main__":
    main()
