"""Batched serving with continuous batching over the decode step.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

import repro.configs as C  # noqa: E402
from repro.models.registry import get_api  # noqa: E402
from repro.runtime.server import Server  # noqa: E402


def main():
    cfg = C.get_reduced("llama3_2_1b")
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, max_batch=4, max_len=64)

    rng = np.random.RandomState(0)
    rids = []
    for i in range(10):  # more requests than slots: queue + backfill
        prompt = rng.randint(0, cfg.vocab_size, size=rng.randint(4, 12))
        rids.append(srv.submit(prompt, max_new_tokens=8))
    t0 = time.time()
    results = srv.run_until_done()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s over {srv.ticks} decode ticks "
          f"({total_tokens / dt:.1f} tok/s)")
    for rid in rids[:3]:
        print(f"  req {rid}: {results[rid]}")
    assert set(results) == set(rids)
    print("OK")


if __name__ == "__main__":
    main()
