"""Fault tolerance / elastic restart demo.

Train, kill mid-run (simulated node failure -> emergency checkpoint),
then resume from the latest checkpoint and verify the loss trajectory
continues exactly where it left off.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

import repro.configs as C  # noqa: E402
from repro.common.config import ChameleonConfig, TrainConfig  # noqa: E402
from repro.data.synthetic import SyntheticTokens  # noqa: E402
from repro.runtime.trainer import Trainer  # noqa: E402

CKPT = "/tmp/elastic_demo"


def make_trainer():
    cfg = C.get_reduced("llama2_paper")
    tcfg = TrainConfig(steps=40, checkpoint_every=10, checkpoint_dir=CKPT,
                       warmup_steps=2, learning_rate=1e-3)
    data = SyntheticTokens(cfg.vocab_size, 64, 4, seed=3)
    return Trainer(cfg, tcfg, ChameleonConfig(enabled=False), data=data)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)

    # ---- reference: uninterrupted run
    ref = make_trainer()
    ref_losses = ref.train(30).losses
    shutil.rmtree(CKPT, ignore_errors=True)

    # ---- run 1: dies at step 17
    tr = make_trainer()

    def bomb(step):
        if step == 17:
            raise RuntimeError("simulated node failure")

    try:
        tr.train(30, fault_hook=bomb)
    except RuntimeError as e:
        print(f"crashed as injected: {e}")
    print(f"emergency checkpoint at step {tr.ckpt.latest_step()}")

    # ---- run 2: fresh process resumes and finishes
    tr2 = make_trainer()
    assert tr2.resume(), "must find the emergency checkpoint"
    print(f"resumed at step {tr2.step}")
    rep2 = tr2.train(30 - tr2.step)

    np.testing.assert_allclose(ref_losses[-len(rep2.losses):], rep2.losses,
                               rtol=1e-5)
    print(f"post-resume losses match uninterrupted run "
          f"(max diff {np.max(np.abs(np.asarray(ref_losses[-len(rep2.losses):]) - np.asarray(rep2.losses))):.2e})")
    print("OK")


if __name__ == "__main__":
    main()
