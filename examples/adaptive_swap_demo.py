"""Chameleon adaptivity demo — the paper's core scenario, end to end.

Under a tight emulated HBM budget we train with (1) dynamic loss scaling and
(2) on-the-fly validation.  Both change the per-iteration operator sequence;
the lightweight profiler detects it (Algo 1), the policy regenerates, and
training never crashes — this is the Fig-7 experiment where Capuchin dies
at the first validation.

    PYTHONPATH=src python examples/adaptive_swap_demo.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

import repro.configs as C  # noqa: E402
from repro.common.config import ChameleonConfig, TrainConfig  # noqa: E402
from repro.data.synthetic import SyntheticTokens  # noqa: E402
from repro.runtime.trainer import Trainer  # noqa: E402


def main():
    cfg = C.get_reduced("llama2_paper")
    steps = 45
    tcfg = TrainConfig(steps=steps, checkpoint_every=0,
                       checkpoint_dir="/tmp/adaptive_demo",
                       eval_every=15, warmup_steps=2, learning_rate=1e-3)
    data = SyntheticTokens(cfg.vocab_size, 64, 4, seed=1)
    tr = Trainer(cfg, tcfg,
                 ChameleonConfig(enabled=True, hbm_budget_bytes=30 << 20),
                 data=data)
    rep = tr.train(steps)

    print("step | stage     | policy")
    last = None
    for h in tr.rt.history:
        key = (h["stage"], h["policy"][:40])
        if key != last:
            print(f"{h['step']:4d} | {h['stage']:9s} | {h['policy'][:60]}")
            last = key
    print("\nstage transitions:", tr.rt.machine.transitions)
    print("eval (sequence-change) steps:", sorted(rep.eval_losses))
    print(f"policies generated: {len(tr.rt.variants)}, "
          f"best grouping knob: {tr.rt.best.knob if tr.rt.best else None}")
    print(f"failures: {rep.failures} (Capuchin-style systems crash here)")
    assert not rep.failures
    assert any(w == "seq-change" for _, w, _ in tr.rt.machine.transitions)
    print("OK — survived operator-sequence changes")


if __name__ == "__main__":
    main()
