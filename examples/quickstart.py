"""Quickstart: train a small llama-family model with Chameleon enabled.

    PYTHONPATH=src python examples/quickstart.py [--steps 50]

Watch the stage machine move WarmUp -> GenPolicy -> Stable while the loss
decreases; ``--budget-mib`` tightens the emulated HBM budget so swap
policies actually generate.
"""
import argparse
import sys

sys.path.insert(0, "src")

import repro.configs as C  # noqa: E402
from repro.common.config import ChameleonConfig, TrainConfig  # noqa: E402
from repro.data.synthetic import SyntheticTokens  # noqa: E402
from repro.runtime.trainer import Trainer  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--budget-mib", type=int, default=30)
    ap.add_argument("--arch", default="llama2-paper")
    args = ap.parse_args()

    cfg = C.get_reduced(args.arch)
    tcfg = TrainConfig(steps=args.steps, checkpoint_every=25,
                       checkpoint_dir="/tmp/quickstart_ckpt",
                       warmup_steps=5, learning_rate=1e-3)
    cham = ChameleonConfig(enabled=True,
                           hbm_budget_bytes=args.budget_mib << 20)
    data = SyntheticTokens(cfg.vocab_size, seq_len=128, global_batch=8)
    tr = Trainer(cfg, tcfg, cham, data=data)
    rep = tr.train(args.steps)

    print(f"\narch={cfg.name} params={cfg.param_count():,}")
    print(f"loss: {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}")
    print(f"stages: {rep.stages}")
    print(f"stage transitions: {tr.rt.machine.transitions}")
    print(f"applied policy: {tr.rt.applied.fingerprint[:80]}")
    print(f"skipped (loss-scale) steps: {rep.skipped_steps}")
    print(f"checkpoints: {rep.checkpoints}")
    assert rep.losses[-1] < rep.losses[0]
    print("OK")


if __name__ == "__main__":
    main()
