"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in seconds (blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Row]) -> None:
    for name, sec, derived in rows:
        print(f"{name},{sec * 1e6:.1f},{derived}")
