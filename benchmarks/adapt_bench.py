"""Adaptation cost with the policy store on vs off (repro.policystore).

Each scenario drives the real Trainer + ChameleonRuntime through a
recurring or drifting operator-sequence pattern and measures
**iterations-to-recovered-throughput**: the GenPolicy steps spent (each
one runs the Detailed profiler and a fresh Algo-2 policy generation) and
the steps from a sequence change back to Stable.

Scenarios (ISSUE 4 suite):

  * ``recur``        — train→eval→train interleave: the exact sequence
    pair recurs every eval step; the store's reuse tier should absorb
    every re-adaptation after the first;
  * ``cold_restart`` — a fresh process with a warm on-disk store must
    apply the cached policy without entering GenPolicy at all;
  * ``seqlen_cycle`` — alternating seq-len buckets: the op stream
    tokenizes identically but shapes differ, exercising the
    matching-demotion path (reuse -> warm-start) and bucket-keyed
    records;
  * ``layer_change`` — a different model depth shares the store dir:
    the length-ratio gate must *not* reuse across it;
  * ``moe_experts``  — expert-count change on a MoE config: moderate
    drift, warm-start territory.

Derived columns report GenPolicy steps with the store on vs off plus
per-tier hit counts; the acceptance bar is ``on < off`` for ``recur``
and ``genpolicy=0`` for ``cold_restart``.

Drift-stall suite (repro.adapt): the policy store is disabled in BOTH
modes so every phase switch (alternating seq-len buckets) pays a real
adaptation, and the worst single-iteration wall time is compared
across placements.  Inline runs the paper's measured GenPolicy
iterations (Detailed profiler + Algo-2 search on the training thread),
so its worst iteration spikes well above the steady median; async
moves that work to the repro.adapt worker and installs at an iteration
boundary, so its worst iteration stays within 1.5x of its bucket's
Stable-stage median.  ``speculative`` additionally pre-generates the
recurring phase's policy (``spec_hits>=1`` with zero inline GenPolicy
steps).  Run just this suite with ``python benchmarks/adapt_bench.py
--drift-only`` (the CI guard does).
"""
from __future__ import annotations

import shutil
import tempfile
from typing import List, Optional

import numpy as np

import repro.configs as C
from repro.common.config import ChameleonConfig, PolicyStoreConfig, TrainConfig
from repro.data.synthetic import SyntheticTokens
from repro.runtime.trainer import Trainer

# tight enough that swap policies really generate (reduced-llama2 baseline
# peak is ~12 MiB at seq 64: 20 MiB fits baseline, 8 MiB forces ~18 swap
# entries per policy, so reuse exercises the §6.1 matching path)
BUDGET = 8 << 20


def _trainer(store_dir: Optional[str], ckdir: str, *, cfg=None, steps=40,
             eval_every=0, seq=64, batch=4, seed=0,
             adapt_mode: str = "inline") -> Trainer:
    cfg = cfg or C.get_reduced("llama2_paper")
    tcfg = TrainConfig(steps=steps, checkpoint_every=0, checkpoint_dir=ckdir,
                       eval_every=eval_every, warmup_steps=2,
                       learning_rate=1e-3)
    cham = ChameleonConfig(
        enabled=True, hbm_budget_bytes=BUDGET,
        policystore=PolicyStoreConfig(enabled=store_dir is not None,
                                      dir=store_dir or ""))
    data = SyntheticTokens(cfg.vocab_size, seq, batch, seed=seed)
    return Trainer(cfg, tcfg, cham, data=data, adapt_mode=adapt_mode)


def _tiers(tr: Trainer) -> str:
    ps = tr.rt.policystore_stats()
    if ps is None:
        return "off"
    t = ps["tiers"]
    return (f"reuse:{t['reuse']}/warm:{t['warm_start']}"
            f"/regen:{t['regen']}/dem:{t['demoted']}")


def _recovery_steps(tr: Trainer) -> float:
    """Mean steps from a sequence change back to Stable."""
    a = tr.rt.adaptations
    return float(np.mean([d["steps"] for d in a])) if a else 0.0


# drift-stall geometry: two seq-len buckets alternate every 12 steps, so
# each stream settles, adapts, and *recurs* (the speculative predictor
# needs a periodic phase pair).  jit compiles — every (policy x shape)
# pair — amortize over the first three blocks, so the guard window
# starts at step 36, where both streams are on their 3rd+ visit.
_DRIFT_STEPS = 60
_DRIFT_PERIOD = 12
_DRIFT_SKIP = 36


def _drift_run(mode: str, mk) -> tuple:
    """One store-off run under the given adaptation placement.  Returns
    (report, worst_s, worst_ratio) where worst_ratio normalizes each
    step against the Stable-stage median of its *own* bucket — the two
    streams have different inherent step costs (seq 64 vs 96), so a raw
    global median would mislabel every slow-bucket step a stall."""
    cfg = C.get_reduced("llama2_paper")
    tr = _trainer(None, mk(), cfg=cfg, steps=_DRIFT_STEPS, adapt_mode=mode)
    buckets = [SyntheticTokens(cfg.vocab_size, 64, 4, seed=0),
               SyntheticTokens(cfg.vocab_size, 96, 4, seed=1)]

    def hook(step: int):
        if (step + 1) % _DRIFT_PERIOD == 0:
            tr.data = buckets[((step + 1) // _DRIFT_PERIOD) % 2]

    try:
        rep = tr.train(_DRIFT_STEPS, fault_hook=hook)
    finally:
        tr.rt.close()
    # wall_times: compute + end_iteration — inline's stall (Detailed
    # profiling, Algo-2 generation, re-prepare) happens *inside*
    # end_iteration, which rep.times deliberately excludes
    times, stages = rep.wall_times, rep.stages

    def bucket(i: int) -> int:
        return (i // _DRIFT_PERIOD) % 2

    med = {}
    for b in (0, 1):
        stable = [times[i] for i in range(_DRIFT_SKIP, len(times))
                  if bucket(i) == b and stages[i] == "Stable"]
        med[b] = (float(np.median(stable)) if stable
                  else float(np.median(times[_DRIFT_SKIP:])))
    ratios = [times[i] / max(med[bucket(i)], 1e-9)
              for i in range(_DRIFT_SKIP, len(times))]
    worst_i = int(np.argmax(ratios)) + _DRIFT_SKIP
    return rep, float(times[worst_i]), float(np.max(ratios))


def _drift_stall_rows(mk) -> List[tuple]:
    rep_in, worst_in, ratio_in = _drift_run("inline", mk)
    rep_as, worst_as, ratio_as = _drift_run("async", mk)
    ad = rep_as.adapt or {}
    rows = [(
        "adapt.drift_stall", worst_as,
        f"worst_async_ms={worst_as * 1e3:.1f};"
        f"worst_inline_ms={worst_in * 1e3:.1f};"
        f"ratio_async={ratio_as:.2f};"
        f"ratio_inline={ratio_in:.2f};"
        f"genpolicy_inline={rep_in.genpolicy_steps};"
        f"installed={ad.get('installed', 0)};jobs={ad.get('jobs', 0)} "
        f"(bar: ratio_async<=1.5<ratio_inline)")]

    rep_sp, worst_sp, ratio_sp = _drift_run("speculative", mk)
    sp = rep_sp.adapt or {}
    rows.append((
        "adapt.speculative", worst_sp,
        f"spec_hits={sp.get('speculative_hits', 0)};"
        f"genpolicy={rep_sp.genpolicy_steps};"
        f"installed={sp.get('installed', 0)};"
        f"jobs={sp.get('jobs', 0)};"
        f"ratio={ratio_sp:.2f} "
        f"(bar: spec_hits>=1, genpolicy=0)"))
    return rows


def run(iters: int = 1) -> List[tuple]:
    rows: List[tuple] = []
    dirs: List[str] = []

    def mk() -> str:
        d = tempfile.mkdtemp()
        dirs.append(d)
        return d

    try:
        # ---- recur: train -> eval -> train interleave -----------------
        store = mk()
        tr_on = _trainer(store, mk(), steps=40, eval_every=13)
        rep_on = tr_on.train(40)
        tr_off = _trainer(None, mk(), steps=40, eval_every=13)
        rep_off = tr_off.train(40)
        t_step = float(np.median(rep_on.times[5:]))
        rows.append((
            "adapt.recur", t_step,
            f"genpolicy_on={rep_on.genpolicy_steps};genpolicy_off={rep_off.genpolicy_steps};"
            f"recovery_on={_recovery_steps(tr_on):.1f};"
            f"recovery_off={_recovery_steps(tr_off):.1f};"
            f"tiers={_tiers(tr_on)}"))

        # ---- cold restart against the warm on-disk store --------------
        tr_cold = _trainer(store, mk(), steps=8)
        rep_cold = tr_cold.train(8)
        rows.append((
            "adapt.cold_restart", float(np.median(rep_cold.times)),
            f"genpolicy={rep_cold.genpolicy_steps};stages={sorted(set(rep_cold.stages))};"
            f"tiers={_tiers(tr_cold)} (bar: genpolicy=0)"))

        # ---- seq-len bucket cycling ------------------------------------
        # period must exceed one cold adaptation (m warmup + n genpolicy
        # steps) or nothing ever finishes and gets stored
        def cycle_hook(tr: Trainer, period: int = 12):
            cfg = tr.cfg
            buckets = [SyntheticTokens(cfg.vocab_size, 64, 4, seed=0),
                       SyntheticTokens(cfg.vocab_size, 96, 4, seed=1)]

            def hook(step: int):
                if (step + 1) % period == 0:
                    tr.data = buckets[((step + 1) // period) % 2]
            return hook

        store2 = mk()
        tr2_on = _trainer(store2, mk(), steps=48)
        rep2_on = tr2_on.train(48, fault_hook=cycle_hook(tr2_on))
        tr2_off = _trainer(None, mk(), steps=48)
        rep2_off = tr2_off.train(48, fault_hook=cycle_hook(tr2_off))
        rows.append((
            "adapt.seqlen_cycle", float(np.median(rep2_on.times[5:])),
            f"genpolicy_on={rep2_on.genpolicy_steps};genpolicy_off={rep2_off.genpolicy_steps};"
            f"recovery_on={_recovery_steps(tr2_on):.1f};"
            f"recovery_off={_recovery_steps(tr2_off):.1f};"
            f"tiers={_tiers(tr2_on)}"))

        # ---- layer-count change (must NOT reuse across it) -------------
        store3 = mk()
        tr3a = _trainer(store3, mk(), steps=14)
        tr3a.train(14)
        deeper = C.get_reduced("llama2_paper").replace(num_layers=6)
        tr3b = _trainer(store3, mk(), cfg=deeper, steps=14)
        rep3b = tr3b.train(14)
        rows.append((
            "adapt.layer_change", float(np.median(rep3b.times[5:])),
            f"genpolicy_after_change={rep3b.genpolicy_steps};tiers={_tiers(tr3b)} "
            f"(bar: no reuse hit)"))

        # ---- MoE expert-count change -----------------------------------
        moe = C.get_reduced("granite_moe_1b_a400m")
        store4 = mk()
        tr4a = _trainer(store4, mk(), cfg=moe, steps=12)
        tr4a.train(12)
        moe2 = moe.replace(num_experts=2 * moe.num_experts)
        tr4b = _trainer(store4, mk(), cfg=moe2, steps=12)
        rep4b = tr4b.train(12)
        rows.append((
            "adapt.moe_experts", float(np.median(rep4b.times[5:])),
            f"genpolicy_after_change={rep4b.genpolicy_steps};tiers={_tiers(tr4b)}"))

        # ---- drift-stall: adaptation placement (repro.adapt) -----------
        rows.extend(_drift_stall_rows(mk))
    finally:
        for d in dirs:
            shutil.rmtree(d, ignore_errors=True)
    return rows


def main() -> None:
    """CI entry: run only the drift-stall suite and enforce its bars."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--drift-only", action="store_true")
    ap.add_argument("--no-guard", action="store_true",
                    help="print the rows without asserting the bars")
    args = ap.parse_args()
    dirs: List[str] = []

    def mk() -> str:
        d = tempfile.mkdtemp()
        dirs.append(d)
        return d

    try:
        rows = (_drift_stall_rows(mk) if args.drift_only
                else run(iters=1))
    finally:
        for d in dirs:
            shutil.rmtree(d, ignore_errors=True)
    for name, val, detail in rows:
        print(f"{name},{val * 1e6:.1f},{detail}")
    if args.no_guard:
        return
    by_name = {r[0]: r[2] for r in rows}
    kv = dict(p.split("=", 1)
              for p in by_name["adapt.drift_stall"].split(";") if "=" in p)
    ratio_async = float(kv["ratio_async"])
    ratio_inline = float(kv["ratio_inline"])
    if ratio_async > 1.5:
        raise SystemExit(
            f"drift-stall guard: async worst iteration is "
            f"{ratio_async:.2f}x the steady median (bar: <=1.5x)")
    if ratio_inline <= 1.5:
        raise SystemExit(
            f"drift-stall guard: inline worst/median {ratio_inline:.2f} "
            f"<=1.5 — the scenario is not paying a visible inline "
            f"adaptation, so the async comparison is vacuous")
    sp = dict(p.split("=", 1)
              for p in by_name["adapt.speculative"].split(";") if "=" in p)
    if int(sp["spec_hits"]) < 1:
        raise SystemExit("drift-stall guard: speculative mode never "
                         "pre-generated the recurring policy")
    if int(sp["genpolicy"]) != 0:
        raise SystemExit("drift-stall guard: speculative mode ran inline "
                         "GenPolicy iterations")
    print("drift-stall guard: ok")


if __name__ == "__main__":
    main()
