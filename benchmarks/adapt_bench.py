"""Adaptation cost with the policy store on vs off (repro.policystore).

Each scenario drives the real Trainer + ChameleonRuntime through a
recurring or drifting operator-sequence pattern and measures
**iterations-to-recovered-throughput**: the GenPolicy steps spent (each
one runs the Detailed profiler and a fresh Algo-2 policy generation) and
the steps from a sequence change back to Stable.

Scenarios (ISSUE 4 suite):

  * ``recur``        — train→eval→train interleave: the exact sequence
    pair recurs every eval step; the store's reuse tier should absorb
    every re-adaptation after the first;
  * ``cold_restart`` — a fresh process with a warm on-disk store must
    apply the cached policy without entering GenPolicy at all;
  * ``seqlen_cycle`` — alternating seq-len buckets: the op stream
    tokenizes identically but shapes differ, exercising the
    matching-demotion path (reuse -> warm-start) and bucket-keyed
    records;
  * ``layer_change`` — a different model depth shares the store dir:
    the length-ratio gate must *not* reuse across it;
  * ``moe_experts``  — expert-count change on a MoE config: moderate
    drift, warm-start territory.

Derived columns report GenPolicy steps with the store on vs off plus
per-tier hit counts; the acceptance bar is ``on < off`` for ``recur``
and ``genpolicy=0`` for ``cold_restart``.
"""
from __future__ import annotations

import shutil
import tempfile
from typing import List, Optional

import numpy as np

import repro.configs as C
from repro.common.config import ChameleonConfig, PolicyStoreConfig, TrainConfig
from repro.data.synthetic import SyntheticTokens
from repro.runtime.trainer import Trainer

# tight enough that swap policies really generate (reduced-llama2 baseline
# peak is ~12 MiB at seq 64: 20 MiB fits baseline, 8 MiB forces ~18 swap
# entries per policy, so reuse exercises the §6.1 matching path)
BUDGET = 8 << 20


def _trainer(store_dir: Optional[str], ckdir: str, *, cfg=None, steps=40,
             eval_every=0, seq=64, batch=4, seed=0) -> Trainer:
    cfg = cfg or C.get_reduced("llama2_paper")
    tcfg = TrainConfig(steps=steps, checkpoint_every=0, checkpoint_dir=ckdir,
                       eval_every=eval_every, warmup_steps=2,
                       learning_rate=1e-3)
    cham = ChameleonConfig(
        enabled=True, hbm_budget_bytes=BUDGET,
        policystore=PolicyStoreConfig(enabled=store_dir is not None,
                                      dir=store_dir or ""))
    data = SyntheticTokens(cfg.vocab_size, seq, batch, seed=seed)
    return Trainer(cfg, tcfg, cham, data=data)


def _tiers(tr: Trainer) -> str:
    ps = tr.rt.policystore_stats()
    if ps is None:
        return "off"
    t = ps["tiers"]
    return (f"reuse:{t['reuse']}/warm:{t['warm_start']}"
            f"/regen:{t['regen']}/dem:{t['demoted']}")


def _recovery_steps(tr: Trainer) -> float:
    """Mean steps from a sequence change back to Stable."""
    a = tr.rt.adaptations
    return float(np.mean([d["steps"] for d in a])) if a else 0.0


def run(iters: int = 1) -> List[tuple]:
    rows: List[tuple] = []
    dirs: List[str] = []

    def mk() -> str:
        d = tempfile.mkdtemp()
        dirs.append(d)
        return d

    try:
        # ---- recur: train -> eval -> train interleave -----------------
        store = mk()
        tr_on = _trainer(store, mk(), steps=40, eval_every=13)
        rep_on = tr_on.train(40)
        tr_off = _trainer(None, mk(), steps=40, eval_every=13)
        rep_off = tr_off.train(40)
        t_step = float(np.median(rep_on.times[5:]))
        rows.append((
            "adapt.recur", t_step,
            f"genpolicy_on={rep_on.genpolicy_steps};genpolicy_off={rep_off.genpolicy_steps};"
            f"recovery_on={_recovery_steps(tr_on):.1f};"
            f"recovery_off={_recovery_steps(tr_off):.1f};"
            f"tiers={_tiers(tr_on)}"))

        # ---- cold restart against the warm on-disk store --------------
        tr_cold = _trainer(store, mk(), steps=8)
        rep_cold = tr_cold.train(8)
        rows.append((
            "adapt.cold_restart", float(np.median(rep_cold.times)),
            f"genpolicy={rep_cold.genpolicy_steps};stages={sorted(set(rep_cold.stages))};"
            f"tiers={_tiers(tr_cold)} (bar: genpolicy=0)"))

        # ---- seq-len bucket cycling ------------------------------------
        # period must exceed one cold adaptation (m warmup + n genpolicy
        # steps) or nothing ever finishes and gets stored
        def cycle_hook(tr: Trainer, period: int = 12):
            cfg = tr.cfg
            buckets = [SyntheticTokens(cfg.vocab_size, 64, 4, seed=0),
                       SyntheticTokens(cfg.vocab_size, 96, 4, seed=1)]

            def hook(step: int):
                if (step + 1) % period == 0:
                    tr.data = buckets[((step + 1) // period) % 2]
            return hook

        store2 = mk()
        tr2_on = _trainer(store2, mk(), steps=48)
        rep2_on = tr2_on.train(48, fault_hook=cycle_hook(tr2_on))
        tr2_off = _trainer(None, mk(), steps=48)
        rep2_off = tr2_off.train(48, fault_hook=cycle_hook(tr2_off))
        rows.append((
            "adapt.seqlen_cycle", float(np.median(rep2_on.times[5:])),
            f"genpolicy_on={rep2_on.genpolicy_steps};genpolicy_off={rep2_off.genpolicy_steps};"
            f"recovery_on={_recovery_steps(tr2_on):.1f};"
            f"recovery_off={_recovery_steps(tr2_off):.1f};"
            f"tiers={_tiers(tr2_on)}"))

        # ---- layer-count change (must NOT reuse across it) -------------
        store3 = mk()
        tr3a = _trainer(store3, mk(), steps=14)
        tr3a.train(14)
        deeper = C.get_reduced("llama2_paper").replace(num_layers=6)
        tr3b = _trainer(store3, mk(), cfg=deeper, steps=14)
        rep3b = tr3b.train(14)
        rows.append((
            "adapt.layer_change", float(np.median(rep3b.times[5:])),
            f"genpolicy_after_change={rep3b.genpolicy_steps};tiers={_tiers(tr3b)} "
            f"(bar: no reuse hit)"))

        # ---- MoE expert-count change -----------------------------------
        moe = C.get_reduced("granite_moe_1b_a400m")
        store4 = mk()
        tr4a = _trainer(store4, mk(), cfg=moe, steps=12)
        tr4a.train(12)
        moe2 = moe.replace(num_experts=2 * moe.num_experts)
        tr4b = _trainer(store4, mk(), cfg=moe2, steps=12)
        rep4b = tr4b.train(12)
        rows.append((
            "adapt.moe_experts", float(np.median(rep4b.times[5:])),
            f"genpolicy_after_change={rep4b.genpolicy_steps};tiers={_tiers(tr4b)}"))
    finally:
        for d in dirs:
            shutil.rmtree(d, ignore_errors=True)
    return rows
