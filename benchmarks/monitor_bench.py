"""Monitoring hot-path micro-benchmarks (ISSUE 5).

Old-vs-new timings for the four always-on/adaptation primitives this PR
vectorized, each printed with its speedup:

  * ``signature`` — per-iteration op-stream signature + Algo-1 similarity:
    full re-concatenate + re-bincount (old) vs the incremental
    ``SignatureAccumulator`` + content-key short-circuit (new);
  * ``match`` — §6.1 fuzzy matching: per-instance Python loop with
    O(old x bucket) ``pack_features`` calls (reference) vs the
    array-native bucketed assignment;
  * ``fingerprint`` — policystore sketching of a recurring stream: full
    shingle/MinHash/unique pass vs the exact-hash memo hit;
  * ``nearest@1k`` — policy lookup across 1000 records: exhaustive
    Python similarity scan vs the LSH band-bucket probe.

All inputs are synthetic and CPU-only; no jax dispatch is involved, so
the numbers isolate the monitoring bookkeeping itself.
"""
from __future__ import annotations

import numpy as np

from repro.common.config import ChameleonConfig, PolicyStoreConfig
from repro.core import tokenizer
from repro.core.matching import match_instances, match_instances_reference
from repro.core.profiler import ProfileData, TensorInstance
from repro.core.stages import StageMachine
from repro.policystore import (PolicyRecord, PolicyStore, fingerprint_tokens)

from benchmarks.common import time_call


# --------------------------------------------------------------- fixtures
def _synth_profile(n_sites=8, n_layers=64, jitter=0, seed=0) -> ProfileData:
    r = np.random.RandomState(seed)
    tensors = []
    uid = 0
    per = 12
    n_ops = n_sites * n_layers * per
    for s in range(n_sites):
        for l in range(n_layers):
            birth = (s * n_layers + l) * per + \
                (int(r.randint(0, jitter + 1)) if jitter else 0)
            tensors.append(TensorInstance(
                uid, 1 << 20, birth, n_ops - birth, site=f"site{s}",
                layer=l, dtype_code=1 + (s % 3), shape=(64, 64 + s)))
            uid += 1
    return ProfileData(np.zeros(n_ops, np.int32), tensors, 1.0, 0)


def _record(fp) -> PolicyRecord:
    return PolicyRecord.from_policy(
        fingerprint=fp, prepare_fingerprint=fp, swap=None, candidates=[],
        n_ops=max(fp.length, 1), knob=1.0, measured_t=0.1, budget=1 << 30,
        policy_kind="conservative")


def run(iters: int = 5):
    rows: list = []
    rng = np.random.RandomState(0)

    def add(name, t_old, t_new, extra=""):
        sp = t_old / t_new if t_new > 0 else float("inf")
        sep = " " if extra else ""
        rows.append((f"monitor.{name}.old", t_old, f"speedup=1.0x{sep}{extra}"))
        rows.append((f"monitor.{name}.new", t_new,
                     f"speedup={sp:.1f}x{sep}{extra}"))

    # ---- signature: 4 dispatches x 50k virtual ops, unchanged iteration
    streams = [tokenizer.TokenStream(
        rng.randint(1, 120, size=50_000).astype(np.int32))
        for _ in range(4)]
    arrs = [s.tokens for s in streams]
    sm_old = StageMachine(ChameleonConfig())
    sm_new = StageMachine(ChameleonConfig())
    acc = tokenizer.SignatureAccumulator()
    sm_new.observe(acc.update(streams))

    def sig_old():
        sig = tokenizer.sequence_signature(arrs)
        sm_old.observe(sig)

    def sig_new():
        sm_new.observe(acc.update(streams))

    add("signature", time_call(sig_old, iters=iters),
        time_call(sig_new, iters=iters),
        f"n_ops={sum(s.virtual_len for s in streams)}")

    # ---- match_instances: 512 candidates, 64-deep buckets
    old_p = _synth_profile(seed=1)
    new_p = _synth_profile(jitter=6, seed=2)
    ref = match_instances_reference(old_p, new_p)
    vec = match_instances(old_p, new_p)
    assert ref.mapping == vec.mapping and ref.unmatched == vec.unmatched
    add("match_instances",
        time_call(match_instances_reference, old_p, new_p, iters=iters),
        time_call(match_instances, old_p, new_p, iters=iters),
        f"candidates={len(old_p.candidates)} matched={len(vec.mapping)}")

    # ---- fingerprint: recurring 200k-token stream (memo hit vs full pass)
    toks = np.tile(rng.randint(1, 80, size=2_000).astype(np.int32), 100)
    fingerprint_tokens(toks)                      # warm the memo
    add("fingerprint",
        time_call(lambda: fingerprint_tokens(toks, cache=False),
                  iters=iters),
        time_call(lambda: fingerprint_tokens(toks), iters=iters),
        f"tokens={toks.size}")

    # ---- nearest @ 1k records: LSH probe vs exhaustive similarity scan
    store = PolicyStore(PolicyStoreConfig(max_records=1024))
    base = None
    for i in range(1000):
        t = rng.randint(1, 40, size=400).astype(np.int32)
        if i == 500:
            base = t
        store.put(_record(fingerprint_tokens(t, cache=False)))
    query = fingerprint_tokens(np.concatenate([base, base[:5]]), cache=False)
    r_new, s_new = store.nearest(query)
    r_old, s_old = store.nearest_exhaustive(query)
    assert s_new >= min(s_old, store.cfg.reuse_threshold)
    evals0 = store.n_sim_evals

    def probe():
        store.nearest(query)

    t_new = time_call(probe, iters=iters)
    t_old = time_call(store.nearest_exhaustive, query, iters=iters)
    per_probe = (store.n_sim_evals - evals0) // max(iters + 2, 1)
    add("nearest@1k", t_old, t_new,
        f"records=1000 sim_evals/probe<={max(per_probe, 1)}")

    # ---- obs tracing overhead (ISSUE 6): the always-on span tracer must
    # honor the same leave-it-on bar as the signature path.  Two rows:
    # the raw per-record cost, and the signature workload untraced vs
    # wrapped in a span (the shape every wired subsystem uses).
    from repro import obs

    tr = obs.SpanTracer()

    def record_block():
        for _ in range(100):
            tr.record(obs.LANE_COMPUTE, "bench", 0.0, 1.0, arg=("tag", 1))

    t_rec = time_call(record_block, iters=iters) / 100
    rows.append(("monitor.obs.record_span", t_rec,
                 f"capacity={tr.capacity}"))

    def sig_traced():
        with tr.span(obs.LANE_COMPUTE, "signature"):
            sm_new.observe(acc.update(streams))

    t_plain = time_call(sig_new, iters=max(iters, 5))
    t_traced = time_call(sig_traced, iters=max(iters, 5))
    added = max(t_traced - t_plain, 0.0)
    rows.append(("monitor.obs.signature_traced", t_traced,
                 f"added<={added * 1e6:.1f}us vs untraced "
                 f"{t_plain * 1e6:.1f}us"))

    # ---- disarmed fault hook (ISSUE 8): inject() sits on every transfer,
    # alloc, store and checkpoint call, so with no plan armed it must cost
    # one global read + a None check — same leave-it-on bar as tracing
    from repro import faults

    faults.disarm()

    def inject_block():
        for _ in range(100):
            faults.inject("engine.transfer_error", key="bench")

    t_inj = time_call(inject_block, iters=iters) / 100
    rows.append(("monitor.faults.inject_disarmed", t_inj,
                 "per-call cost with no FaultPlan armed"))
    return rows
