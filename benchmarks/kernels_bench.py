"""Kernel micro-benchmarks (interpret-mode wall times are NOT TPU perf —
the derived column reports achieved-vs-reference correctness + shapes;
TPU roofline positioning comes from the dry-run analysis)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.quant_offload.ops import dequantize, quantize
from repro.kernels.ssd_scan.ops import ssd_scan

from benchmarks.common import time_call


def run(iters: int = 3):
    rng = np.random.RandomState(0)
    rows = []

    q = jnp.asarray(rng.randn(1, 512, 4, 64) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(1, 512, 2, 64) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(1, 512, 2, 64) * 0.3, jnp.float32)
    fa = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True))
    t = time_call(fa, q, k, v, iters=iters)
    flops = 4 * 512 * 512 * 4 * 64
    rows.append(("kernel.flash_attention_512", t,
                 f"gqa=2x;flops={flops:.2e};interpret=True"))

    x = jnp.asarray(rng.randn(2, 512, 4, 64) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(2, 512, 4)) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.randn(4)) + 0.5, jnp.float32)
    Bm = jnp.asarray(rng.randn(2, 512, 64) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(2, 512, 64) * 0.3, jnp.float32)
    ssd = jax.jit(lambda *a: ssd_scan(*a, chunk=128))
    t = time_call(ssd, x, dt, A, Bm, Cm, iters=iters)
    rows.append(("kernel.ssd_scan_512", t, "chunk=128;interpret=True"))

    big = jnp.asarray(rng.randn(1024, 1024), jnp.float32)
    qz = jax.jit(quantize)
    t = time_call(qz, big, iters=iters)
    rows.append(("kernel.quantize_1Mx", t,
                 f"compression={big.dtype.itemsize}x->1x+scales"))

    rows.extend(_autotune_rows(iters))
    return rows


def _autotune_rows(iters):
    """Default-vs-tuned block configs for the swap-path kernels: one
    measurement sweep per kernel (variant[0] is the hardcoded default),
    achieved bytes/s + roofline efficiency per row.  Tuned >= default by
    construction — the winner is the argmax of the same sweep."""
    from repro.kernels.autotune.device import get_device_spec
    from repro.kernels.autotune.space import SPACES
    from repro.kernels.autotune.tuner import default_measure

    spec = get_device_spec()
    dtype = np.dtype(np.float32)
    rows = []
    for kernel in ("quantize", "dequantize"):
        space = SPACES[kernel]
        shape = space.default_shape
        args = space.make_args(shape, dtype)
        nbytes = space.bytes_moved(shape, dtype)
        sweep = []
        for config in space.variants:
            sec = default_measure(lambda: space.run(args, config),
                                  iters=iters)
            sweep.append((nbytes / sec if sec > 0 else 0.0, sec, config))
        default_bps, default_s, default_cfg = sweep[0]
        tuned_bps, tuned_s, tuned_cfg = max(sweep, key=lambda r: r[0])
        for tag, bps, sec, cfg in (
                ("default", default_bps, default_s, default_cfg),
                ("tuned", tuned_bps, tuned_s, tuned_cfg)):
            eff = min(bps / spec.hbm_bw, 1.0)
            rows.append((f"kernel.{kernel}_{tag}", sec,
                         f"config={cfg};achieved_gbps={bps / 1e9:.3f};"
                         f"efficiency={eff:.2e};"
                         f"speedup_vs_default="
                         f"{bps / default_bps if default_bps else 1.0:.2f}x"
                         f";interpret={jax.default_backend() != 'tpu'}"))
    return rows
