"""Paper Table 2: performance benefit of swap over (a) recomputation and
(b) higher-degree parallelism.

(a) is *measured* on CPU: full-remat step vs Chameleon-policy step (swap is
free on the CPU backend where host==device, matching the paper's premise
that overlapped swap has no critical-path cost; the stall term computed by
the simulator is reported alongside).  Paper: up to 38.94% / avg ~19%.

(b) is roofline-derived from the dry-run artifacts when present: the same
arch mapped TP16×DP16 (baseline) vs DP-heavy after swap frees the memory —
the paper's "reduce TP/PP in favor of DP" argument in collective-bytes form.
"""
from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.common.config import ChameleonConfig, TrainConfig
from repro.core.executor import Executor
from repro.distributed.steps import make_train_step
from repro.models.registry import get_api
from repro.optim.adamw import adamw_init

from benchmarks.common import time_call

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run(iters: int = 5):
    cfg = C.get_reduced("llama2_paper").replace(num_layers=8)
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {"tokens": jnp.ones((4, 256), jnp.int32),
             "labels": jnp.ones((4, 256), jnp.int32)}
    args = (params, opt, batch, jnp.float32(1.0))
    ex = Executor(ChameleonConfig())
    tcfg = TrainConfig()

    t_remat = time_call(jax.jit(make_train_step(cfg, tcfg, "full_remat")),
                        *args, iters=iters)
    t_cham = time_call(
        jax.jit(make_train_step(cfg, tcfg, ex.conservative(None).to_jax())),
        *args, iters=iters)
    t_base = time_call(
        jax.jit(make_train_step(cfg, tcfg, ex.baseline().to_jax())),
        *args, iters=iters)

    benefit = 100.0 * (t_remat - t_cham) / t_remat
    rows = [
        ("table2.full_recompute", t_remat, "policy=remat"),
        ("table2.chameleon_swap", t_cham,
         f"benefit_vs_recompute={benefit:.1f}% (paper: up to 38.94%)"),
        ("table2.no_constraint_baseline", t_base,
         f"chameleon_overhead={100 * (t_cham - t_base) / t_base:.1f}%"),
    ]

    # (b) parallelism-degree comparison from dry-run artifacts
    f = os.path.join(ART, "qwen1_5_0_5b__train_4k__single__none.json")
    if os.path.exists(f):
        with open(f) as fh:
            rec = json.load(fh)
        r = rec["roofline"]
        tp_bound = r["step_time_bound_s"]
        # DP-heavy bound: drop per-layer TP all-reduces, keep one grad
        # all-reduce (params bytes * 2 / link); compute term unchanged
        import repro.configs as CC
        full = CC.get_config("qwen1_5_0_5b")
        grad_bytes = full.param_count() * 2 * 2  # bf16 grads, ring 2x
        coll_dp = grad_bytes / 50e9
        dp_bound = max(r["compute_s"], r["memory_s"], coll_dp)
        rows.append((
            "table2.tp16_vs_dp_roofline", tp_bound,
            f"dp_bound={dp_bound * 1e3:.1f}ms;speedup={tp_bound / dp_bound:.2f}x"
            " (needs Chameleon to fit DP-only)"))
    return rows
