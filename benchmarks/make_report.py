"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
artifacts/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.make_report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

ARCH_ORDER = ["whisper_large_v3", "qwen2_7b", "qwen1_5_0_5b",
              "stablelm_1_6b", "llama3_2_1b", "qwen3_moe_30b_a3b",
              "granite_moe_1b_a400m", "llama3_2_vision_90b", "mamba2_780m",
              "zamba2_1_2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, policy: str):
    out = {}
    for f in glob.glob(os.path.join(ART, f"*__{mesh}__{policy}.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_ms(s):
    return f"{s * 1e3:.1f}"


def roofline_table(mesh: str = "single", policy: str = "none") -> str:
    recs = load(mesh, policy)
    lines = [
        "| arch | shape | peak/chip GiB | compute ms | memory ms | "
        "collective ms | bottleneck | MODEL/HLO flops | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                lines.append(
                    f"| {arch} | {shape} | — | — | — | — | "
                    f"skip (full-attn, long_500k needs sub-quadratic) | — | — |")
                continue
            rl = r["roofline"]
            m = r["memory"]
            lines.append(
                f"| {arch} | {shape} | {m['peak_per_chip'] / 2**30:.2f} "
                f"| {fmt_ms(rl['compute_s'])} | {fmt_ms(rl['memory_s'])} "
                f"| {fmt_ms(rl['collective_s'])} | {rl['bottleneck']} "
                f"| {rl['useful_flops_ratio']:.2f} "
                f"| {rl['mfu_bound']:.3f} |")
    return "\n".join(lines)


def memory_table(policy: str = "chameleon") -> str:
    recs = load("single", policy)
    base = load("single", "none")
    lines = [
        "| arch (train_4k) | baseline peak/chip | policy | swapped/chip | "
        "device est (TPU) | fits 16G | stall ms |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        r = recs.get((arch, "train_4k"))
        b = base.get((arch, "train_4k"))
        if not r or not b:
            continue
        pi = r.get("policy_info", {})
        m = r["memory"]
        bpeak = b["memory"]["peak_per_chip"] / 2 ** 30
        sw = pi.get("swapped_bytes_per_chip", 0) / 2 ** 30
        dev = m.get("device_peak_est_tpu", m["peak_per_chip"]) / 2 ** 30
        fits = m.get("fits_16g_with_offload", m["fits_16g"])
        stall = pi.get("stall_s", 0.0) * 1e3
        lines.append(f"| {arch} | {bpeak:.2f} GiB | {pi.get('policy')} "
                     f"| {sw:.2f} GiB | {dev:.2f} GiB | {fits} "
                     f"| {stall:.0f} |")
    return "\n".join(lines)


def multi_vs_single() -> str:
    s = load("single", "none")
    m = load("multi", "none")
    lines = [
        "| arch | shape | 1-pod coll ms | 2-pod coll ms | 1-pod peak GiB | "
        "2-pod peak GiB |",
        "|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            a, b = s.get((arch, shape)), m.get((arch, shape))
            if not a or not b:
                continue
            lines.append(
                f"| {arch} | {shape} "
                f"| {fmt_ms(a['roofline']['collective_s'])} "
                f"| {fmt_ms(b['roofline']['collective_s'])} "
                f"| {a['memory']['peak_per_chip'] / 2**30:.2f} "
                f"| {b['memory']['peak_per_chip'] / 2**30:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", choices=["roofline", "memory", "multi"],
                    default="roofline")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--policy", default="none")
    args = ap.parse_args()
    if args.table == "roofline":
        print(roofline_table(args.mesh, args.policy))
    elif args.table == "memory":
        print(memory_table(args.policy))
    else:
        print(multi_vs_single())


if __name__ == "__main__":
    main()
