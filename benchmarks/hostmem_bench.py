"""Host-memory tier benchmark: pool reuse under steady-state swap churn,
measured-curve vs constant-bandwidth transfer-time prediction error, and
policy-swap latency under a concurrent checkpoint drain.

Claims the hostmem subsystem makes, measured:

  * the slab pool amortizes host allocation — after the first training
    step touches each size class, the steady-state hit rate must be
    >= 90% (it is ~= (steps-1)/steps: only step 0 misses);
  * the calibrated piecewise curve predicts real host-link transfer
    times far better than the single ``host_link_gbps`` constant,
    especially in the latency-bound small-size regime the constant
    cannot represent.  We calibrate on even powers of two and evaluate
    on the held-out odd powers;
  * the prioritized per-traffic-class streams keep a policy swap's
    completion latency low even when a bulk checkpoint drain is queued:
    on a single shared queue the swap waits behind the whole drain
    (FIFO), on the class streams it preempts the drain at transfer
    granularity.  The multi-stream latency must be strictly better.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, time_call
from repro.common.config import HostMemConfig
from repro.hostmem import (BandwidthModel, HostMemTier, TC_CHECKPOINT,
                           TC_POLICY_SWAP)
from repro.hostmem.pool import PinnedSlabPool


# ---------------------------------------------------------- pool reuse
def _pool_steady_state(steps: int = 50) -> Row:
    """Replay a swap working set (one policy's candidate sizes) for
    ``steps`` iterations — the per-step alloc/free pattern of training."""
    working_set = [3 << 20, 3 << 20, 1 << 22, 768 << 10, 1 << 20,
                   5 << 20, 256 << 10, 1 << 22]
    pool = PinnedSlabPool()
    t0 = time.perf_counter()
    steady_allocs = steady_hits = 0
    for step in range(steps):
        blocks = [pool.alloc(s, tag=f"cand{i}")
                  for i, s in enumerate(working_set)]
        for b in blocks:
            pool.free(b)
        if step > 0:                       # steady state = after warm-up
            steady_allocs += len(working_set)
    dt = time.perf_counter() - t0
    pool.check()
    steady_hits = pool.reuse_hits          # only step 0 can miss
    rate = steady_hits / steady_allocs if steady_allocs else 0.0
    assert rate >= 0.90, f"steady-state pool hit rate {rate:.1%} < 90%"
    return ("hostmem_pool.steady_hit_rate", dt / steps,
            f"hit_rate={rate:.3f} slab_allocs={pool.slab_allocs} "
            f"frag={pool.fragmentation:.3f}")


# --------------------------------------- calibrated vs constant pricing
def _measure_actual(tier: HostMemTier, size: int, iters: int) -> float:
    """Ground-truth one-way transfer time through the production engine
    path (pool-staged copy) — the same mechanism the policy schedules.
    Same estimator as calibration: min of warm out/in round trips."""
    arr = np.zeros(size, np.uint8)
    outs, ins = [], []
    for i in range(max(iters, 2) + 1):
        ev = tier.engine.wait(tier.engine.submit_swap_out(arr, "probe"))
        ev2 = tier.engine.wait(tier.engine.submit_swap_in(ev, "probe"))
        if i:                              # drop the cold (slab-alloc) run
            outs.append(ev.seconds)
            ins.append(ev2.seconds)
    return (min(outs) + min(ins)) / 2


def _prediction_error(iters: int) -> Row:
    from repro.common.config import HOSTMEM_CALIBRATION_SIZES
    constant_gbps = 32.0                   # ChameleonConfig default (Eq. 3)
    calib_sizes = HOSTMEM_CALIBRATION_SIZES                 # even powers
    eval_sizes = tuple(s << 1 for s in calib_sizes[:-1])    # held-out odd
    tier = HostMemTier(constant_gbps=constant_gbps)
    t0 = time.perf_counter()
    model = tier.calibrate(calib_sizes, iters=max(iters, 3))
    dt = time.perf_counter() - t0
    # evaluate with a separate probe tier so held-out samples don't feed
    # back into the curve under test
    probe = HostMemTier(constant_gbps=constant_gbps)
    errs_model, errs_const = [], []
    for s in eval_sizes:
        actual = _measure_actual(probe, s, iters)
        errs_model.append(abs(model.transfer_time(s) - actual) / actual)
        errs_const.append(abs(s / (constant_gbps * 1e9) - actual) / actual)
    em = float(np.mean(errs_model))
    ec = float(np.mean(errs_const))
    return ("hostmem_bwmodel.prediction_error", dt,
            f"calibrated_err={em:.3f} constant_err={ec:.3f} "
            f"improvement={ec / max(em, 1e-9):.1f}x")


# ----------------------------------------------------- engine throughput
def _engine_throughput(iters: int) -> Row:
    tier = HostMemTier()
    arr = np.random.RandomState(0).randn(1 << 18).astype(np.float32)  # 1 MiB

    def churn():
        evs = [tier.engine.submit_swap_out(arr, f"t{i}") for i in range(8)]
        tier.engine.synchronize()
        for ev in evs:
            tier.engine.wait(tier.engine.submit_swap_in(ev))

    sec = time_call(churn, iters=max(iters, 3))
    st = tier.engine.stats()
    return ("hostmem_engine.churn_8x1MiB", sec,
            f"gbps_out={st['gbps_out']:.2f} gbps_in={st['gbps_in']:.2f} "
            f"pool_hit_rate={tier.pool.hit_rate:.3f}")


# --------------------------- policy-swap latency under checkpoint drain
_DRAIN_TRANSFERS = 8
_DRAIN_BYTES = 8 << 20                  # 8 x 8 MiB queued checkpoint drain
_SWAP_BYTES = 1 << 20                   # the latency-critical policy swap


def _swap_latency(ckpt_class: str, iters: int) -> float:
    """Queue a full checkpoint drain, then submit one policy swap and
    measure its wait-to-completion.  ``ckpt_class`` selects the baseline
    (drain shares the policy_swap queue = old single-queue engine) or the
    split-stream engine (drain on the checkpoint class)."""
    best = None
    for _ in range(max(iters, 3)):
        tier = HostMemTier(HostMemConfig(
            engine_depth=2,
            class_depths=(("policy_swap", _DRAIN_TRANSFERS + 2),
                          ("checkpoint", _DRAIN_TRANSFERS + 2))))
        eng = tier.engine
        drain = np.zeros(_DRAIN_BYTES, np.uint8)
        swap = np.zeros(_SWAP_BYTES, np.uint8)
        # warm the slab classes so neither scenario pays first-touch allocs
        for arr, cls in ((drain, ckpt_class), (swap, TC_POLICY_SWAP)):
            ev = eng.submit_swap_out(arr, "warm", cls=cls)
            eng.wait(ev)
            tier.pool.free(ev.block)
        for i in range(_DRAIN_TRANSFERS):
            eng.submit_swap_out(drain, f"ckpt{i}", cls=ckpt_class)
        ev = eng.submit_swap_out(swap, "policy", cls=TC_POLICY_SWAP)
        t0 = time.perf_counter()
        eng.wait(ev)                     # FIFO drains first iff same class
        dt = time.perf_counter() - t0
        eng.synchronize()
        best = dt if best is None else min(best, dt)
    return best


def _swap_under_checkpoint_drain(iters: int) -> Row:
    single = _swap_latency(TC_POLICY_SWAP, iters)   # shared-queue baseline
    multi = _swap_latency(TC_CHECKPOINT, iters)     # split class streams
    assert multi < single, \
        f"class streams must beat the single queue: {multi} >= {single}"
    return ("hostmem_engine.swap_latency_under_ckpt_drain", multi,
            f"single_q_ms={single * 1e3:.2f} multi_q_ms={multi * 1e3:.2f} "
            f"speedup={single / max(multi, 1e-9):.1f}x "
            f"drain={_DRAIN_TRANSFERS}x{_DRAIN_BYTES >> 20}MiB")


def _per_class_stats(iters: int) -> Row:
    """Mixed traffic through one engine: per-class counters must separate
    the flows and account checkpoint stall behind higher classes."""
    tier = HostMemTier(HostMemConfig(
        class_depths=(("checkpoint", _DRAIN_TRANSFERS + 2),)))
    eng = tier.engine
    drain = np.zeros(_DRAIN_BYTES, np.uint8)
    swap = np.zeros(_SWAP_BYTES, np.uint8)
    t0 = time.perf_counter()
    for _ in range(max(iters, 3)):
        evs = [eng.submit_swap_out(drain, "ck", cls=TC_CHECKPOINT)
               for _ in range(4)]
        pol = eng.submit_swap_out(swap, "pol", cls=TC_POLICY_SWAP)
        eng.wait(evs[0])             # the policy swap preempts the drain
        assert pol.done, "strict priority must run the swap first"
        for ev in evs[1:]:
            eng.wait(ev)
        for ev in evs:
            tier.pool.free(ev.block)
        tier.pool.free(pol.block)
    dt = time.perf_counter() - t0
    cs = eng.stats()["classes"]
    pol_c, ck_c = cs["policy_swap"], cs["checkpoint"]
    tier.pool.check()
    return ("hostmem_engine.per_class_stats", dt / max(iters, 3),
            f"policy_out={pol_c['n_out']} ckpt_out={ck_c['n_out']} "
            f"ckpt_stall_ms={ck_c['stall_s'] * 1e3:.2f} "
            f"ckpt_waits={ck_c['stall_transfers']} "
            f"pool_hit_rate={tier.pool.hit_rate:.3f}")


def run(iters: int = 3):
    return [_pool_steady_state(),
            _prediction_error(iters),
            _engine_throughput(iters),
            _swap_under_checkpoint_drain(iters),
            _per_class_stats(iters)]
