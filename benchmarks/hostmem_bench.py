"""Host-memory tier benchmark: pool reuse under steady-state swap churn,
and measured-curve vs constant-bandwidth transfer-time prediction error.

Two claims the hostmem subsystem makes, measured:

  * the slab pool amortizes host allocation — after the first training
    step touches each size class, the steady-state hit rate must be
    >= 90% (it is ~= (steps-1)/steps: only step 0 misses);
  * the calibrated piecewise curve predicts real host-link transfer
    times far better than the single ``host_link_gbps`` constant,
    especially in the latency-bound small-size regime the constant
    cannot represent.  We calibrate on even powers of two and evaluate
    on the held-out odd powers.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, time_call
from repro.hostmem import BandwidthModel, HostMemTier
from repro.hostmem.pool import PinnedSlabPool


# ---------------------------------------------------------- pool reuse
def _pool_steady_state(steps: int = 50) -> Row:
    """Replay a swap working set (one policy's candidate sizes) for
    ``steps`` iterations — the per-step alloc/free pattern of training."""
    working_set = [3 << 20, 3 << 20, 1 << 22, 768 << 10, 1 << 20,
                   5 << 20, 256 << 10, 1 << 22]
    pool = PinnedSlabPool()
    t0 = time.perf_counter()
    steady_allocs = steady_hits = 0
    for step in range(steps):
        blocks = [pool.alloc(s, tag=f"cand{i}")
                  for i, s in enumerate(working_set)]
        for b in blocks:
            pool.free(b)
        if step > 0:                       # steady state = after warm-up
            steady_allocs += len(working_set)
    dt = time.perf_counter() - t0
    pool.check()
    steady_hits = pool.reuse_hits          # only step 0 can miss
    rate = steady_hits / steady_allocs if steady_allocs else 0.0
    assert rate >= 0.90, f"steady-state pool hit rate {rate:.1%} < 90%"
    return ("hostmem_pool.steady_hit_rate", dt / steps,
            f"hit_rate={rate:.3f} slab_allocs={pool.slab_allocs} "
            f"frag={pool.fragmentation:.3f}")


# --------------------------------------- calibrated vs constant pricing
def _measure_actual(tier: HostMemTier, size: int, iters: int) -> float:
    """Ground-truth one-way transfer time through the production engine
    path (pool-staged copy) — the same mechanism the policy schedules.
    Same estimator as calibration: min of warm out/in round trips."""
    arr = np.zeros(size, np.uint8)
    outs, ins = [], []
    for i in range(max(iters, 2) + 1):
        ev = tier.engine.wait(tier.engine.submit_swap_out(arr, "probe"))
        ev2 = tier.engine.wait(tier.engine.submit_swap_in(ev, "probe"))
        if i:                              # drop the cold (slab-alloc) run
            outs.append(ev.seconds)
            ins.append(ev2.seconds)
    return (min(outs) + min(ins)) / 2


def _prediction_error(iters: int) -> Row:
    from repro.common.config import HOSTMEM_CALIBRATION_SIZES
    constant_gbps = 32.0                   # ChameleonConfig default (Eq. 3)
    calib_sizes = HOSTMEM_CALIBRATION_SIZES                 # even powers
    eval_sizes = tuple(s << 1 for s in calib_sizes[:-1])    # held-out odd
    tier = HostMemTier(constant_gbps=constant_gbps)
    t0 = time.perf_counter()
    model = tier.calibrate(calib_sizes, iters=max(iters, 3))
    dt = time.perf_counter() - t0
    # evaluate with a separate probe tier so held-out samples don't feed
    # back into the curve under test
    probe = HostMemTier(constant_gbps=constant_gbps)
    errs_model, errs_const = [], []
    for s in eval_sizes:
        actual = _measure_actual(probe, s, iters)
        errs_model.append(abs(model.transfer_time(s) - actual) / actual)
        errs_const.append(abs(s / (constant_gbps * 1e9) - actual) / actual)
    em = float(np.mean(errs_model))
    ec = float(np.mean(errs_const))
    return ("hostmem_bwmodel.prediction_error", dt,
            f"calibrated_err={em:.3f} constant_err={ec:.3f} "
            f"improvement={ec / max(em, 1e-9):.1f}x")


# ----------------------------------------------------- engine throughput
def _engine_throughput(iters: int) -> Row:
    tier = HostMemTier()
    arr = np.random.RandomState(0).randn(1 << 18).astype(np.float32)  # 1 MiB

    def churn():
        evs = [tier.engine.submit_swap_out(arr, f"t{i}") for i in range(8)]
        tier.engine.synchronize()
        for ev in evs:
            tier.engine.wait(tier.engine.submit_swap_in(ev))

    sec = time_call(churn, iters=max(iters, 3))
    st = tier.engine.stats()
    return ("hostmem_engine.churn_8x1MiB", sec,
            f"gbps_out={st['gbps_out']:.2f} gbps_in={st['gbps_in']:.2f} "
            f"pool_hit_rate={tier.pool.hit_rate:.3f}")


def run(iters: int = 3):
    return [_pool_steady_state(),
            _prediction_error(iters),
            _engine_throughput(iters)]
