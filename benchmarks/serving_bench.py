"""Serving throughput under over-subscription vs queueing (ROADMAP item).

The same request load is pushed through the slot server twice:

  * **queueing** — admission capped at the HBM-resident slot count
    (``max_active == max_batch``): excess requests wait in the queue;
  * **over-subscription** — ``max_active > max_batch`` with the host
    tier: excess requests are admitted immediately and preempted decode
    state parks in the pinned pool.

Derived columns come from ``Server.latency_stats()`` (tick-level
batching log): token throughput, slot occupancy, per-tick latency
percentiles, and per-request queue-wait / completion percentiles — the
trade over-subscription makes is queue-wait for spill traffic.
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

import repro.configs as C
from repro.models.registry import get_api
from repro.runtime.server import Server

MAX_BATCH = 2
N_REQUESTS = 8
NEW_TOKENS = 8


def _load(srv: Server) -> None:
    rng = np.random.RandomState(0)
    for _ in range(N_REQUESTS):
        srv.submit(rng.randint(0, srv.cfg.vocab_size, size=rng.randint(4, 12)),
                   max_new_tokens=NEW_TOKENS)


def run(iters: int = 1) -> List[tuple]:
    cfg = C.get_reduced("llama2_paper")
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    rows: List[tuple] = []
    for mode, max_active in (("queueing", MAX_BATCH),
                             ("oversub", 2 * MAX_BATCH)):
        srv = Server(cfg, params, max_batch=MAX_BATCH, max_len=64,
                     max_active=max_active)
        _load(srv)
        srv.run_until_done(max_ticks=2000)
        lat = srv.latency_stats()
        t_tick = lat["tick_ms"]["p50"] * 1e-3
        rows.append((
            f"serving.{mode}", t_tick,
            f"tok_per_s={lat['tokens_per_s']:.1f};"
            f"tok_per_tick={lat['tokens_per_tick']:.2f};"
            f"occupancy={lat['slot_occupancy']:.2f};"
            f"tick_p95_ms={lat['tick_ms']['p95']:.1f};"
            f"queue_wait_p95={lat['queue_wait_ticks']['p95']:.0f};"
            f"completion_p95={lat['completion_ticks']['p95']:.0f};"
            f"preemptions={srv.n_preemptions}"))
    return rows
