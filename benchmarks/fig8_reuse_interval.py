"""Paper Fig 8: custom recordStream vs naive — memory-block reuse interval.

The simulator's swap-out completion points (§5.4.2) give the release op for
each swapped block (our XLA-schedule analogue of the custom recordStream);
the naive policy holds blocks until the next use of the tensor (host-poll
recordStream semantics).  Paper: naive is 3-4x longer on average, up to
2-3 orders of magnitude at the tail.  Also reports the projected peak-memory
consequence of late release."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.common.config import ChameleonConfig, TrainConfig
from repro.core.executor import Executor
from repro.core.memtrace import build_timeline
from repro.core.policy import generate_policy
from repro.core.profiler import profile_jaxpr
from repro.core.simulator import Simulator
from repro.distributed.steps import make_grad_step
from repro.models.registry import get_api


def run(iters: int = 1):
    cfg = C.get_reduced("llama2_paper").replace(num_layers=16)
    api = get_api(cfg)
    params_sds = jax.eval_shape(lambda k: api.init(cfg, k)[0],
                                jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 256), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 256), jnp.int32)}
    step = make_grad_step(cfg, TrainConfig(),
                          Executor(ChameleonConfig()).baseline().to_jax())
    cj = jax.make_jaxpr(step)(params_sds, batch,
                              jax.ShapeDtypeStruct((), jnp.float32))
    prof = profile_jaxpr(cj, t_iter=5.0)
    tl = build_timeline(prof)
    budget = int(tl.peak * 0.6)
    pol = generate_policy(prof, ChameleonConfig(), budget, timeline=tl)
    sim = Simulator(prof, tl.peak_op, ChameleonConfig())
    sim.set_free_time(pol.entries)
    custom = sim.reuse_intervals(pol.entries).astype(np.float64)
    naive = sim.naive_reuse_intervals(pol.entries).astype(np.float64)
    ratio_mean = naive.mean() / max(custom.mean(), 1e-9)
    ratio_max = naive.max() / max(custom.min(), 1.0)

    # peak consequence: blocks released at swap-out-done vs at next use
    n = prof.n_ops
    d_custom = np.zeros(n + 2, np.int64)
    d_naive = np.zeros(n + 2, np.int64)
    swapped = {e.uid: e for e in pol.entries}
    for t in prof.tensors:
        e = swapped.get(t.uid)
        for d, rel in ((d_custom, e.swap_out_done_op if e else t.death),
                       (d_naive, t.death)):
            d[t.birth] += t.nbytes
            d[min(max(rel, t.birth), n + 1)] -= t.nbytes
    peak_c = int(np.cumsum(d_custom)[:n + 1].max())
    peak_n = int(np.cumsum(d_naive)[:n + 1].max())
    return [
        ("fig8.reuse_interval_custom", float(custom.mean()),
         f"mean_ops={custom.mean():.0f}"),
        ("fig8.reuse_interval_naive", float(naive.mean()),
         f"mean_ops={naive.mean():.0f};mean_ratio={ratio_mean:.1f}x"
         f" (paper:3-4x);max_ratio={ratio_max:.0f}x"),
        ("fig8.peak_with_early_release", 0.0,
         f"custom={peak_c / 2**20:.1f}MiB;naive={peak_n / 2**20:.1f}MiB;"
         f"saving={100 * (peak_n - peak_c) / peak_n:.1f}%"),
    ]
