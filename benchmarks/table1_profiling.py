"""Paper Table 1: profiling overhead.

Baseline iteration vs (a) Lightweight mode (token-stream record + stage
machine), (b) Detailed mode (full jaxpr walk + timeline), (c) the built-in
profiler analogue (jax.profiler device trace).  Paper numbers: +0.9%,
+34.6%, +219.7%.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.common.config import ChameleonConfig, TrainConfig
from repro.core import tokenizer
from repro.core.memtrace import build_timeline
from repro.core.profiler import profile_jaxpr
from repro.core.stages import StageMachine
from repro.distributed.steps import make_grad_step
from repro.models.registry import get_api
from repro.optim.adamw import adamw_init

from benchmarks.common import Row, time_call


def run(iters: int = 5):
    cfg = C.get_reduced("llama2_paper").replace(num_layers=8)
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((4, 256), jnp.int32),
             "labels": jnp.ones((4, 256), jnp.int32)}
    step = jax.jit(make_grad_step(cfg, TrainConfig()))
    args = (params, batch, jnp.float32(1.0))

    base = time_call(step, *args, iters=iters)

    # (a) Lightweight: cached token stream + incremental signature + stage
    # machine — the runtime's actual steady-state path (record_dispatch
    # serves the cached TokenStream, the accumulator sees an unchanged
    # content hash and the stage machine short-circuits to (0, 1))
    traced = step.trace(*args)
    stream = tokenizer.tokenize_jaxpr_stream(traced.jaxpr)
    sm = StageMachine(ChameleonConfig())
    acc = tokenizer.SignatureAccumulator()

    def light():
        out = step(*args)
        sm.observe(acc.update([stream]))
        return out

    t_light = time_call(light, iters=iters)

    # bookkeeping-only old-vs-new (the full-step percentage above is
    # noise-dominated on CPU; this isolates the monitoring cost the
    # incremental signature removed — re-concat + re-bincount per iter)
    sm_old = StageMachine(ChameleonConfig())
    toks = stream.tokens

    def book_old():
        sm_old.observe(tokenizer.sequence_signature([toks]))

    def book_new():
        sm.observe(acc.update([stream]))

    t_book_old = time_call(book_old, iters=max(50, iters * 10))
    t_book_new = time_call(book_new, iters=max(50, iters * 10))

    # (b) Detailed: full jaxpr walk + memory timeline every iteration
    cj = jax.make_jaxpr(make_grad_step(cfg, TrainConfig()))(*args)

    def detailed():
        out = step(*args)
        prof = profile_jaxpr(cj, t_iter=base)
        build_timeline(prof)
        return out

    t_detail = time_call(detailed, iters=max(3, iters // 2))

    # (c) built-in profiler analogue: full device trace per iteration
    tdir = tempfile.mkdtemp()

    def builtin():
        with jax.profiler.trace(tdir):
            out = step(*args)
            jax.block_until_ready(out)
        return out

    t_builtin = time_call(builtin, iters=3, warmup=1)

    def pct(t):
        # CPU timer noise can make sub-ms overheads slightly negative
        return max(100.0 * (t - base) / base, 0.0)

    red = (100 * (pct(t_builtin) - pct(t_detail)) / pct(t_builtin)
           if pct(t_builtin) > 0.5 else float("nan"))
    return [
        ("table1.baseline", base, "overhead=0%"),
        ("table1.lightweight", t_light,
         f"overhead={pct(t_light):.1f}% (paper:0.9%)"),
        ("table1.lightweight_bookkeeping", t_book_new,
         f"old={t_book_old * 1e6:.1f}us "
         f"speedup={t_book_old / max(t_book_new, 1e-12):.1f}x"),
        ("table1.detailed", t_detail,
         f"overhead={pct(t_detail):.1f}% (paper:34.6%)"),
        ("table1.builtin_profiler", t_builtin,
         f"overhead={pct(t_builtin):.1f}% (paper:219.7%)"),
        ("table1.reduction_vs_builtin", t_detail,
         f"reduction={red:.1f}% (paper:84.25%)"),
    ]
