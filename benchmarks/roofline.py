"""§Roofline: the three-term roofline per (arch × shape × mesh) cell from
the dry-run artifacts (artifacts/dryrun/*.json — produced by
``python -m repro.launch.dryrun --all``)."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run(iters: int = 1):
    rows = []
    files = sorted(glob.glob(os.path.join(ART, "*__none.json")))
    if not files:
        return [("roofline.no_artifacts", 0.0,
                 "run `python -m repro.launch.dryrun --all` first")]
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        name = f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        rows.append((name, r["step_time_bound_s"],
                     f"bottleneck={r['bottleneck']};"
                     f"compute={r['compute_s'] * 1e3:.1f}ms;"
                     f"mem={r['memory_s'] * 1e3:.1f}ms;"
                     f"coll={r['collective_s'] * 1e3:.1f}ms;"
                     f"mfu_bound={r['mfu_bound']:.3f};"
                     f"useful_flops={r['useful_flops_ratio']:.2f}"))
    return rows
