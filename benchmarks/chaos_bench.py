"""Chaos drills for the swap path (ISSUE 8) — the §6.3 "training never
crashes" gate, run as a benchmark so the evidence carries numbers.

Each scenario pairs a fault-free reference run with an identically-seeded
chaos run on reduced llama2 (HBM budget squeezed so policy swaps carry
real engine traffic) and asserts three things:

  * **no crash** — the chaos run completes every step with an empty
    failure list, whatever the armed ``FaultPlan`` throws at it;
  * **bit-exact loss** — recovery is by retry / retain-in-HBM / sync
    fallback, never by dropping or re-deriving tensor data, so the loss
    trajectory matches the reference float-for-float;
  * **bounded T_iter inflation** — degradation trades bandwidth for
    safety, not throughput collapse: the chaos run's median step time
    stays within ``INFLATION_CAP``x the reference median.

The ``engine-window`` scenario additionally asserts the degradation
ladder *descended and recovered* (visible in the audit log), i.e. the
health FSM both reacted to the fault window and probed its way back to
the full rung after it closed.

CLI:

    PYTHONPATH=src python -m benchmarks.chaos_bench --fast       # CI gate
    PYTHONPATH=src python -m benchmarks.chaos_bench \
        --audit-out /tmp/chaos_audit.jsonl                       # nightly

``--fast`` runs the single highest-signal scenario at reduced length
(~1 min CPU); the full matrix adds seeded everywhere-chaos and the
store/checkpoint fault family.
"""
from __future__ import annotations

import argparse
import shutil
import statistics
import tempfile
from typing import List, Optional, Tuple

Row = Tuple[str, float, str]

# generous by design: reduced-config step times are sub-ms, so scheduler
# noise dominates — the cap only exists to catch pathological stalls
# (e.g. a retry storm serializing every iteration)
INFLATION_CAP = 5.0


def _train(steps: int, seed: int, plan=None, budget: int = 12 << 20,
           checkpoint_every: int = 0, persist_store: bool = False):
    """One reduced-llama2 run; returns (report, trainer-stats dict)."""
    import os

    import repro.configs as C
    from repro import faults
    from repro.common.config import (ChameleonConfig, PolicyStoreConfig,
                                     TrainConfig)
    from repro.data.synthetic import SyntheticTokens
    from repro.runtime.trainer import Trainer

    ckpt_dir = tempfile.mkdtemp(prefix="chaos_bench_")
    cfg = C.get_reduced("llama2_paper")
    tcfg = TrainConfig(steps=steps, checkpoint_every=checkpoint_every,
                       checkpoint_dir=ckpt_dir, eval_every=0,
                       warmup_steps=2, learning_rate=1e-3, seed=seed)
    data = SyntheticTokens(cfg.vocab_size, 64, 4, seed=seed)
    ps = PolicyStoreConfig(dir=os.path.join(ckpt_dir, "policies")
                           if persist_store else "")
    tr = Trainer(cfg, tcfg,
                 ChameleonConfig(enabled=True, hbm_budget_bytes=budget,
                                 policystore=ps),
                 data=data)
    try:
        if plan is not None:
            faults.arm(plan)
        rep = tr.train(steps)
        eng = tr.rt.hostmem.engine
        lad = tr.rt.ladder
        stats = {
            "fired": plan.total_fired() if plan is not None else 0,
            "retries": eng.n_retries,
            "failed_out": eng.n_failed_out,
            "hbm_fallback_in": eng.n_hbm_fallback_in,
            "sync_fallback_in": eng.n_sync_fallback_in,
            "worst_health": eng.health.worst(),
            "descents": lad.n_descents if lad else 0,
            "ascents": lad.n_ascents if lad else 0,
            "rung": lad.name if lad else "full",
            "live_blocks": eng.pool.live_blocks,
        }
        eng.pool.check()
        return rep, stats
    finally:
        faults.disarm()
        tr.rt.close()
        shutil.rmtree(ckpt_dir, ignore_errors=True)


def _compare(name: str, steps: int, seed: int, plan,
             require_ladder: bool = False, **train_kw) -> Row:
    ref, _ = _train(steps, seed, **train_kw)
    rep, st = _train(steps, seed, plan=plan, **train_kw)

    assert not rep.failures, f"{name}: chaos run crashed: {rep.failures}"
    assert st["fired"] > 0, f"{name}: fault plan never fired"
    n_diff = sum(1 for a, b in zip(ref.losses, rep.losses) if a != b)
    assert len(rep.losses) == len(ref.losses) and n_diff == 0, \
        f"{name}: loss diverged under faults (n_diff={n_diff})"
    assert st["live_blocks"] == 0, f"{name}: leaked staging slabs"

    t_ref = statistics.median(ref.wall_times)
    t_chaos = statistics.median(rep.wall_times)
    inflation = t_chaos / t_ref if t_ref > 0 else 1.0
    assert inflation <= INFLATION_CAP, \
        f"{name}: T_iter inflated {inflation:.2f}x (cap {INFLATION_CAP}x)"

    if require_ladder:
        assert st["descents"] >= 1, f"{name}: ladder never descended"
        assert st["ascents"] >= 1, \
            f"{name}: ladder never recovered (rung={st['rung']})"
        assert st["worst_health"] == "healthy", \
            f"{name}: health stuck at {st['worst_health']}"

    derived = (f"bit_exact=True fired={st['fired']} "
               f"retries={st['retries']} retained={st['failed_out']} "
               f"descents={st['descents']} ascents={st['ascents']} "
               f"inflation={inflation:.2f}x")
    return (f"chaos.{name}", t_chaos, derived)


def _scenarios(fast: bool, seed: int):
    from repro.faults import FaultPlan, FaultSpec
    # recovery needs post-window headroom: probes fire every 8 iterations
    # and each ascent holds 2, so climbing no_swap -> full takes ~25 steps
    steps = 48 if fast else 60
    win = dict(start=steps // 4, stop=steps // 4 + 10)
    yield ("engine_window", steps,
           FaultPlan([FaultSpec("engine.transfer_error", prob=1.0, **win)],
                     seed=seed),
           True, {})   # the window is long enough to demand ladder motion
    if fast:
        return
    yield ("everywhere", steps,
           FaultPlan.everywhere(seed=seed, prob=0.05, seconds=0.002),
           False, {})  # low-rate scatter may not push past degrade_score
    yield ("drop_and_stall", steps,
           FaultPlan([FaultSpec("engine.transfer_drop", prob=0.3, **win),
                      FaultSpec("engine.transfer_stall", prob=0.2,
                                seconds=0.002, **win)], seed=seed),
           False, {})
    # the storage family needs the storage paths live: checkpoint cadence
    # for ckpt.write, an on-disk policy store for store.put
    yield ("storage", steps,
           FaultPlan([FaultSpec("store.put", prob=0.5),
                      FaultSpec("store.load", prob=0.5),
                      FaultSpec("ckpt.write", prob=0.5, max_fires=2)],
                     seed=seed),
           False, {"checkpoint_every": steps // 3, "persist_store": True})


def run(iters: int = 3, fast: bool = True, seed: int = 0) -> List[Row]:
    rows: List[Row] = []
    for name, steps, plan, need_ladder, kw in _scenarios(fast, seed):
        rows.append(_compare(name, steps, seed, plan,
                             require_ladder=need_ladder, **kw))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fast", action="store_true",
                    help="single-scenario CI gate (~1 min CPU)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--audit-out", default="",
                    help="stream the audit log (JSONL) here — the "
                         "nightly evidence artifact")
    args = ap.parse_args(argv)

    if args.audit_out:
        from repro import obs
        obs.audit().attach_file(args.audit_out)

    print("name,us_per_call,derived")
    for name, sec, derived in run(fast=args.fast, seed=args.seed):
        print(f"{name},{sec * 1e6:.1f},{derived}")
    print("chaos gate: OK (no crash, bit-exact loss, bounded inflation)")


if __name__ == "__main__":
    main()
