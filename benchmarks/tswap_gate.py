"""t_swap prediction-error gate: autotuned pricing must not be worse.

Compares the memory ledger's predicted-vs-realized peak scoreboard
(``mean_abs_error``) between two training runs' ``--stats-json`` dumps —
a baseline (bandwidth-only Eq-3 pricing) and a ``--autotune`` run (link
efficiency derates the constant, tuned kernels on the spill path).  The
gate passes when the tuned run's mean absolute peak error is no worse
than the baseline's plus a small tolerance; nightly runs both and fails
the job if efficiency-priced ``t_swap`` regresses prediction accuracy.

    python -m benchmarks.tswap_gate baseline.json tuned.json [--tol 0.02]
"""
from __future__ import annotations

import argparse
import json
import sys


def scoreboard_error(stats_path: str):
    """``mean_abs_error`` (and n) out of one --stats-json dump."""
    with open(stats_path) as f:
        snap = json.load(f)
    sb = (snap.get("runtime", {}).get("obs", {})
          .get("memory", {}).get("scoreboard") or {})
    return sb.get("mean_abs_error"), sb.get("n", 0)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="--stats-json of the baseline run")
    ap.add_argument("tuned", help="--stats-json of the --autotune run")
    ap.add_argument("--tol", type=float, default=0.02,
                    help="allowed absolute regression in mean |peak "
                         "error| (fraction of projected peak)")
    args = ap.parse_args(argv)

    base_err, base_n = scoreboard_error(args.baseline)
    tuned_err, tuned_n = scoreboard_error(args.tuned)
    print(f"baseline: mean |peak error| = {base_err} over {base_n} "
          f"scored iterations")
    print(f"tuned:    mean |peak error| = {tuned_err} over {tuned_n} "
          f"scored iterations")
    if base_err is None or tuned_err is None:
        # a run with no scored iterations can't regress anything — don't
        # turn a config hiccup into a false red
        print("tswap_gate: SKIP (a run has no scored iterations)")
        return 0
    if tuned_err <= base_err + args.tol:
        print(f"tswap_gate: PASS (delta {tuned_err - base_err:+.4f} "
              f"<= tol {args.tol})")
        return 0
    print(f"tswap_gate: FAIL (tuned regressed by "
          f"{tuned_err - base_err:+.4f} > tol {args.tol})")
    return 1


if __name__ == "__main__":
    sys.exit(main())
