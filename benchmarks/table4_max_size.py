"""Paper Table 4 / Fig 6: maximum trainable model per scaling dimension
under a fixed HBM budget — PyTorch-analogue baseline vs Chameleon.

For each dimension (batch, seq, hidden, layers) we grow the dimension and
evaluate the reconstructed no-swap peak vs the Chameleon-projected peak
(Algo 2 on the same profile).  Budget is an emulated 1.5 GiB device.
Paper ratios: batch 4x, seq 4x, hidden 1.24x, layers 1.83x.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.common.config import ChameleonConfig, TrainConfig
from repro.core.memtrace import build_timeline
from repro.core.policy import ChameleonOOMError, generate_policy
from repro.core.profiler import profile_jaxpr
from repro.core.executor import Executor
from repro.distributed.steps import make_grad_step
from repro.models.registry import get_api

BUDGET = int(1.5 * 2 ** 30)


def _peaks(cfg, B, S):
    api = get_api(cfg)
    params_sds = jax.eval_shape(lambda k: api.init(cfg, k)[0],
                                jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    step = make_grad_step(cfg, TrainConfig(),
                          Executor(ChameleonConfig()).baseline().to_jax())
    cj = jax.make_jaxpr(step)(params_sds, batch,
                              jax.ShapeDtypeStruct((), jnp.float32))
    prof = profile_jaxpr(cj, t_iter=10.0)
    tl = build_timeline(prof)
    if tl.peak <= BUDGET:
        return tl.peak, tl.peak
    try:
        pol = generate_policy(prof, ChameleonConfig(), BUDGET, timeline=tl)
        return tl.peak, pol.projected_peak
    except ChameleonOOMError:
        return tl.peak, tl.peak  # swap can't fix it


def _max_dim(base_cfg, B0, S0, dim, values):
    """Largest value whose (baseline, chameleon) peak fits the budget."""
    best_base = best_cham = None
    for v in values:
        cfg, B, S = base_cfg, B0, S0
        if dim == "batch":
            B = v
        elif dim == "seq":
            S = v
        elif dim == "hidden":
            cfg = base_cfg.replace(d_model=v, num_heads=max(2, v // 32),
                                   num_kv_heads=max(2, v // 32), head_dim=32,
                                   d_ff=int(v * 2.7) // 8 * 8)
        elif dim == "layers":
            cfg = base_cfg.replace(num_layers=v)
        base_peak, cham_peak = _peaks(cfg, B, S)
        if base_peak <= BUDGET:
            best_base = v
        if cham_peak <= BUDGET:
            best_cham = v
        if cham_peak > BUDGET:
            break
    return best_base, best_cham


def run(iters: int = 1):
    # deep-and-narrow toy llama: activations dominate the floor the way
    # they do at the paper's scale (batch/seq sweeps), shallower for the
    # width/depth sweeps to keep CPU profiling time sane
    deep = C.get_reduced("llama2_paper").replace(
        num_layers=16, d_model=256, num_heads=8, num_kv_heads=8,
        head_dim=32, d_ff=688, vocab_size=2048)
    shallow = deep.replace(num_layers=5)
    rows = []
    sweeps = {
        "batch": (deep, 4, 512,
                  [4, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256]),
        "seq": (deep, 4, 512,
                [512, 1024, 2048, 3072, 4096, 6144, 8192, 12288, 16384]),
        "hidden": (deep, 4, 512,
                   [256, 320, 384, 448, 512, 640, 768, 896, 1024]),
        "layers": (shallow, 4, 512,
                   [5, 7, 9, 11, 14, 17, 21, 26, 32, 40, 50, 64, 80]),
    }
    for dim, (cfg, B0, S0, values) in sweeps.items():
        if dim == "batch":
            B0 = values[0]
        if dim == "seq":
            S0 = values[0]
        bb, bc = _max_dim(cfg, B0, S0, dim, values)
        ratio = (bc / bb) if (bb and bc) else float("nan")
        paper = {"batch": 4.0, "seq": 4.0, "hidden": 1.24,
                 "layers": 1.83}[dim]
        rows.append((f"table4.max_{dim}", 0.0,
                     f"baseline={bb};chameleon={bc};ratio={ratio:.2f}x"
                     f" (paper:{paper}x)"))
    return rows
