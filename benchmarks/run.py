"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import emit

MODULES = [
    "table1_profiling",
    "monitor_bench",
    "fig4_grouping",
    "table2_perf_benefit",
    "table4_max_size",
    "fig7_stability",
    "fig8_reuse_interval",
    "hostmem_bench",
    "adapt_bench",
    "serving_bench",
    "kernels_bench",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if args.only and not any(s in mod_name
                                 for s in args.only.split(",")):
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            emit(mod.run(iters=args.iters))
        except Exception as e:  # noqa: BLE001
            failed.append(mod_name)
            print(f"{mod_name}.ERROR,0,{e!r}", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark failures: {failed}")


if __name__ == "__main__":
    main()
