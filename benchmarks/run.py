"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` additionally
writes a machine-readable ``BENCH.json`` (schema-versioned headline
numbers per bench) so nightly runs leave a diffable perf trajectory."""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks.common import emit

BENCH_SCHEMA_VERSION = 1

MODULES = [
    "table1_profiling",
    "monitor_bench",
    "fig4_grouping",
    "table2_perf_benefit",
    "table4_max_size",
    "fig7_stability",
    "fig8_reuse_interval",
    "hostmem_bench",
    "adapt_bench",
    "serving_bench",
    "kernels_bench",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also write the results as BENCH.json here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    results = []
    for mod_name in MODULES:
        if args.only and not any(s in mod_name
                                 for s in args.only.split(",")):
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run(iters=args.iters)
            emit(rows)
            results.append({
                "module": mod_name,
                "rows": [{"name": name, "us_per_call": sec * 1e6,
                          "derived": derived}
                         for name, sec, derived in rows],
            })
        except Exception as e:  # noqa: BLE001
            failed.append(mod_name)
            print(f"{mod_name}.ERROR,0,{e!r}", file=sys.stderr)
            traceback.print_exc()
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"schema_version": BENCH_SCHEMA_VERSION,
                       "iters": args.iters,
                       "benches": results,
                       "failed": failed}, f, indent=1)
        print(f"wrote {args.json_out}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark failures: {failed}")


if __name__ == "__main__":
    main()
