"""Paper Fig 4: CV of per-group execution time + Eq-1 estimation error vs
number of groups.

Real per-operator wall times measured by evaluating the layer jaxpr
equation-by-equation on CPU (primitive bind + block_until_ready) — the
op stream of L identical transformer layers, exactly the structure the
paper's insight rests on.  Expected: CV -> small and Eq-1 error -> small
once groups <= layer count.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models import transformer as T
from repro.models.registry import get_api


_SKIP = {"name"}


def _per_op_times(cfg, params, x, positions, repeats_per_layer: int):
    """Eval one dense block eqn-by-eqn with timing; replicate L times."""
    lp = jax.tree.map(lambda t: t[0], params["blocks"])

    def one_layer(x):
        out, _ = T.dense_block(cfg, lp, x, positions)
        return out

    cj = jax.make_jaxpr(one_layer)(x)
    consts = cj.consts
    env = {}

    def read(v):
        if hasattr(v, "val"):
            return v.val
        return env[v]

    j = cj.jaxpr
    for cv, c in zip(j.constvars, consts):
        env[cv] = c
    env[j.invars[0]] = x

    times: List[float] = []
    for eqn in j.eqns:
        invals = [read(v) for v in eqn.invars]
        t0 = time.perf_counter()
        out = eqn.primitive.bind(*invals, **eqn.params)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        outs = out if eqn.primitive.multiple_results else [out]
        for ov, o in zip(eqn.outvars, outs):
            env[ov] = o
        if eqn.primitive.name not in _SKIP:
            times.append(dt)
    return np.asarray(times * repeats_per_layer)


def run(iters: int = 1):
    cfg = C.get_reduced("llama2_paper").replace(num_layers=32,
                                                attn_impl="dense")
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 128
    x = jnp.asarray(np.random.RandomState(0).randn(B, S, cfg.d_model),
                    jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    times = _per_op_times(cfg, params, x, positions,
                          repeats_per_layer=cfg.num_layers)
    total = times.sum()
    n_ops = len(times)
    rows = []
    for groups in (256, 128, 64, 32, 16, 8):
        splits = np.array_split(times, groups)
        sums = np.asarray([s.sum() for s in splits])
        cv = sums.std() / sums.mean()
        # Eq 1: T̄_group = T_iter/N_iter × N_group
        est = np.asarray([total / n_ops * len(s) for s in splits])
        err = np.abs(est - sums) / np.maximum(sums, 1e-12)
        rows.append((f"fig4.groups_{groups}", float(sums.mean()),
                     f"cv={cv:.3f};eq1_err={np.median(err) * 100:.1f}%"))
    return rows
