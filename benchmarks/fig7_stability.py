"""Paper Fig 7: long-term stability under operator-sequence changes.

Mini-scale run: Chameleon-enabled training with on-the-fly validation
(sequence extension) and loss-scale dynamics vs the full-recompute baseline
(the paper's comparator).  Derived: max |loss difference| — the curves must
overlap (swap changes no math), and the run must complete with stage
transitions but zero failures (Capuchin analogue crashes at the first
validation)."""
from __future__ import annotations

import shutil
import tempfile

import numpy as np

import repro.configs as C
from repro.common.config import ChameleonConfig, TrainConfig
from repro.data.synthetic import SyntheticTokens
from repro.runtime.trainer import Trainer


def run(iters: int = 1):
    cfg = C.get_reduced("llama2_paper")
    steps = 40
    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        def make(cham, ckdir):
            tcfg = TrainConfig(steps=steps, checkpoint_every=0,
                               checkpoint_dir=ckdir, eval_every=13,
                               warmup_steps=2, learning_rate=1e-3)
            data = SyntheticTokens(cfg.vocab_size, 64, 4, seed=11)
            return Trainer(cfg, tcfg,
                           ChameleonConfig(enabled=cham,
                                           hbm_budget_bytes=20 << 20),
                           data=data)

        tr = make(True, d1)
        rep = tr.train(steps)
        base = make(False, d2)
        rep2 = base.train(steps)
        diff = float(np.max(np.abs(np.asarray(rep.losses)
                                   - np.asarray(rep2.losses))))
        n_trans = len(tr.rt.machine.transitions)
        t_step = float(np.median(rep.times[5:]))
        return [
            ("fig7.chameleon_run", t_step,
             f"steps={steps};failures={len(rep.failures)};"
             f"stage_transitions={n_trans}"),
            ("fig7.loss_curve_divergence", t_step,
             f"max_abs_diff={diff:.2e} (paper: curves overlap)"),
        ]
    finally:
        shutil.rmtree(d1, ignore_errors=True)
        shutil.rmtree(d2, ignore_errors=True)
