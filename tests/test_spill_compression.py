"""int8 KV-spill compression (HostMemConfig.spill_compression): staged
bytes shrink 2-4x, the round trip stays within quantization tolerance,
and lifetime rules (consume-on-restore, idempotent discard) carry over
from the raw path."""
import jax
import numpy as np
import pytest

import repro.configs as C
from repro.common.config import HostMemConfig
from repro.hostmem import HostMemTier
from repro.hostmem.kvspill import KVSpillManager
from repro.models.registry import get_api
from repro.runtime.server import Server


@pytest.fixture(scope="module")
def llama_serve():
    cfg = C.get_reduced("llama2_paper")
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _int8_tier():
    return HostMemTier(HostMemConfig(spill_compression="int8",
                                     spill_compress_min_bytes=1))


def test_unknown_compression_rejected():
    tier = HostMemTier()
    with pytest.raises(ValueError, match="spill compression"):
        KVSpillManager(tier.pool, tier.engine, compression="zstd")


def test_int8_roundtrip_within_tolerance(llama_serve):
    cfg, params = llama_serve
    srv = Server(cfg, params, max_batch=2, max_len=32)
    tier = _int8_tier()
    srv.submit(np.arange(5, dtype=np.int32), max_new_tokens=30)
    srv.submit(np.arange(7, dtype=np.int32), max_new_tokens=30)
    srv.tick()
    before_k = np.asarray(srv.state.attn_k[:, 0], np.float32).copy()
    before_pos = int(srv.state.pos[0])

    sp = tier.kvspill.spill(srv.state, 0, tag="req-a")
    ks = tier.kvspill.stats()
    assert ks["compression"] == "int8"
    assert ks["bytes_spilled"] < ks["bytes_raw"]   # payload really shrank
    assert ks["compression_ratio"] > 1.5
    assert any(fs.kind == "int8" for fs in sp.layout)

    srv.state = srv.state._replace(
        attn_k=srv.state.attn_k.at[:, 0].set(0),
        pos=srv.state.pos.at[0].set(0))
    srv.state = tier.kvspill.restore(srv.state, sp, 0)
    after_k = np.asarray(srv.state.attn_k[:, 0], np.float32)
    # row-wise symmetric int8: error bounded by scale/2 = absmax/254 per row
    tol = np.abs(before_k).max() / 100.0 + 1e-6
    np.testing.assert_allclose(after_k, before_k, atol=tol)
    assert int(srv.state.pos[0]) == before_pos     # pos is metadata: exact
    assert tier.pool.bytes_in_use == 0


def test_int8_discard_is_idempotent(llama_serve):
    cfg, params = llama_serve
    srv = Server(cfg, params, max_batch=1, max_len=32)
    tier = _int8_tier()
    srv.submit(np.arange(6, dtype=np.int32), max_new_tokens=10)
    srv.tick()
    sp = tier.kvspill.spill(srv.state, 0, tag="cancelled")
    tier.kvspill.discard(sp)
    tier.kvspill.discard(sp)                       # no double free
    assert tier.kvspill.n_discards == 1
    assert tier.pool.bytes_in_use == 0


def test_small_fields_stay_raw(llama_serve):
    """Rows under the compression floor ship raw (quantizing tiny rows
    costs more than it saves)."""
    cfg, params = llama_serve
    srv = Server(cfg, params, max_batch=1, max_len=32)
    tier = HostMemTier(HostMemConfig(spill_compression="int8",
                                     spill_compress_min_bytes=1 << 30))
    srv.submit(np.arange(6, dtype=np.int32), max_new_tokens=10)
    srv.tick()
    before_k = np.asarray(srv.state.attn_k[:, 0]).copy()
    sp = tier.kvspill.spill(srv.state, 0, tag="raw")
    assert all(fs.kind == "raw" for fs in sp.layout)
    srv.state = tier.kvspill.restore(srv.state, sp, 0)
    np.testing.assert_array_equal(np.asarray(srv.state.attn_k[:, 0]),
                                  before_k)        # raw path stays bit-exact


def test_oversubscribed_int8_server_completes(llama_serve):
    """Over-subscription with compressed spill still completes every
    request (decode is lossy-tolerant; outputs may legally differ from the
    resident baseline)."""
    cfg, params = llama_serve
    tier = _int8_tier()
    srv = Server(cfg, params, max_batch=2, max_len=48, max_active=4,
                 hostmem=tier)
    rng = np.random.RandomState(0)
    rids = [srv.submit(rng.randint(0, cfg.vocab_size, size=6),
                       max_new_tokens=5) for _ in range(4)]
    out = srv.run_until_done(max_ticks=400)
    assert sorted(out) == sorted(rids)
    assert all(len(v) == 5 for v in out.values())
    assert srv.n_preemptions > 0
    assert tier.kvspill.stats()["compression_ratio"] > 1.5
    assert tier.pool.bytes_in_use == 0
