"""WarmUp-stage OOM handling (Algo 3, compile-time form) + sites module."""
import numpy as np
import pytest

from repro.common.config import ChameleonConfig
from repro.core.memtrace import build_timeline
from repro.core.oom import passive_swap_fit, warmup_offload_sites
from repro.core.policy import ChameleonOOMError
from repro.core.profiler import ProfileData, TensorInstance
from repro.core.sites import OFFLOAD_SITES, SITE_INDEX, base_site, site_prefix, tag

from tests.test_simulator_policy import synth_profile


def test_passive_swap_reaches_budget():
    prof = synth_profile(n_layers=10)
    tl = build_timeline(prof)
    budget = int(tl.peak * 0.5)
    absent, peak, order = passive_swap_fit(prof, ChameleonConfig(), budget)
    assert peak <= budget
    assert len(absent) >= 1
    assert all(t.uid in absent for t in order)


def test_passive_swap_closest_size_rule():
    """Algo 3 line 9: pick the tensor whose size is closest to the deficit."""
    n_ops = 100
    tensors = [
        TensorInstance(0, 100, 10, 90, site="resid_post", layer=0),
        TensorInstance(1, 55, 10, 90, site="resid_post", layer=1),
        TensorInstance(2, 300, 10, 90, site="resid_post", layer=2),
    ]
    prof = ProfileData(np.zeros(n_ops, np.int32), tensors, 1.0, 0)
    # peak 455, budget 400 -> deficit 55 -> must pick uid=1 first
    absent, peak, order = passive_swap_fit(prof, ChameleonConfig(), 400)
    assert order[0].uid == 1
    assert peak <= 400


def test_passive_swap_raises_when_impossible():
    prof = synth_profile(n_layers=2)
    prof.tensors.append(TensorInstance(99, 10 << 30, 0, prof.n_ops))
    with pytest.raises(ChameleonOOMError):
        passive_swap_fit(prof, ChameleonConfig(), 1 << 20)


def test_warmup_offload_sites():
    prof = synth_profile(n_layers=8)
    tl = build_timeline(prof)
    sites = warmup_offload_sites(prof, ChameleonConfig(), int(tl.peak * 0.5))
    assert sites == {"resid_post"}


# ------------------------------------------------------------------- sites
def test_site_vocabulary_unique():
    assert len(OFFLOAD_SITES) == len(set(OFFLOAD_SITES))
    assert all(SITE_INDEX[s] == i for i, s in enumerate(OFFLOAD_SITES))


def test_tag_rejects_unknown_site():
    import jax.numpy as jnp
    with pytest.raises(AssertionError):
        tag(jnp.ones(3), "not_a_site")


def test_site_prefix_and_base():
    import jax
    import jax.numpy as jnp

    def f(x):
        with site_prefix("l3/"):
            return tag(x, "ffn_pre")

    cj = jax.make_jaxpr(f)(jnp.ones(4))
    names = [e.params["name"] for e in cj.jaxpr.eqns
             if e.primitive.name == "name"]
    assert names == ["l3/ffn_pre"]
    assert base_site("l3/ffn_pre") == "ffn_pre"
    assert base_site("ffn_pre") == "ffn_pre"
