"""Roofline autotuner (repro.kernels.autotune): cache persistence and
corruption safety, warm-restart zero re-measurement, variant parity
against the kernel references, priced spill compression, sustained-
contention pricing, and the efficiency-derated Eq-3 fallback."""
import json
import os
import typing

import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import AutotuneConfig, ChameleonConfig, HostMemConfig
from repro.hostmem import HostMemTier
from repro.hostmem.bwmodel import BandwidthModel
from repro.hostmem.engine import TC_CHECKPOINT, TC_KV_SPILL, TC_POLICY_SWAP
from repro.kernels.autotune import table as T
from repro.kernels.autotune.advisor import (COMPRESS_INT8, COMPRESS_RAW,
                                            CompressionAdvisor)
from repro.kernels.autotune.cache import (CACHE_FILENAME, SCHEMA_VERSION,
                                          AutotuneCache, cache_key)
from repro.kernels.autotune.device import (DEFAULT_DEVICE_KIND, DEVICE_SPECS,
                                           get_device_spec)
from repro.kernels.autotune.space import SPACES
from repro.kernels.autotune.tuner import HOST_LINK_KERNEL, Autotuner


@pytest.fixture(autouse=True)
def _clean_table():
    """Every test starts and ends with an empty process-wide table."""
    T.clear()
    yield
    T.clear()


# ---------------------------------------------------------- device spec
def test_device_spec_registry():
    spec = get_device_spec()
    assert spec.kind == DEFAULT_DEVICE_KIND
    assert spec.hbm_bw > 0 and spec.host_bw > 0
    assert set(DEVICE_SPECS) >= {"tpu_v5e", "tpu_v5p", "tpu_v4", "cpu"}
    unknown = get_device_spec("tpu_v9x")
    assert unknown.kind == "tpu_v9x"              # asked-for name kept
    assert unknown.hbm_bw == DEVICE_SPECS["tpu_v5e"].hbm_bw
    d = spec.to_dict()
    assert d["kind"] == spec.kind and d["hbm_bw"] == spec.hbm_bw


def test_roofline_uses_device_spec():
    from repro.launch import roofline
    spec = get_device_spec()
    assert roofline.PEAK_FLOPS == spec.peak_flops
    assert roofline.HBM_BW == spec.hbm_bw


# ------------------------------------------------------- keys / buckets
def test_shape_bucket_pow2_rounding():
    assert T.shape_bucket((1000, 900)) == "1024x1024"
    assert T.shape_bucket((1024, 1024)) == "1024x1024"
    assert T.shape_bucket((1025, 1)) == "2048x1"


def test_dtype_name_normalization():
    assert T.dtype_name(np.float32) == "float32"
    assert T.dtype_name(np.dtype(np.float32)) == "float32"
    assert T.dtype_name(jnp.zeros((1,), jnp.bfloat16).dtype) == "bfloat16"
    assert (T.table_key("quantize", (1000, 900), np.float32)
            == T.table_key("quantize", (1024, 1024),
                           jnp.zeros((1,), jnp.float32).dtype))


# ----------------------------------------------------- cache round-trip
def _entry(block_rows=128, bps=1e9):
    return {"config": {"block_rows": block_rows}, "achieved_bps": bps,
            "measured_s": 0.001, "bytes_moved": 1 << 20,
            "efficiency": 0.5, "shape": [1024, 1024]}


def test_cache_roundtrip(tmp_path):
    cache = AutotuneCache(str(tmp_path))
    cache.put("quantize", (1024, 1024), np.float32, _entry())
    cache.bwmodel = BandwidthModel(32.0, link_efficiency=0.7).to_dict()
    path = cache.save()
    assert path and os.path.exists(path)
    assert not os.path.exists(path + ".tmp")      # atomic write cleaned up
    loaded = AutotuneCache.load(str(tmp_path))
    assert loaded.entries == cache.entries
    assert loaded.bwmodel["link_efficiency"] == pytest.approx(0.7)
    assert loaded.load_errors == 0
    # bucketed hit/miss
    assert loaded.get("quantize", (1000, 900), np.float32) is not None
    assert loaded.get("quantize", (2048, 1024), np.float32) is None
    assert loaded.get("quantize", (1024, 1024), np.int8) is None


def test_cache_missing_dir_is_empty(tmp_path):
    cache = AutotuneCache.load(str(tmp_path / "nowhere"))
    assert cache.entries == {} and cache.load_errors == 0


@pytest.mark.parametrize("payload", [
    "{garbage",                                    # truncated / not JSON
    json.dumps({"schema_version": 99, "entries": {}}),
    json.dumps({"schema_version": SCHEMA_VERSION, "entries": [1, 2]}),
])
def test_cache_corruption_safe_load(tmp_path, payload):
    (tmp_path / CACHE_FILENAME).write_text(payload)
    cache = AutotuneCache.load(str(tmp_path))
    assert cache.entries == {}
    assert cache.load_errors == 1


def test_cache_malformed_entries_skipped_individually(tmp_path):
    good_key = cache_key("quantize", (1024, 1024), np.float32, "tpu_v5e")
    payload = {"schema_version": SCHEMA_VERSION,
               "entries": {good_key: _entry(),
                           "bad-key": _entry(),
                           "a|b|c|d": "not-a-dict",
                           "e|f|g|h": {"no_config": True}}}
    (tmp_path / CACHE_FILENAME).write_text(json.dumps(payload))
    cache = AutotuneCache.load(str(tmp_path))
    assert list(cache.entries) == [good_key]
    assert cache.load_errors == 3


def test_table_entries_drop_other_devices():
    cache = AutotuneCache(device_kind="tpu_v5e")
    cache.put("quantize", (1024, 1024), np.float32, _entry(128))
    cache.entries[cache_key("quantize", (1024, 1024), np.float32,
                            "tpu_v4")] = _entry(64)
    entries = cache.table_entries()
    assert list(entries.values()) == [{"block_rows": 128}]


# ----------------------------------------------- tuner counters / cache
def test_tuner_measures_all_variants_once():
    tuner = Autotuner(measure=lambda fn: 0.01)
    cfg = tuner.tune("quantize")
    assert cfg in list(SPACES["quantize"].variants)
    assert tuner.n_measured == len(SPACES["quantize"].variants)
    assert tuner.n_cache_hits == 0
    # same bucket: answered from cache, zero new measurements
    again = tuner.tune("quantize", shape=(1000, 900))
    assert again == cfg
    assert tuner.n_measured == len(SPACES["quantize"].variants)
    assert tuner.n_cache_hits == 1


def test_warm_restart_zero_remeasurement(tmp_path):
    t1 = Autotuner(cache=AutotuneCache(str(tmp_path)),
                   measure=lambda fn: 0.01)
    t1.tune_all(("quantize", "dequantize"))
    assert t1.n_measured > 0
    t1.cache.save()
    # cold process, warm directory
    t2 = Autotuner(cache=AutotuneCache.load(str(tmp_path)),
                   measure=lambda fn: pytest.fail("re-measured!"))
    t2.tune_all(("quantize", "dequantize"))
    assert t2.n_measured == 0
    assert t2.n_cache_hits == 2


def test_tuner_picks_fastest_variant():
    space = SPACES["quantize"]
    fast = dict(space.variants[2])                # not the default
    times = {i: (0.001 if dict(v) == fast else 0.01)
             for i, v in enumerate(space.variants)}
    it = iter(range(len(space.variants)))
    tuner = Autotuner(measure=lambda fn: times[next(it)])
    assert tuner.tune("quantize") == fast
    entry = tuner.cache.get("quantize", space.default_shape, np.float32)
    assert entry["achieved_bps"] == pytest.approx(
        space.bytes_moved(space.default_shape, np.dtype(np.float32)) / 0.001)
    assert 0.0 < entry["efficiency"] <= 1.0


# ------------------------------------------------------ variant parity
@pytest.mark.parametrize("kernel,shape", [
    ("quantize", (256, 64)),
    ("dequantize", (256, 64)),
    ("flash_attention", (1, 256, 2, 32)),
    ("ssd_scan", (1, 256, 2, 32)),
])
def test_every_variant_matches_reference(kernel, shape):
    """Tuning must never trade numerics for speed: every config in every
    search space reproduces the kernel's reference implementation."""
    space = SPACES[kernel]
    args = space.make_args(shape, np.dtype(np.float32))
    ref = space.ref(args)
    for config in space.variants:
        out = space.run(args, config)
        if kernel == "quantize":
            q, s = out
            qr, sr = ref
            diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
            assert diff.max() <= 1                # 1-quantum rounding flips
            assert (diff > 0).mean() < 0.01
            np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                                       rtol=1e-6)
        else:
            np.testing.assert_allclose(
                np.asarray(out, np.float32), np.asarray(ref, np.float32),
                rtol=2e-3, atol=2e-3)


# ------------------------------------------- table -> ops wrapper wiring
def test_ops_wrappers_consult_installed_table():
    from repro.kernels.quant_offload import ops as Q
    shape, dtype = (1024, 1024), np.dtype(np.float32)
    assert Q._tuned_block_rows("quantize", shape, dtype) == 256  # default
    T.install({T.table_key("quantize", shape, dtype): {"block_rows": 64}})
    assert Q._tuned_block_rows("quantize", shape, dtype) == 64
    x = jnp.asarray(np.random.RandomState(0).randn(100, 64), jnp.float32)
    q, s = Q.quantize(x)                          # ragged + tuned lookup
    assert q.shape == (100, 64) and s.shape == (100, 1)


def test_install_cache_roundtrip():
    from repro.kernels.autotune import install_cache
    cache = AutotuneCache()
    cache.put("ssd_scan", (1, 256, 4, 64), np.float32,
              {"config": {"chunk": 64}, "achieved_bps": 1e9})
    assert install_cache(cache) == 1
    assert T.tuned_config("ssd_scan", (1, 256, 4, 64),
                          np.float32) == {"chunk": 64}


# --------------------------------------------------- link efficiency
def test_link_efficiency_from_calibrated_model():
    bw = BandwidthModel(32.0)
    for size in (1 << 16, 1 << 20, 1 << 24):
        bw.observe(size, size / 16e9)             # measured 16 GB/s link
    tuner = Autotuner(measure=lambda fn: 0.01)
    eff = tuner.link_efficiency(bw)
    spec = tuner.spec
    assert eff == pytest.approx(16e9 / spec.host_bw, rel=0.05)
    stored = tuner.cache.entries[
        f"{HOST_LINK_KERNEL}|-|-|{tuner.cache.device_kind}"]
    assert stored["config"]["efficiency"] == pytest.approx(eff)
    # uncalibrated model + warm cache: reuse the stored value
    t2 = Autotuner(cache=tuner.cache, measure=lambda fn: 0.01)
    assert t2.link_efficiency(BandwidthModel(32.0)) == pytest.approx(eff)
    assert t2.n_cache_hits == 1
    # nothing stored and nothing calibrated: nominal link
    assert Autotuner(measure=lambda fn: 0.01).link_efficiency(None) == 1.0


def test_t_swap_derated_by_link_efficiency():
    from repro.core.simulator import Simulator
    prof = _toy_profile()
    cfg = ChameleonConfig(groups_per_phase=8)
    full = Simulator(prof, 50, cfg,
                     bwmodel=BandwidthModel(32.0, link_efficiency=1.0))
    half = Simulator(prof, 50, cfg,
                     bwmodel=BandwidthModel(32.0, link_efficiency=0.5))
    nbytes = 1 << 20
    assert half.t_swap(nbytes) == pytest.approx(2 * full.t_swap(nbytes))
    # a *calibrated* curve is already a measurement — never derated
    bw = BandwidthModel(32.0, link_efficiency=0.5)
    for size in (1 << 16, 1 << 20, 1 << 24):
        bw.observe(size, size / 16e9)
    cal = Simulator(prof, 50, cfg, bwmodel=bw)
    assert cal.t_swap(nbytes) == pytest.approx(bw.transfer_time(nbytes))


def test_link_efficiency_survives_snapshot_roundtrip():
    bw = BandwidthModel(32.0, link_efficiency=0.4)
    again = BandwidthModel.from_dict(bw.to_dict())
    assert again.link_efficiency == pytest.approx(0.4)
    assert BandwidthModel.from_dict(
        BandwidthModel(32.0).to_dict()).link_efficiency == 1.0


# ------------------------------------------------ compression advisor
def _skewed_cache(bps):
    cache = AutotuneCache()
    cache.put("quantize", (1024, 1024), np.float32,
              {"config": {"block_rows": 256}, "achieved_bps": bps})
    cache.put("dequantize", (1024, 1024), np.float32,
              {"config": {"block_rows": 256}, "achieved_bps": bps})
    return cache


def test_advisor_picks_int8_when_kernels_are_cheap():
    adv = CompressionAdvisor(bwmodel=BandwidthModel(1.0),  # slow link
                             cache=_skewed_cache(1e15))    # free kernels
    choice, detail = adv.decide(1 << 20, 4, rows=256)
    assert choice == COMPRESS_INT8
    assert detail["int8_s"] < detail["raw_s"]
    assert adv.n_int8 == 1 and adv.n_raw == 0


def test_advisor_picks_raw_when_kernels_are_slow():
    adv = CompressionAdvisor(bwmodel=BandwidthModel(1000.0),  # fast link
                             cache=_skewed_cache(1e3))        # slow kernels
    choice, detail = adv.decide(1 << 20, 4, rows=256)
    assert choice == COMPRESS_RAW
    assert detail["raw_s"] < detail["int8_s"]
    assert adv.n_raw == 1


def test_advisor_decision_is_audited():
    from repro import obs
    adv = CompressionAdvisor(bwmodel=BandwidthModel(1.0),
                             cache=_skewed_cache(1e15))
    adv.decide(1 << 20, 4, rows=256, tag="probe-row")
    ev = [e for e in obs.audit().tail(20)
          if e["kind"] == "kvspill.compression_choice"
          and e.get("tag") == "probe-row"]
    assert ev and ev[-1]["choice"] == COMPRESS_INT8
    assert ev[-1]["raw_us"] > 0


def test_advisor_untuned_reduces_to_static_int8_rule():
    adv = CompressionAdvisor(bwmodel=BandwidthModel(32.0), cache=None)
    choice, _ = adv.decide(1 << 20, 4, rows=256)
    assert choice == COMPRESS_INT8                # smaller payload wins


# -------------------------------------------- auto spill compression
class _State(typing.NamedTuple):
    attn_k: object
    pos: object


def _toy_state(rows=64, cols=512):
    rng = np.random.RandomState(0)
    return _State(attn_k=jnp.asarray(rng.randn(2, 2, rows, cols),
                                     jnp.float32),
                  pos=jnp.asarray([5, 7], jnp.int32))


def _auto_tier(advisor):
    tier = HostMemTier(HostMemConfig(spill_compression="auto",
                                     spill_compress_min_bytes=1))
    tier.kvspill.advisor = advisor
    return tier


def test_auto_compression_compresses_when_priced_cheaper():
    tier = _auto_tier(CompressionAdvisor(bwmodel=BandwidthModel(1.0),
                                         cache=_skewed_cache(1e15)))
    sp = tier.kvspill.spill(_toy_state(), 0, tag="auto-int8")
    assert all(fs.kind == "int8" for fs in sp.layout)
    assert tier.kvspill.stats()["advisor"]["n_int8"] >= 1
    tier.kvspill.discard(sp)


def test_auto_compression_stays_raw_when_priced_dearer():
    tier = _auto_tier(CompressionAdvisor(bwmodel=BandwidthModel(1000.0),
                                         cache=_skewed_cache(1e3)))
    sp = tier.kvspill.spill(_toy_state(), 0, tag="auto-raw")
    assert all(fs.kind == "raw" for fs in sp.layout)
    assert tier.kvspill.stats()["advisor"]["n_raw"] >= 1
    tier.kvspill.discard(sp)


def test_auto_roundtrip_restores_state():
    state = _toy_state()
    before = np.asarray(state.attn_k[:, 0], np.float32).copy()
    tier = _auto_tier(CompressionAdvisor(bwmodel=BandwidthModel(1.0),
                                         cache=_skewed_cache(1e15)))
    sp = tier.kvspill.spill(state, 0, tag="rt")
    zeroed = state._replace(attn_k=state.attn_k.at[:, 0].set(0),
                            pos=state.pos.at[0].set(0))
    back = tier.kvspill.restore(zeroed, sp, 0)
    tol = np.abs(before).max() / 100.0 + 1e-6
    np.testing.assert_allclose(np.asarray(back.attn_k[:, 0], np.float32),
                               before, atol=tol)
    assert int(back.pos[0]) == 5
    assert tier.pool.bytes_in_use == 0


def test_auto_without_advisor_behaves_like_int8():
    tier = HostMemTier(HostMemConfig(spill_compression="auto",
                                     spill_compress_min_bytes=1))
    tier.kvspill.advisor = None
    sp = tier.kvspill.spill(_toy_state(), 0, tag="fallback")
    assert all(fs.kind == "int8" for fs in sp.layout)
    tier.kvspill.discard(sp)


# ------------------------------------------- sustained contention EWMA
def _engine():
    return HostMemTier().engine


def test_arrival_rate_ewma_decays():
    eng = _engine()
    assert eng.arrival_rate_bps(TC_KV_SPILL) == 0.0
    eng._note_arrival(TC_KV_SPILL, 2_000_000, now=100.0)
    from repro.hostmem.engine import ARRIVAL_TAU_S
    r0 = eng.arrival_rate_bps(TC_KV_SPILL, now=100.0)
    assert r0 == pytest.approx(2_000_000 / ARRIVAL_TAU_S)
    r1 = eng.arrival_rate_bps(TC_KV_SPILL, now=100.0 + ARRIVAL_TAU_S)
    assert r1 == pytest.approx(r0 * np.exp(-1.0))


def test_sustained_contention_prices_other_classes():
    eng = _engine()
    assert eng.sustained_contention(TC_POLICY_SWAP) == 0.0
    for _ in range(4):
        eng.wait(eng.submit_swap_out(np.zeros(1 << 20, np.uint8),
                                     "spill", cls=TC_KV_SPILL))
    occ = eng.sustained_contention(TC_POLICY_SWAP)
    assert occ > 0.0
    # a class never counts its own traffic
    assert eng.sustained_contention(TC_KV_SPILL) < occ + 1e-12
    eng.synchronize()


def test_sustained_contention_clamped():
    import time
    eng = _engine()
    eng._note_arrival(TC_CHECKPOINT, 1 << 50, now=1.0)
    eng._arr_last_t[TC_CHECKPOINT] = time.perf_counter()
    assert eng.sustained_contention(TC_POLICY_SWAP) == 0.95


def test_backlog_snapshot_carries_occupancy():
    eng = _engine()
    for _ in range(3):
        eng.wait(eng.submit_swap_out(np.zeros(1 << 20, np.uint8),
                                     "spill", cls=TC_KV_SPILL))
    snap = eng.backlog_snapshot()
    for cls in snap:
        assert "occupancy" in snap[cls] and "arrival_bps" in snap[cls]
    assert snap[TC_KV_SPILL]["arrival_bps"] > 0.0
    assert snap[TC_POLICY_SWAP]["occupancy"] > 0.0
    assert snap[TC_KV_SPILL]["occupancy"] == pytest.approx(
        eng.sustained_contention(TC_KV_SPILL), rel=0.2)
    eng.synchronize()


def _toy_profile(n_ops=100):
    from repro.core.profiler import ProfileData, TensorInstance
    tensors = [TensorInstance(i, 1 << 20, i, n_ops - i, site="ffn_pre",
                              layer=i) for i in range(10)]
    return ProfileData(np.zeros(n_ops, np.int32), tensors, 1.0, 0)


class _BusyEngine:
    """Engine stand-in with sustained traffic but an empty queue."""

    def __init__(self, occ):
        self._occ = occ

    def queued_delay(self, cls="policy_swap", kind="out"):
        return 0.0

    def sustained_contention(self, cls="policy_swap"):
        return self._occ


def test_simulator_scales_budgets_by_occupancy():
    from repro.core.simulator import Simulator
    prof = _toy_profile()
    cfg = ChameleonConfig(groups_per_phase=8)
    idle = Simulator(prof, 50, cfg)
    busy = Simulator(prof, 50, cfg, engine=_BusyEngine(0.5))
    assert busy.occupancy == 0.5
    assert busy.contention_s == 0.0               # backlog pricing intact
    np.testing.assert_allclose(busy._remaining, idle._remaining * 0.5)


def test_policy_records_occupancy_and_roundtrips(llama_profile):
    from repro.core.memtrace import build_timeline
    from repro.core.policy import generate_policy
    prof, _ = llama_profile
    tl = build_timeline(prof)
    pol = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                          int(tl.peak * 0.7), timeline=tl,
                          engine=_BusyEngine(0.25),
                          register_free_times=False)
    assert pol.occupancy == 0.25
    # policystore serialization carries it through a JSON round trip
    from repro.policystore.fingerprint import fingerprint_tokens
    from repro.policystore.store import PolicyRecord
    fp = fingerprint_tokens(np.arange(100, dtype=np.int32))
    rec = PolicyRecord.from_policy(
        fingerprint=fp, prepare_fingerprint=fp, swap=pol, candidates=[],
        n_ops=pol.n_ops, knob=8.0, measured_t=0.1, budget=pol.budget)
    assert rec.policy_meta["occupancy"] == 0.25
    back = PolicyRecord.from_json(rec.to_json())
    assert back.swap_policy().occupancy == 0.25


def test_frozen_backlog_matches_live_engine():
    from repro.adapt.snapshot import AdaptSnapshot
    eng = _engine()
    for _ in range(3):
        eng.wait(eng.submit_swap_out(np.zeros(1 << 20, np.uint8),
                                     "spill", cls=TC_KV_SPILL))
    snap = AdaptSnapshot(contention_s=eng.queued_delay(),
                         backlog=eng.backlog_snapshot())
    frozen = snap.engine_view()
    live = eng.sustained_contention(TC_POLICY_SWAP)
    assert frozen.sustained_contention(TC_POLICY_SWAP) == pytest.approx(
        live, rel=0.2)
    assert frozen.sustained_contention("unknown_class") == 0.0
    eng.synchronize()


# ------------------------------------------------- tier-level wiring
def test_tier_autotune_warm_restart(tmp_path, monkeypatch):
    import repro.kernels.autotune.tuner as tuner_mod
    monkeypatch.setattr(tuner_mod, "default_measure",
                        lambda fn, iters=3: 0.01)
    atcfg = AutotuneConfig(enabled=True, cache_dir=str(tmp_path), iters=1)
    t1 = HostMemTier().autotune(atcfg)
    assert t1.n_measured > 0
    assert os.path.exists(os.path.join(str(tmp_path), CACHE_FILENAME))
    assert T.installed_count() >= 2
    t2 = HostMemTier().autotune(atcfg)            # cold process, warm dir
    assert t2.n_measured == 0
    assert t2.n_cache_hits >= 2


def test_from_chameleon_triggers_autotune(tmp_path, monkeypatch):
    import repro.kernels.autotune.tuner as tuner_mod
    monkeypatch.setattr(tuner_mod, "default_measure",
                        lambda fn, iters=3: 0.01)
    ccfg = ChameleonConfig(
        autotune=AutotuneConfig(enabled=True, cache_dir=str(tmp_path)))
    tier = HostMemTier.from_chameleon(ccfg)
    assert tier.autotuner is not None
    assert tier.autotuner.stats()["cache"]["entries"] >= 2
