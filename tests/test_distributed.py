"""Mesh-dependent tests — run in child processes with 8 host devices so the
main pytest process keeps seeing exactly 1 CPU device."""
import pytest

from tests.conftest import run_child


def test_sharded_train_step_matches_single_device():
    run_child("""
import jax, jax.numpy as jnp, numpy as np
import repro.configs as C
from repro.common.config import TrainConfig, ShapeConfig
from repro.distributed import sharding as shd, steps as S
from repro.launch import specs as SP
from repro.launch.mesh import make_test_mesh
from repro.models.registry import get_api
from repro.optim.adamw import adamw_init

cfg = C.get_reduced('llama2_paper')
api = get_api(cfg)
params, _ = api.init(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size),
         'labels': jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size)}
tcfg = TrainConfig(warmup_steps=0)
step = S.make_train_step(cfg, tcfg)

# single-device reference
p1, o1, m1 = jax.jit(step)(params, opt, batch, jnp.float32(1.0))

# sharded on a (2,4) mesh
mesh = make_test_mesh((2, 4))
shape = ShapeConfig('t', 'train', 32, 4)
with shd.use_mesh(mesh):
    in_sh, _ = SP.train_shardings(cfg, shape, mesh, zero_stage=2)
    jf = jax.jit(step, in_shardings=in_sh)
    p2, o2, m2 = jf(params, opt, batch, jnp.float32(1.0))
np.testing.assert_allclose(float(m1['loss']), float(m2['loss']), rtol=1e-5)
for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
print('SHARDED == SINGLE OK')
""")


def test_dryrun_cell_reduced_mesh():
    """The dry-run machinery end-to-end on a reduced config + small mesh:
    lower + compile + memory/cost/roofline extraction."""
    run_child("""
import repro.configs as C
from repro.common.config import ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import run_cell

mesh = make_test_mesh((2, 4))
cfg = C.get_reduced('qwen2_7b')
shape = ShapeConfig('train_4k', 'train', 64, 8)
rec = run_cell('qwen2_7b', 'train_4k', False, 'none', None, verbose=False,
               mesh=mesh, cfg=cfg, shape=shape)
assert rec['status'] == 'ok', rec
assert rec['roofline']['flops_per_chip'] > 0
assert rec['memory']['peak_per_chip'] > 0
rec2 = run_cell('qwen2_7b', 'decode_32k', False, 'none', None, verbose=False,
                mesh=mesh, cfg=cfg, shape=ShapeConfig('decode_32k', 'decode', 256, 8))
assert rec2['status'] == 'ok', rec2
print('DRYRUN REDUCED OK')
""")


def test_offload_policy_compiles_on_mesh():
    run_child("""
import jax, jax.numpy as jnp
import repro.configs as C
from repro.common.config import TrainConfig, ShapeConfig, ChameleonConfig
from repro.core.executor import Executor
from repro.distributed import sharding as shd, steps as S
from repro.launch import specs as SP
from repro.launch.mesh import make_test_mesh
from repro.models.registry import get_api
from repro.optim.adamw import adamw_init

cfg = C.get_reduced('llama2_paper')
api = get_api(cfg)
params, _ = api.init(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)
batch = {'tokens': jnp.ones((4, 32), jnp.int32), 'labels': jnp.ones((4, 32), jnp.int32)}
mesh = make_test_mesh((2, 4))
pol = Executor(ChameleonConfig()).conservative(None).to_jax()
step = S.make_train_step(cfg, TrainConfig(), pol)
with shd.use_mesh(mesh):
    in_sh, _ = SP.train_shardings(cfg, ShapeConfig('t','train',32,4), mesh, 2)
    c = jax.jit(step, in_shardings=in_sh).lower(params, opt, batch, jnp.float32(1.0)).compile()
    out = c(params, opt, batch, jnp.float32(1.0))
    jax.block_until_ready(out)
print('OFFLOAD ON MESH OK')
""")


def test_compressed_grad_sync_int8_on_wire():
    """Cross-pod int8 all-gather: s8 operands must appear in the HLO and
    EF-compressed sync must approximate the true mean."""
    run_child("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import make_compressed_grad_sync
from repro.distributed.sharding import shard_map
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((4, 2), ('pod', 'model'))
sync = make_compressed_grad_sync(mesh, 'pod')

def f(g, e):
    return sync({'w': g}, {'w': e})

sm = shard_map(f, mesh=mesh, in_specs=(P('pod', None), P('pod', None)),
               out_specs=(P('pod', None), P('pod', None)))
g = jnp.asarray(np.random.RandomState(0).randn(8, 64), jnp.float32)
e = jnp.zeros_like(g)
jf = jax.jit(sm)
txt = jf.lower(g, e).compile().as_text()
assert 's8[' in txt and 'all-gather' in txt, 'int8 all-gather missing from HLO'
synced, err = jf(g, e)
true_mean = np.mean(np.asarray(g).reshape(4, 2, 64), axis=0)
got = np.asarray(synced['w']).reshape(4, 2, 64)[0]
rel = np.abs(got - true_mean).max() / (np.abs(true_mean).max() + 1e-9)
assert rel < 0.05, rel
print('COMPRESSED SYNC OK')
""")


def test_elastic_restore_new_mesh(tmp_path):
    """Checkpoint written under one topology restores onto another mesh
    (elastic restart after excluding a failed node)."""
    run_child(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpointing.manager import CheckpointManager
from repro.launch.mesh import make_test_mesh

tree = {{'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
mgr = CheckpointManager('{tmp_path}', keep=2)
mesh1 = make_test_mesh((4, 2))
sh1 = NamedSharding(mesh1, P('data', 'model'))
tree1 = {{'w': jax.device_put(tree['w'], sh1)}}
mgr.save(1, {{'params': tree1}}, extra={{'step': 1}}, block=True)

mesh2 = make_test_mesh((2, 2))   # 'smaller cluster' after failure
sh2 = NamedSharding(mesh2, P('data', 'model'))
restored, extra = mgr.restore(1, {{'params': tree}},
                              shardings={{'params': {{'w': sh2}}}})
np.testing.assert_array_equal(np.asarray(restored['params']['w']),
                              np.asarray(tree['w']))
assert restored['params']['w'].sharding == sh2
print('ELASTIC RESTORE OK')
""")
