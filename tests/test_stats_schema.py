"""Golden-key stats-schema tests (ISSUE 6 satellite).

Dashboards, the launch CLIs, the benchmarks, and the nightly validator
all read these dicts by key.  A refactor that silently drops a key
breaks them without failing any behavior test — so the documented key
sets are pinned here.  Adding keys is fine (supersets pass); removing or
renaming one must be a deliberate, test-visible change.
"""
import jax
import numpy as np
import pytest

import repro.configs as C
from repro.common.config import ChameleonConfig
from repro.core.runtime import ChameleonRuntime
from repro.hostmem import HostMemTier
from repro.hostmem import metrics as hm_metrics
from repro.hostmem.engine import TC_CHECKPOINT, TRAFFIC_CLASSES
from repro.models.registry import get_api
from repro.obs import SNAPSHOT_KEYS, MetricsRegistry
from repro.runtime.server import Server

POOL_KEYS = {
    "bytes_reserved", "bytes_in_use", "bytes_free", "peak_reserved",
    "peak_bytes_in_use", "bytes_alloc_total", "bytes_freed_total",
    "class_peaks", "live_blocks", "alloc_count", "reuse_hits",
    "slab_allocs", "free_count", "hit_rate", "fragmentation",
}
ENGINE_KEYS = {
    "n_out", "n_in", "bytes_out", "bytes_in", "time_out_s", "time_in_s",
    "gbps_out", "gbps_in", "in_flight", "queued_bytes", "forced_retires",
    "planned_releases", "current_op", "classes",
}
ENGINE_CLASS_KEYS = {
    "n_out", "n_in", "bytes_out", "bytes_in", "time_out_s", "time_in_s",
    "forced_retires", "stall_s", "stall_transfers", "preemptions",
    "released_at_op", "queue_depth", "queued_bytes", "hwm_queued_bytes",
}
KVSPILL_KEYS = {
    "n_spills", "n_restores", "n_discards", "bytes_spilled",
    "bytes_restored", "live_bytes", "hwm_live_bytes", "compression",
    "bytes_raw", "compression_ratio",
}
# the memory-ledger provider / runtime stats()["obs"]["memory"] block
MEMORY_KEYS = {
    "iterations", "events", "events_dropped", "leak_suspects",
    "staged_bytes", "scoreboard", "last",
}
SCOREBOARD_KEYS = {
    "n", "mean_abs_error", "max_abs_error", "worst_step", "last_error",
}
SERVER_KEYS = {
    "ticks", "active", "spilled", "queued", "completed", "preemptions",
    "kv_spill_class", "hostmem", "latency", "policystore",
}
RUNTIME_KEYS = {
    "stage", "transitions", "n_variants", "best_knob", "applied",
    "release_plan", "contention_s", "profiling_overhead_s",
    "adaptation_overhead_s", "signature", "hostmem", "policystore", "obs",
}


def test_hostmem_collect_keys():
    tier = HostMemTier()
    stats = hm_metrics.collect(tier)
    assert {"pool", "engine", "bwmodel", "kvspill"} <= set(stats)
    assert POOL_KEYS <= set(stats["pool"])
    assert ENGINE_KEYS <= set(stats["engine"])
    assert KVSPILL_KEYS <= set(stats["kvspill"])
    assert set(stats["bwmodel"]) >= {"calibrated", "constant_gbps", "points"}


def test_engine_class_keys_and_backlog_gauges():
    tier = HostMemTier()
    eng = tier.engine
    stats = eng.stats()
    assert set(stats["classes"]) == set(TRAFFIC_CLASSES)
    for c in stats["classes"].values():
        assert ENGINE_CLASS_KEYS <= set(c)
    # live backlog: widen the class window so submits queue, then check
    # the per-class depth/bytes gauges and the top-level total
    eng.set_class_depth(TC_CHECKPOINT, 8)
    evs = [eng.submit_swap_out(np.zeros(128, np.uint8), f"q{i}",
                               cls=TC_CHECKPOINT) for i in range(4)]
    assert not any(e.done for e in evs)
    stats = eng.stats()
    cs = stats["classes"][TC_CHECKPOINT]
    assert cs["queue_depth"] == 4
    assert cs["queued_bytes"] == 4 * 128
    assert stats["queued_bytes"] == 4 * 128
    assert eng.queued_bytes(TC_CHECKPOINT) == 4 * 128
    summary = hm_metrics.format_summary(hm_metrics.collect(tier))
    assert "queued 4 (0.0 MiB)" in summary
    eng.synchronize()
    for e in evs:
        tier.pool.free(e.block)
    assert eng.stats()["queued_bytes"] == 0


def test_server_stats_keys():
    cfg = C.get_reduced("llama2_paper")
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, max_batch=2, max_len=32)
    srv.submit(np.arange(5, dtype=np.int32), max_new_tokens=4)
    srv.tick()
    stats = srv.stats()
    assert SERVER_KEYS <= set(stats)
    lat = stats["latency"]
    assert {"n_completed", "ticks", "tokens", "tokens_per_s",
            "tokens_per_tick", "slot_occupancy", "tick_ms",
            "queue_wait_ticks", "completion_ticks"} <= set(lat)
    for pkeys in (lat["tick_ms"], lat["queue_wait_ticks"],
                  lat["completion_ticks"]):
        assert {"p50", "p95", "max"} <= set(pkeys)


def test_runtime_stats_keys():
    rt = ChameleonRuntime(ChameleonConfig(), lambda pol: (lambda x: x))
    stats = rt.stats()
    assert RUNTIME_KEYS <= set(stats)
    # the monitoring guard pins this exact set — keep it frozen
    assert set(stats["signature"]) == {"iterations", "changed_slots",
                                       "update_tokens"}
    ob = stats["obs"]
    assert {"overlap", "tracer", "audit", "memory"} <= set(ob)
    assert {"last", "mean", "measured", "iterations", "transfer_s",
            "hidden_s"} <= set(ob["overlap"])
    assert {"n_spans", "retained", "dropped", "capacity",
            "names"} <= set(ob["tracer"])
    assert MEMORY_KEYS <= set(ob["memory"])
    assert SCOREBOARD_KEYS <= set(ob["memory"]["scoreboard"])


def test_registry_snapshot_keys():
    snap = MetricsRegistry().snapshot()
    assert tuple(snap.keys()) == SNAPSHOT_KEYS
    assert SNAPSHOT_KEYS == ("time", "seq", "counters", "gauges", "series",
                             "providers")


def test_policystore_stats_keys():
    rt = ChameleonRuntime(ChameleonConfig(), lambda pol: (lambda x: x))
    ps = rt.policystore_stats()
    assert ps is not None
    assert {"store", "tiers", "adaptations",
            "genpolicy_steps_total"} <= set(ps)
    assert {"reuse", "warm_start", "regen", "demoted"} <= set(ps["tiers"])
    assert {"records", "dir", "lookups", "exact_hits", "sim_hits",
            "misses", "evictions"} <= set(ps["store"])
