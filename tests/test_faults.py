"""repro.faults: the fault-injection harness and the health-driven
degradation ladder (ISSUE 8).

Families:

  * **plan** — seeded schedules are deterministic and replayable, the
    disarmed hook is a no-op, windows/max_fires bound firing, JSON
    round-trips;
  * **engine recovery** — bounded retry with backoff recovers transient
    faults bit-exactly; terminal swap-out failure retains the block in
    HBM (later swap-in short-circuits, still bit-exact); terminal
    swap-in failure falls back to a synchronous host copy; a dropped DMA
    never loses data (the staging check fires while the source is still
    held); with resilience disabled the legacy raise survives;
  * **properties** — per-class FIFO completion order is preserved under
    random fault schedules, and no slab is ever double-released
    (hypothesis, pool invariants checked);
  * **health / ladder** — score thresholds drive healthy→degraded→failed
    and recovery needs a clean streak; the ladder descends one rung per
    hold window, trims before it abandons, probes only at reduced rungs;
  * **hardening satellites** — policy store survives corrupt records,
    mid-put crashes and a truncated lsh.index; checkpoint restore names
    the corrupt shard and falls back to the previous step; the adapt
    worker's crash/hang faults exercise the conservative fallback and
    the watchdog;
  * **integration** — a reduced-llama2 trainer under a seeded engine
    fault window never crashes, descends the ladder, and recovers, with
    the whole chain visible in the audit log.
"""
import glob
import json
import os
import shutil
import tempfile
import time
from types import SimpleNamespace

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import faults, obs
from repro.common.config import ResilienceConfig
from repro.faults import (DEGRADED, FAILED, HEALTHY, RUNG_CONSERVATIVE,
                          RUNG_FULL, RUNG_NO_SWAP, RUNG_TRIMMED,
                          DegradationLadder, Fault, FaultPlan, FaultSpec,
                          HealthMonitor, trim_swap)
from repro.hostmem import (TC_CHECKPOINT, TC_KV_SPILL, TC_POLICY_SWAP,
                           HostMemError, PinnedSlabPool, TransferEngine)


@pytest.fixture
def tmpdir():
    d = tempfile.mkdtemp()
    yield d
    shutil.rmtree(d, ignore_errors=True)


@pytest.fixture(autouse=True)
def _always_disarmed():
    """No test leaks an armed plan into the rest of the suite."""
    faults.disarm()
    yield
    faults.disarm()


def _engine(**rs_kw):
    rs = ResilienceConfig(retry_backoff_s=0.0, **rs_kw)
    return TransferEngine(PinnedSlabPool(), resilience=rs)


def _roundtrip(eng, arr, tag="t"):
    ev = eng.wait(eng.submit_swap_out(arr, tag))
    return eng.wait(eng.submit_swap_in(ev, tag))


# ------------------------------------------------------------------- plan
def test_plan_is_deterministic_in_seed():
    def fires(seed):
        plan = FaultPlan([FaultSpec("engine.transfer_error", prob=0.3)],
                         seed=seed)
        out = []
        for it in range(20):
            plan.set_iteration(it)
            out.append([plan.fire("engine.transfer_error", key="k")
                        is not None for _ in range(5)])
        return out

    assert fires(7) == fires(7)
    assert fires(7) != fires(8)         # astronomically unlikely to collide


def test_plan_window_and_max_fires():
    plan = FaultPlan([FaultSpec("pool.alloc", prob=1.0, start=3, stop=6,
                                max_fires=2)])
    hits = []
    for it in range(10):
        plan.set_iteration(it)
        if plan.fire("pool.alloc") is not None:
            hits.append(it)
    assert hits == [3, 4]               # window opens at 3, capped at 2


def test_unknown_site_rejected():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("engine.nonexistent")


def test_plan_json_roundtrip():
    plan = FaultPlan.everywhere(seed=42, prob=0.1, seconds=0.5, stop=100)
    clone = FaultPlan.from_json(json.loads(json.dumps(plan.to_json())))
    assert clone.seed == plan.seed
    assert [s.to_json() for s in clone.specs] == \
           [s.to_json() for s in plan.specs]


def test_disarmed_inject_is_noop():
    assert not faults.armed()
    assert faults.inject("engine.transfer_error", key="x") is None
    faults.tick(5)                      # no plan: silently ignored


def test_arm_disarm_and_audit_trail():
    plan = FaultPlan([FaultSpec("store.load", prob=1.0)], seed=3)
    with faults.injected(plan):
        assert faults.active() is plan
        assert faults.inject("store.load", key="rec") is not None
    assert faults.active() is None
    kinds = [e["kind"] for e in obs.audit().tail(50)]
    assert "fault.armed" in kinds and "fault.injected" in kinds \
        and "fault.disarmed" in kinds


# -------------------------------------------------------- engine recovery
def test_retry_recovers_transient_fault_bit_exactly():
    eng = _engine()
    arr = np.random.RandomState(0).randn(257).astype(np.float32)
    plan = FaultPlan([FaultSpec("engine.transfer_error", prob=1.0,
                                max_fires=2)])
    with faults.injected(plan):
        ev2 = _roundtrip(eng, arr)
    np.testing.assert_array_equal(np.asarray(ev2.result), arr)
    assert not ev2.failed
    assert eng.n_retries == 2 and eng.n_failed_out == 0
    assert eng.pool.live_blocks == 0
    eng.pool.check()


def test_terminal_swap_out_retains_in_hbm_and_short_circuits():
    eng = _engine(max_retries=1)
    arr = np.random.RandomState(1).randn(100).astype(np.float32)
    plan = FaultPlan([FaultSpec("engine.transfer_error", prob=1.0)])
    with faults.injected(plan):
        ev = eng.wait(eng.submit_swap_out(arr, "t"))
        assert ev.failed and ev.block is None
        assert ev.result is arr          # the retained device reference
        # swap-in of a failed staging short-circuits: no link traffic,
        # the retained array comes back as-is — bit-exact by identity
        ev2 = eng.wait(eng.submit_swap_in(ev, "t"))
    assert ev2.done and ev2.failed is False
    np.testing.assert_array_equal(np.asarray(ev2.result), arr)
    assert eng.n_failed_out == 1 and eng.n_hbm_fallback_in == 1
    assert eng.pool.live_blocks == 0     # the slab was released exactly once
    eng.pool.check()
    # one retry (0.5) + one terminal error (1.0): scored but not yet
    # degraded — a single bad transfer must not flap the ladder
    assert eng.health.links[TC_POLICY_SWAP].score >= 1.0
    assert eng.health.state(TC_POLICY_SWAP) == HEALTHY


def test_terminal_swap_in_falls_back_to_sync_copy():
    eng = _engine(max_retries=1)
    arr = np.random.RandomState(2).randn(64).astype(np.float32)
    ev = eng.wait(eng.submit_swap_out(arr, "t"))
    assert not ev.failed
    plan = FaultPlan([FaultSpec("engine.transfer_drop", prob=1.0)])
    with faults.injected(plan):
        ev2 = eng.wait(eng.submit_swap_in(ev, "t"))
    # the async device-put path kept failing; the staged bytes were
    # recovered by a synchronous host-side read instead
    np.testing.assert_array_equal(np.asarray(ev2.result), arr)
    assert eng.n_sync_fallback_in == 1
    assert eng.pool.live_blocks == 0
    eng.pool.check()


def test_dropped_dma_never_loses_data():
    """A swap-out whose copy silently does nothing must be caught while
    the source reference is still held — retry, don't lose the tensor."""
    eng = _engine()
    arr = np.random.RandomState(3).randn(333).astype(np.float32)
    plan = FaultPlan([FaultSpec("engine.transfer_drop", prob=1.0,
                                max_fires=1)])
    with faults.injected(plan):
        ev2 = _roundtrip(eng, arr)
    np.testing.assert_array_equal(np.asarray(ev2.result), arr)
    assert eng.n_retries >= 1


def test_stall_fault_delays_but_completes():
    eng = _engine()
    arr = np.zeros(64, np.float32)
    plan = FaultPlan([FaultSpec("engine.transfer_stall", prob=1.0,
                                seconds=0.05, max_fires=1)])
    with faults.injected(plan):
        t0 = time.perf_counter()
        ev2 = _roundtrip(eng, arr)
        dt = time.perf_counter() - t0
    assert dt >= 0.05 and not ev2.failed


def test_pool_faults_are_absorbed_by_engine_retry():
    eng = _engine()
    arr = np.random.RandomState(4).randn(50).astype(np.float32)
    plan = FaultPlan([FaultSpec("pool.alloc", prob=1.0, max_fires=1)])
    with faults.injected(plan):
        ev2 = _roundtrip(eng, arr)
    np.testing.assert_array_equal(np.asarray(ev2.result), arr)
    assert eng.n_retries == 1


def test_resilience_disabled_preserves_legacy_raise():
    eng = TransferEngine(PinnedSlabPool(),
                         resilience=ResilienceConfig(enabled=False))
    plan = FaultPlan([FaultSpec("engine.transfer_error", prob=1.0)])
    with faults.injected(plan):
        with pytest.raises(Exception):
            eng.wait(eng.submit_swap_out(np.zeros(8, np.float32), "t"))


def test_pool_pressure_spares_recycled_slabs():
    pool = PinnedSlabPool()
    blk = pool.alloc(1000, "warm")
    pool.free(blk)
    plan = FaultPlan([FaultSpec("pool.pressure", prob=1.0)])
    with faults.injected(plan):
        # same class: served from the free list, pressure fault untouched
        ok = pool.alloc(900, "recycled")
        # fresh class: the host allocator is the one under pressure
        with pytest.raises(HostMemError, match="pressure"):
            pool.alloc(1 << 20, "fresh")
    pool.free(ok)
    pool.check()


# --------------------------------------------------------------- properties
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 32 - 1), st.floats(0.0, 0.6))
def test_per_class_fifo_order_survives_faults(seed, prob):
    """Within a (class, direction) stream, completion order must equal
    submission order no matter which copies fault and retry: retries
    happen inside the executing slot, never by re-queueing."""
    faults.disarm()
    eng = _engine()
    done: dict = {c: [] for c in (TC_POLICY_SWAP, TC_KV_SPILL,
                                  TC_CHECKPOINT)}
    plan = FaultPlan([FaultSpec("engine.transfer_error", prob=prob),
                      FaultSpec("engine.transfer_drop", prob=prob / 2)],
                     seed=seed)
    rng = np.random.RandomState(seed % (2 ** 31))
    with faults.injected(plan):
        evs = []
        for i in range(18):
            cls = (TC_POLICY_SWAP, TC_KV_SPILL,
                   TC_CHECKPOINT)[int(rng.randint(3))]
            ev = eng.submit_swap_out(np.full(8 + i, i, np.float32),
                                     f"s{i}", cls=cls)
            ev.on_done(lambda e, c=cls: done[c].append(e.eid))
            evs.append(ev)
        eng.synchronize()
    for c, order in done.items():
        assert order == sorted(order), (c, order)
    # every payload either staged faithfully or was retained in HBM
    for i, ev in enumerate(evs):
        src = np.full(8 + i, i, np.float32)
        got = (np.asarray(ev.result) if ev.failed
               else ev.block.read())
        np.testing.assert_array_equal(got, src)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 32 - 1))
def test_no_double_release_under_chaos(seed):
    """Whatever faults fire, every slab is released exactly once: live
    blocks drain to zero and the pool's byte accounting stays exact."""
    faults.disarm()
    eng = _engine(max_retries=1)
    plan = FaultPlan.everywhere(seed=seed, prob=0.25)
    with faults.injected(plan):
        outs = [eng.submit_swap_out(np.full(16, i, np.float32), f"o{i}")
                for i in range(12)]
        for ev in outs:
            eng.wait(ev)
            eng.wait(eng.submit_swap_in(ev, ev.tag))
    assert eng.pool.live_blocks == 0
    eng.pool.check()


# ----------------------------------------------------------------- health
def test_health_degrades_fails_and_recovers():
    h = HealthMonitor(["link"], degrade_score=2.0, fail_score=4.0,
                      recover_successes=3, decay=0.5)
    assert h.worst() == HEALTHY
    h.note_error("link")
    h.note_error("link")                 # score 2.0 -> degraded
    assert h.state("link") == DEGRADED
    h.note_error("link")
    h.note_error("link")                 # score 4.0 -> failed
    assert h.state("link") == FAILED
    for _ in range(10):
        h.note_success("link")
    assert h.state("link") == HEALTHY
    assert h.links["link"].n_transitions >= 2


def test_health_retry_weighs_half_and_slow_quarter():
    h = HealthMonitor(["link"], degrade_score=2.0)
    for _ in range(3):
        h.note_retry("link")             # 1.5: still healthy
    assert h.state("link") == HEALTHY
    h.note_retry("link")                 # 2.0: degraded
    assert h.state("link") == DEGRADED
    h2 = HealthMonitor(["l2"], degrade_score=2.0, residual_limit=8.0)
    for _ in range(7):
        h2.note_success("l2", residual=50.0)   # 7 * 0.25 = 1.75
    assert h2.state("l2") == HEALTHY
    h2.note_success("l2", residual=50.0)
    assert h2.state("l2") == DEGRADED


def test_health_recovery_needs_clean_streak():
    h = HealthMonitor(["link"], degrade_score=2.0, recover_successes=4,
                      decay=0.1)
    h.note_error("link")
    h.note_error("link")
    assert h.state("link") == DEGRADED
    h.note_success("link")               # score decays fast but streak=1
    h.note_retry("link")                 # streak broken
    h.note_success("link")
    h.note_success("link")
    h.note_success("link")
    assert h.state("link") == DEGRADED   # streak only 3
    h.note_success("link")
    assert h.state("link") == HEALTHY


# ----------------------------------------------------------------- ladder
def test_ladder_descends_with_hold_and_recovers():
    lad = DegradationLadder(hold_iterations=2)
    assert lad.decide(FAILED, 10) == RUNG_TRIMMED
    assert lad.decide(FAILED, 11) is None        # hold window
    assert lad.decide(FAILED, 12) == RUNG_CONSERVATIVE
    assert lad.decide(FAILED, 14) == RUNG_NO_SWAP
    assert lad.decide(FAILED, 20) is None        # bottom rung holds
    assert lad.decide(HEALTHY, 22) == RUNG_CONSERVATIVE
    assert lad.decide(HEALTHY, 24) == RUNG_TRIMMED
    assert lad.decide(HEALTHY, 26) == RUNG_FULL
    assert lad.decide(HEALTHY, 30) is None       # already at full
    assert lad.n_descents == 3 and lad.n_ascents == 3


def test_ladder_degraded_goes_to_trimmed_only():
    lad = DegradationLadder(hold_iterations=0)
    assert lad.decide(DEGRADED, 1) == RUNG_TRIMMED
    assert lad.decide(DEGRADED, 5) is None       # never deeper on degraded


def test_ladder_reset_and_probe_throttle():
    lad = DegradationLadder(hold_iterations=0, probe_interval=4)
    assert not lad.should_probe(0)               # full rung: no probes
    lad.decide(FAILED, 1)
    assert lad.should_probe(2)
    assert not lad.should_probe(3)               # throttled
    assert lad.should_probe(6)
    lad.reset(7)
    assert lad.rung == RUNG_FULL
    assert any(t["why"] == "new-policy" for t in lad.transitions)


def test_trim_swap_drops_lowest_scores_within_budget(monkeypatch):
    entries = [SimpleNamespace(uid=i, score=float(i), nbytes=10)
               for i in range(10)]
    swap = SimpleNamespace(entries=entries)
    # dropping an entry raises the peak by its footprint: monotone in the
    # number dropped, exactly what the binary search assumes
    import repro.core.policy as P
    monkeypatch.setattr(
        P, "projected_peak",
        lambda prof, kept: 100 + (len(entries) - len(kept)) * 10)
    kept = trim_swap(None, swap, budget=130, max_drop_fraction=0.5)
    assert len(kept) == 7                        # 3 dropped: peak 130
    assert [e.uid for e in kept] == [3, 4, 5, 6, 7, 8, 9]  # lowest cut
    # budget below any drop: nothing to trim
    assert trim_swap(None, swap, budget=100, max_drop_fraction=0.5) is None
    # cap respected even with infinite headroom
    kept = trim_swap(None, swap, budget=10 ** 9, max_drop_fraction=0.3)
    assert len(kept) == 7


# -------------------------------------------- policy store hardening (S2)
def _mini_store(d, n=3):
    from repro.common.config import PolicyStoreConfig
    from repro.policystore import PolicyRecord, PolicyStore, \
        fingerprint_tokens
    store = PolicyStore(PolicyStoreConfig(dir=d))
    for i in range(n):
        fp = fingerprint_tokens(np.arange(100) % (i + 5) + 1)
        store.put(PolicyRecord.from_policy(
            fingerprint=fp, prepare_fingerprint=fp, swap=None,
            candidates=[], n_ops=100, knob=1.0, measured_t=0.1,
            budget=1 << 20, policy_kind="conservative"))
    return store


def test_store_injected_corrupt_record_skipped_on_load(tmpdir):
    _mini_store(tmpdir, n=3)
    from repro.common.config import PolicyStoreConfig
    from repro.policystore import PolicyStore
    plan = FaultPlan([FaultSpec("store.load", prob=1.0, max_fires=1)])
    with faults.injected(plan):
        store = PolicyStore(PolicyStoreConfig(dir=tmpdir))
    assert len(store) == 2 and store.n_corrupt == 1
    # the LSH index was rebuilt to match the surviving record set
    assert store.index.keys() == {r.key for r in store.records()}


def test_store_mid_put_crash_is_atomic(tmpdir):
    """A writer dying mid-persist leaves a *.tmp behind; the record file
    and the next load are unaffected, and put() never raises."""
    store = _mini_store(tmpdir, n=1)
    rec = store.records()[0]
    before = open(os.path.join(tmpdir, rec.key + ".json")).read()
    rec.knob = 9.0
    plan = FaultPlan([FaultSpec("store.put", prob=1.0, max_fires=1)])
    with faults.injected(plan):
        store.put(rec)                   # must not raise
    assert store.n_io_errors == 1
    assert open(os.path.join(tmpdir, rec.key + ".json")).read() == before
    assert glob.glob(os.path.join(tmpdir, "*.json.tmp"))
    # tmp leftovers are invisible to a fresh attach; memory copy won
    from repro.common.config import PolicyStoreConfig
    from repro.policystore import PolicyStore
    store2 = PolicyStore(PolicyStoreConfig(dir=tmpdir))
    assert len(store2) == 1 and store2.n_corrupt == 0
    assert [e["kind"] for e in obs.audit().tail(20)].count("store.io_error")


def test_store_truncated_index_rebuilds_silently(tmpdir):
    store = _mini_store(tmpdir, n=3)
    keys = {r.key for r in store.records()}
    idx_path = os.path.join(tmpdir, "lsh.index")
    payload = open(idx_path).read()
    with open(idx_path, "w") as f:
        f.write(payload[: len(payload) // 3])    # truncated mid-write
    from repro.common.config import PolicyStoreConfig
    from repro.policystore import PolicyStore
    store2 = PolicyStore(PolicyStoreConfig(dir=tmpdir))
    assert len(store2) == 3
    assert store2.n_index_rebuilds == 1
    assert store2.index.keys() == keys
    # and the rebuilt index was re-persisted in valid form
    json.load(open(idx_path))


def test_store_crash_between_record_write_and_index_update(tmpdir):
    """Kill the writer after the record file lands but before the index
    flush: the on-disk index is stale, and the next attach must detect
    the key-set mismatch and rebuild instead of serving a partial index."""
    store = _mini_store(tmpdir, n=2)
    from repro.common.config import PolicyStoreConfig
    from repro.policystore import PolicyRecord, PolicyStore, \
        fingerprint_tokens
    fp = fingerprint_tokens(np.arange(100) % 13 + 1)
    rec = PolicyRecord.from_policy(
        fingerprint=fp, prepare_fingerprint=fp, swap=None, candidates=[],
        n_ops=100, knob=1.0, measured_t=0.1, budget=1 << 20,
        policy_kind="conservative")
    # simulate the crash: write the record file directly, never the index
    with open(os.path.join(tmpdir, rec.key + ".json"), "w") as f:
        json.dump(rec.to_json(), f)
    store2 = PolicyStore(PolicyStoreConfig(dir=tmpdir))
    assert len(store2) == 3
    assert store2.n_index_rebuilds == 1
    assert store2.index.keys() == {r.key for r in store2.records()}


# --------------------------------------------- checkpoint hardening (S3)
def _ckpt_trees(v):
    return {"arrays": {"w": np.full((4, 4), v, np.float32),
                       "b": np.arange(6, dtype=np.float32) + v}}


def test_ckpt_restore_falls_back_on_bit_flip(tmpdir):
    from repro.checkpointing.manager import CheckpointManager
    mgr = CheckpointManager(tmpdir, process_index=0)
    mgr.save(1, _ckpt_trees(1.0), extra={"step": 1}, block=True)
    mgr.save(2, _ckpt_trees(2.0), extra={"step": 2}, block=True)
    shard = os.path.join(tmpdir, "step_00000002", "arrays.p0.npz")
    raw = bytearray(open(shard, "rb").read())
    raw[len(raw) // 2] ^= 0xFF                   # bit-flip mid-file
    with open(shard, "wb") as f:
        f.write(raw)
    # fallback disabled: the error names the shard
    with pytest.raises(IOError, match=r"arrays\.p0\.npz"):
        mgr.restore(2, _ckpt_trees(0.0), fallback=False)
    # fallback enabled: the previous step_N restores transparently
    out, extra = mgr.restore(2, _ckpt_trees(0.0))
    assert extra["step"] == 1
    np.testing.assert_array_equal(np.asarray(out["arrays"]["w"]),
                                  np.full((4, 4), 1.0, np.float32))
    assert mgr.n_restore_fallbacks == 1
    kinds = [e["kind"] for e in obs.audit().tail(20)]
    assert "ckpt.restore_failed" in kinds and "ckpt.restore_fallback" in kinds


def test_ckpt_write_fault_retries_then_succeeds(tmpdir):
    from repro.checkpointing.manager import CheckpointManager
    mgr = CheckpointManager(tmpdir, process_index=0)
    plan = FaultPlan([FaultSpec("ckpt.write", prob=1.0, max_fires=1)])
    with faults.injected(plan):
        mgr.save(5, _ckpt_trees(5.0), extra={"step": 5}, block=True)
    out, extra = mgr.restore(5, _ckpt_trees(0.0))
    assert extra["step"] == 5
    assert any(e["kind"] == "ckpt.write_retry"
               for e in obs.audit().tail(20))


def test_ckpt_degrade_mode_survives_write_failure(tmpdir):
    from repro.checkpointing.manager import CheckpointManager
    mgr = CheckpointManager(tmpdir, process_index=0, on_error="degrade")
    plan = FaultPlan([FaultSpec("ckpt.write", prob=1.0)])  # beats retries
    with faults.injected(plan):
        mgr.save(3, _ckpt_trees(3.0), extra={"step": 3})
        mgr.wait()                       # raise-mode would explode here
    assert mgr.n_write_failures == 1
    assert mgr.all_steps() == []         # the tmp dir never got renamed
    assert any(e["kind"] == "ckpt.write_failed"
               for e in obs.audit().tail(20))
    # raise mode keeps the legacy fail-stop contract
    mgr2 = CheckpointManager(tmpdir, process_index=0)
    with faults.injected(FaultPlan([FaultSpec("ckpt.write", prob=1.0)])):
        mgr2.save(4, _ckpt_trees(4.0), extra={"step": 4})
        with pytest.raises(RuntimeError, match="checkpoint write failed"):
            mgr2.wait()


def test_ckpt_collect_snapshots_failed_staging_from_hbm(tmpdir):
    """With the engine's checkpoint-class staging failing terminally, the
    writer snapshots the retained-in-HBM arrays instead of crashing."""
    from repro.checkpointing.manager import CheckpointManager
    eng = _engine(max_retries=0)
    mgr = CheckpointManager(tmpdir, process_index=0, engine=eng)
    plan = FaultPlan([FaultSpec("engine.transfer_error", prob=1.0)])
    with faults.injected(plan):
        mgr.save(9, _ckpt_trees(9.0), extra={"step": 9}, block=True)
    out, extra = mgr.restore(9, _ckpt_trees(0.0))
    np.testing.assert_array_equal(np.asarray(out["arrays"]["w"]),
                                  np.full((4, 4), 9.0, np.float32))
    assert eng.pool.live_blocks == 0
    eng.pool.check()


# ------------------------------------------------- adapt worker faults
def _adapt_service(mode="async"):
    from tests.test_adapt_service import _EchoPipeline
    from repro.adapt import AdaptationService
    return AdaptationService(_EchoPipeline(), mode=mode)


def test_adapt_worker_crash_publishes_conservative_fallback():
    from tests.test_adapt_service import _snap
    svc = _adapt_service()
    plan = FaultPlan([FaultSpec("adapt.worker", prob=1.0, max_fires=1)])
    with faults.injected(plan):
        svc.submit(_snap("fp-a", step=1))
        assert svc.drain(timeout=10.0)
    res = svc.poll()
    assert res is not None and res.kind == "conservative-fallback"
    assert svc.n_failed == 1
    svc.close()


def test_adapt_hang_trips_watchdog_once():
    from tests.test_adapt_service import _snap
    svc = _adapt_service()
    plan = FaultPlan([FaultSpec("adapt.hang", prob=1.0, seconds=1.0,
                                max_fires=1)])
    with faults.injected(plan):
        svc.submit(_snap("fp-b", step=2))
        time.sleep(0.1)
        assert svc.watchdog(0.05) is True
        assert svc.watchdog(0.05) is False       # fires at most once per job
    assert svc.n_watchdog == 1
    assert svc.stats()["watchdog_fired"] == 1
    svc.invalidate("worker-timeout")             # what the runtime does
    svc.drain(timeout=10.0)
    assert svc.poll() is None                    # late result discarded
    svc.close()


def test_watchdog_disabled_and_clean_poll_clears_timer():
    from tests.test_adapt_service import _snap
    svc = _adapt_service()
    svc.submit(_snap("fp-c", step=3))
    assert svc.watchdog(0.0) is False            # 0 disables
    svc.drain(timeout=10.0)
    assert svc.poll() is not None
    assert svc.watchdog(1e-9) is False           # timer cleared by poll
    svc.close()


# -------------------------------------------------- trainer integration
def test_straggler_callback_emits_audit_event():
    from repro.runtime.straggler import StragglerDetector, StragglerEvent
    from repro.runtime.trainer import Trainer
    det = StragglerDetector(threshold_sigma=3.0, warmup=2,
                            on_straggler=lambda ev:
                            Trainer._on_straggler(None, ev))
    for s in range(8):
        det.observe(s, 0.01 + 0.0001 * (s % 2))
    assert det.observe(8, 10.0) is True
    ev = obs.audit().tail(5, kind="straggler.flagged")[-1]
    assert ev["step"] == 8 and ev["wall"] == 10.0


@pytest.mark.slow
def test_chaos_trainer_descends_and_recovers(tmpdir):
    """The ISSUE-8 integration bar at test scale: a reduced-llama2 run
    with a seeded engine-fault window never crashes, degrades the swap
    path while the link is bad, recovers after, and the audit log shows
    the whole chain (fault -> retry -> health -> ladder)."""
    import repro.configs as C
    from repro.common.config import ChameleonConfig, TrainConfig
    from repro.data.synthetic import SyntheticTokens
    from repro.runtime.trainer import Trainer
    cfg = C.get_reduced("llama2_paper")
    tcfg = TrainConfig(steps=48, checkpoint_every=0, checkpoint_dir=tmpdir,
                       eval_every=0, warmup_steps=2, learning_rate=1e-3)
    data = SyntheticTokens(cfg.vocab_size, 64, 4, seed=0)
    tr = Trainer(cfg, tcfg,
                 ChameleonConfig(enabled=True, hbm_budget_bytes=12 << 20),
                 data=data)
    plan = FaultPlan([FaultSpec("engine.transfer_error", prob=1.0,
                                start=12, stop=22)], seed=1)
    with faults.injected(plan):
        rep = tr.train(48)
    assert not rep.failures
    assert plan.total_fired() > 0
    eng = tr.rt.hostmem.engine
    assert eng.n_retries > 0
    lad = tr.rt.ladder
    assert lad.n_descents >= 1, lad.transitions
    assert lad.n_ascents >= 1, lad.transitions   # probe-driven recovery
    assert eng.health.worst() == HEALTHY
    kinds = {e["kind"] for e in obs.audit().tail(500)}
    assert {"fault.injected", "engine.retry",
            "ladder.transition"} <= kinds
    assert eng.pool.live_blocks == 0
    eng.pool.check()
