"""KV-cache spill: over-subscribed serving must decode exactly what a
fully HBM-resident server decodes (spill -> restore -> continued decode)."""
import jax
import numpy as np
import pytest

import repro.configs as C
from repro.common.config import HostMemConfig
from repro.hostmem import HostMemTier
from repro.models.registry import get_api
from repro.runtime.server import Server


@pytest.fixture(scope="module")
def llama_serve():
    cfg = C.get_reduced("llama2_paper")
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, size=rng.randint(4, 10))
            for _ in range(n)]


def test_spill_restore_roundtrip_is_exact(llama_serve):
    """Unit-level: spill a slot, let other slots decode, restore -> the
    kv rows and pos come back bit-identical."""
    cfg, params = llama_serve
    srv = Server(cfg, params, max_batch=2, max_len=32)
    tier = HostMemTier()
    srv.submit(np.arange(5, dtype=np.int32), max_new_tokens=30)
    srv.submit(np.arange(7, dtype=np.int32), max_new_tokens=30)
    srv.tick()
    before_k = np.asarray(srv.state.attn_k[:, 0]).copy()
    before_pos = int(srv.state.pos[0])
    sp = tier.kvspill.spill(srv.state, 0, tag="req-a")
    assert sp.nbytes > 0
    srv.tick()                       # slot 1 keeps decoding meanwhile
    # clobber slot 0 as a new tenant would
    srv.state = srv.state._replace(
        attn_k=srv.state.attn_k.at[:, 0].set(0),
        pos=srv.state.pos.at[0].set(0))
    srv.state = tier.kvspill.restore(srv.state, sp, 0)
    np.testing.assert_array_equal(np.asarray(srv.state.attn_k[:, 0]),
                                  before_k)
    assert int(srv.state.pos[0]) == before_pos
    assert tier.kvspill.n_spills == 1 and tier.kvspill.n_restores == 1
    assert tier.pool.bytes_in_use == 0   # restore freed the slabs


def test_oversubscribed_server_matches_resident(llama_serve):
    """2 HBM slots, 5 concurrent requests: every request must generate the
    same tokens as on a server with 5 resident slots."""
    cfg, params = llama_serve
    prompts = _prompts(cfg, 5)

    ref = Server(cfg, params, max_batch=5, max_len=48)
    ref_ids = [ref.submit(p, max_new_tokens=6) for p in prompts]
    ref_out = ref.run_until_done()

    tier = HostMemTier()
    srv = Server(cfg, params, max_batch=2, max_len=48, max_active=5,
                 hostmem=tier)
    ids = [srv.submit(p, max_new_tokens=6) for p in prompts]
    out = srv.run_until_done(max_ticks=500)

    assert srv.n_active == 0 and len(out) == 5
    assert srv.n_preemptions > 0, "over-subscription must actually spill"
    for ra, rb in zip(ref_ids, ids):
        assert out[rb] == ref_out[ra], \
            f"spilled request {rb} diverged from resident decode"
    ks = srv.stats()["hostmem"]["kvspill"]
    assert ks["n_spills"] == ks["n_restores"] == srv.n_preemptions
    assert tier.pool.bytes_in_use == 0   # all slabs returned after drain


def test_oversubscription_requires_hostmem_builds_default(llama_serve):
    cfg, params = llama_serve
    srv = Server(cfg, params, max_batch=1, max_len=32, max_active=2)
    assert srv.hostmem is not None       # auto-provisioned tier
    a, b = _prompts(cfg, 2, seed=3)
    ra = srv.submit(a, max_new_tokens=4)
    rb = srv.submit(b, max_new_tokens=4)
    out = srv.run_until_done(max_ticks=200)
    assert len(out[ra]) == 4 and len(out[rb]) == 4


def test_resident_only_server_never_spills(llama_serve):
    """Default config (max_active == max_batch) must not touch the tier."""
    cfg, params = llama_serve
    tier = HostMemTier(HostMemConfig(engine_depth=2))
    srv = Server(cfg, params, max_batch=3, max_len=48, hostmem=tier)
    for p in _prompts(cfg, 6, seed=1):
        srv.submit(p, max_new_tokens=4)
    srv.run_until_done(max_ticks=200)
    assert srv.n_preemptions == 0
    assert tier.engine.n_out == 0 and tier.pool.alloc_count == 0


def test_pool_reuse_across_spill_churn(llama_serve):
    """Steady-state spill traffic recycles slabs: hit rate >= 90%."""
    cfg, params = llama_serve
    tier = HostMemTier()
    srv = Server(cfg, params, max_batch=2, max_len=48, max_active=4,
                 hostmem=tier)
    for p in _prompts(cfg, 16, seed=2):
        srv.submit(p, max_new_tokens=5)
    srv.run_until_done(max_ticks=800)
    assert srv.n_preemptions >= 16
    assert tier.pool.hit_rate >= 0.9, tier.pool.stats()
    tier.pool.check()
