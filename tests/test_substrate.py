"""Optimizer / loss-scale / schedules / data pipeline / checkpointing."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.manager import CheckpointManager
from repro.common.config import TrainConfig
from repro.data.synthetic import SyntheticTokens
from repro.optim.adamw import (adamw_init, adamw_update,
                               clip_by_global_norm, global_norm)
from repro.optim.loss_scale import (check_finite, init_loss_scale,
                                    update_loss_scale)
from repro.optim.schedules import warmup_cosine


# ------------------------------------------------------------------ adamw
def test_adamw_matches_manual():
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, 2.0], jnp.float32)}
    g = {"w": jnp.asarray([0.5, -0.5], jnp.float32)}
    st = adamw_init(p)
    new_p, st2 = adamw_update(p, g, st, tcfg, jnp.float32(0.1))
    # manual first-step adam: mhat = g, vhat = g^2 -> update ~ -lr*sign(g)
    exp = np.asarray([1.0, 2.0]) - 0.1 * np.sign([0.5, -0.5])
    np.testing.assert_allclose(np.asarray(new_p["w"]), exp, rtol=1e-4)
    assert int(st2.step) == 1


def test_adamw_weight_decay():
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.5)
    p = {"w": jnp.asarray([10.0], jnp.float32)}
    g = {"w": jnp.asarray([0.0], jnp.float32)}
    st = adamw_init(p)
    new_p, _ = adamw_update(p, g, st, tcfg, jnp.float32(0.1))
    assert float(new_p["w"][0]) < 10.0  # decay shrinks


def test_adamw_master_for_bf16():
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw_init(p)
    assert st.master is not None
    assert st.master["w"].dtype == jnp.float32


def test_clip_by_global_norm():
    g = {"a": jnp.ones((100,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(100.0, rel=1e-5)


# -------------------------------------------------------------- loss scale
def test_loss_scale_dynamics():
    st = init_loss_scale(1024.0)
    st = update_loss_scale(st, finite=False)
    assert float(st.scale) == 512.0
    for _ in range(200):
        st = update_loss_scale(st, finite=True, growth_interval=200)
    assert float(st.scale) == 1024.0


def test_check_finite():
    assert bool(check_finite({"a": jnp.ones(3)}))
    assert not bool(check_finite({"a": jnp.asarray([1.0, np.inf])}))


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, 1.0, 10, 100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, rel=1e-3)
    assert lrs[5] < lrs[9]           # warming up
    assert lrs[50] > lrs[99]         # decaying
    assert lrs[99] >= 0.1 * 0.99     # final_frac floor


# -------------------------------------------------------------------- data
def test_data_deterministic():
    a = SyntheticTokens(1000, 32, 8, seed=3)
    b = SyntheticTokens(1000, 32, 8, seed=3)
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    assert np.all(a.next_batch()["tokens"] < 1000)


def test_data_labels_shifted():
    d = SyntheticTokens(1000, 32, 4, seed=0)
    b = d.next_batch()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_host_shards_disjoint():
    full = SyntheticTokens(1000, 16, 8, seed=1, host_index=0, host_count=1)
    h0 = SyntheticTokens(1000, 16, 8, seed=1, host_index=0, host_count=2)
    h1 = SyntheticTokens(1000, 16, 8, seed=1, host_index=1, host_count=2)
    f, a, b = full.next_batch(), h0.next_batch(), h1.next_batch()
    np.testing.assert_array_equal(np.concatenate([a["tokens"], b["tokens"]]),
                                  f["tokens"])


def test_data_resume_exact():
    d = SyntheticTokens(1000, 16, 4, seed=2)
    d.next_batch()
    st = d.state()
    want = d.next_batch()
    d2 = SyntheticTokens(1000, 16, 4, seed=0)
    d2.restore(st)
    got = d2.next_batch()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_data_prefetch_thread():
    d = SyntheticTokens(1000, 16, 4, seed=5).start()
    try:
        b1 = d.get()
        b2 = d.get()
        assert not np.array_equal(b1["tokens"], b2["tokens"])
    finally:
        d.stop()


# ------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "nested": {"b": jnp.ones((3, 3), jnp.bfloat16)}}
        mgr.save(5, {"params": tree}, extra={"step": 5}, block=True)
        restored, extra = mgr.restore(5, {"params": tree})
        assert extra["step"] == 5
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d)


def test_checkpoint_gc_keeps_n():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"params": {"a": jnp.ones(2)}}, block=True)
        assert mgr.all_steps() == [3, 4]
    finally:
        shutil.rmtree(d)


def test_checkpoint_corruption_detected():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=2)
        path = mgr.save(7, {"params": {"a": jnp.ones(64)}}, block=True)
        npz = [f for f in os.listdir(path) if f.endswith(".npz")][0]
        fp = os.path.join(path, npz)
        with open(fp, "r+b") as f:
            f.seek(100)
            f.write(b"\xff\xff\xff")
        with pytest.raises(IOError):
            mgr.restore(7, {"params": {"a": jnp.ones(64)}})
    finally:
        shutil.rmtree(d)


def test_checkpoint_async():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=3)
        mgr.save(1, {"params": {"a": jnp.ones(1000)}})  # async
        mgr.wait()
        assert mgr.latest_step() == 1
    finally:
        shutil.rmtree(d)
