"""Per-architecture smoke tests (assigned requirement): each reduced config
runs one forward AND one train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.common.config import TrainConfig
from repro.distributed.steps import make_train_step
from repro.models.registry import get_api
from repro.optim.adamw import adamw_init


def _batch(cfg, B=2, S=16):
    rng = jax.random.PRNGKey(1)
    b = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["memory"] = jnp.ones((B, cfg.image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        b["memory"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = C.get_reduced(arch)
    api = get_api(cfg)
    params, axes = api.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = api.forward(cfg, params, batch["tokens"],
                              memory=batch.get("memory"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    step = jax.jit(make_train_step(cfg, TrainConfig(steps=10,
                                                    warmup_steps=0)))
    opt = adamw_init(params)
    new_p, new_opt, metrics = step(params, opt, batch, jnp.float32(1.0))
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_p)))
    assert moved


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_decode_step(arch):
    cfg = C.get_reduced(arch)
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    B = 2
    batch = _batch(cfg, B, 4)
    state = api.init_decode_state(cfg, B, 32, memory=batch.get("memory"),
                                  params=params)
    logits, state2 = api.decode_step(cfg, params, batch["tokens"][:, :1],
                                     state)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert np.all(np.asarray(state2.pos) == 1)


def test_param_count_analytic_close():
    """ModelConfig.param_count (used for 6·N·D roofline flops) agrees with
    the real initialized tree within 2%."""
    for arch in C.ARCH_IDS:
        cfg = C.get_reduced(arch)
        api = get_api(cfg)
        params, _ = api.init(cfg, jax.random.PRNGKey(0))
        real = sum(int(np.prod(x.shape))
                   for x in jax.tree_util.tree_leaves(params))
        est = cfg.param_count()
        assert abs(est - real) / real < 0.02, (arch, est, real)


def test_decode_matches_forward_dense():
    """Token-by-token decode logits == full forward logits (dense)."""
    cfg = C.get_reduced("llama3_2_1b")
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    full, _ = api.forward(cfg, params, toks)
    state = api.init_decode_state(cfg, B, 16)
    outs = []
    for t in range(S):
        lg, state = api.decode_step(cfg, params, toks[:, t:t + 1], state)
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), dec, rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_ssm():
    cfg = C.get_reduced("mamba2_780m")
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    full, _ = api.forward(cfg, params, toks)
    state = api.init_decode_state(cfg, B, 16)
    outs = []
    for t in range(S):
        lg, state = api.decode_step(cfg, params, toks[:, t:t + 1], state)
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), dec, rtol=5e-3, atol=5e-3)


def test_cell_matrix_skips():
    m = C.cell_matrix()
    assert len(m) == 10
    total = sum(len(v) for v in m.values())
    assert total == 32  # 40 cells - 8 long_500k skips (full-attention archs)
    assert "long_500k" in m["mamba2_780m"]
    assert "long_500k" in m["zamba2_1_2b"]
    assert "long_500k" not in m["qwen2_7b"]
