"""Fuzzy matching (§6.1/Appendix A) + Executor policy application (§6)."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ChameleonConfig
from repro.core.executor import Executor
from repro.core.matching import match_instances, pack_features, remap_policy
from repro.core.memtrace import build_timeline
from repro.core.policy import generate_policy
from repro.core.profiler import ProfileData, TensorInstance

from tests.test_simulator_policy import synth_profile


def test_identity_matching():
    prof = synth_profile()
    res = match_instances(prof, prof)
    assert len(res.mapping) == len(prof.candidates)
    assert not res.unmatched
    for a, b in res.mapping.items():
        assert a == b


def test_matching_survives_shift():
    """Minor sequence extension (ops inserted) shifts op indices; features
    still match within the position tolerance."""
    old = synth_profile(n_layers=8, ops_per_layer=10)
    new = synth_profile(n_layers=8, ops_per_layer=11)  # ~10% more ops
    res = match_instances(old, new)
    assert len(res.mapping) == 8
    # layer identity preserved
    by_uid_new = {t.uid: t for t in new.candidates}
    by_uid_old = {t.uid: t for t in old.candidates}
    for o, n in res.mapping.items():
        assert by_uid_old[o].layer == by_uid_new[n].layer


def test_matching_rejects_dtype_change():
    old = synth_profile()
    new = synth_profile()
    for t in new.tensors:
        t.dtype_code = 7
    res = match_instances(old, new)
    assert not res.mapping
    assert len(res.unmatched) == len(old.candidates)


def test_features_are_integers():
    prof = synth_profile()
    for t in prof.candidates:
        f = pack_features(t, prof.n_ops)
        assert isinstance(f, int) and f >= 0


def test_remap_policy_hit_rate():
    prof = synth_profile(n_layers=8, t_iter=30.0)
    tl = build_timeline(prof)
    pol = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                          int(tl.peak * 0.6))
    new = synth_profile(n_layers=8, ops_per_layer=11, t_iter=30.0)
    entries, hit = remap_policy(pol, prof, new)
    assert hit >= 0.9
    sites = {e.site for e in entries}
    assert sites == {e.site for e in pol.entries}


# ----------------------------------------------------- executor application
def test_offload_policy_grads_exact(llama_small):
    """The applied swap policy must not change training math (paper Fig 7:
    loss curves overlap exactly)."""
    cfg, api, params, _ = llama_small
    batch = {"tokens": jnp.ones((2, 64), jnp.int32),
             "labels": jnp.ones((2, 64), jnp.int32)}

    def loss(p, policy):
        l, _ = api.loss_fn(cfg, p, batch, policy=policy)
        return l

    ex = Executor(ChameleonConfig())
    base = ex.baseline().to_jax()
    l0, g0 = jax.jit(lambda p: jax.value_and_grad(
        lambda q: loss(q, base))(p))(params)

    off = ex.conservative(None).to_jax()   # offload every site
    l1, g1 = jax.jit(lambda p: jax.value_and_grad(
        lambda q: loss(q, off))(p))(params)

    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=1e-4)


def test_executor_lower_modes(llama_profile):
    prof, _ = llama_profile
    tl = build_timeline(prof)
    ccfg = ChameleonConfig(hbm_budget_bytes=int(tl.peak * 0.7),
                           allow_remat_fallback=True)
    pol = generate_policy(prof, ccfg, int(tl.peak * 0.7), timeline=tl)
    ex = Executor(ccfg)
    ap = ex.lower(pol, prof)
    assert ap.offload, "policy with MREs must offload something"
    assert not (ap.offload & ap.save)
    assert not (ap.offload & ap.remat)
    jp = ap.to_jax()
    assert jp is not None
    # no-remat-fallback variant keeps cheap sites saved
    ap2 = ex.lower(pol, prof, remat_fallback=False)
    assert not ap2.remat


def test_full_remat_policy_grads_exact(llama_small):
    cfg, api, params, _ = llama_small
    batch = {"tokens": jnp.ones((2, 64), jnp.int32),
             "labels": jnp.ones((2, 64), jnp.int32)}

    def loss(p, policy):
        l, _ = api.loss_fn(cfg, p, batch, policy=policy)
        return l

    l0, g0 = jax.jit(lambda p: jax.value_and_grad(
        lambda q: loss(q, None))(p))(params)
    l1, g1 = jax.jit(lambda p: jax.value_and_grad(
        lambda q: loss(q, "full_remat"))(p))(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=1e-4)
