"""Trainer + ChameleonRuntime integration: the paper's long-term-stability
scenario (Fig 7) at mini scale, fault tolerance, stragglers, serving."""
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.common.config import ChameleonConfig, TrainConfig
from repro.core.stages import Stage
from repro.data.synthetic import SyntheticTokens
from repro.runtime.server import Server
from repro.runtime.straggler import StragglerDetector
from repro.runtime.trainer import Trainer


@pytest.fixture
def tmpdir():
    d = tempfile.mkdtemp()
    yield d
    shutil.rmtree(d, ignore_errors=True)


def _trainer(tmpdir, *, cham=False, eval_every=0, steps=30, seed=0,
             budget=1 << 60, seq=64, batch=4):
    cfg = C.get_reduced("llama2_paper")
    tcfg = TrainConfig(steps=steps, checkpoint_every=10,
                       checkpoint_dir=tmpdir, eval_every=eval_every,
                       warmup_steps=2, learning_rate=1e-3)
    data = SyntheticTokens(cfg.vocab_size, seq, batch, seed=seed)
    return Trainer(cfg, tcfg,
                   ChameleonConfig(enabled=cham, hbm_budget_bytes=budget),
                   data=data)


def test_loss_decreases(tmpdir):
    tr = _trainer(tmpdir, steps=25)
    rep = tr.train(25)
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first, (first, last)


def test_long_term_stability_with_sequence_changes(tmpdir):
    """Paper Fig 7: on-the-fly validation changes the operator sequence;
    Chameleon adapts (Capuchin crashes).  Loss must exactly track the
    no-chameleon baseline — swap never changes math."""
    tr = _trainer(tmpdir, cham=True, eval_every=13, steps=40,
                  budget=20 << 20)  # tight budget: policies really generate
    rep = tr.train(40)
    assert not rep.failures
    stages = set(rep.stages)
    assert "GenPolicy" in stages and "Stable" in stages
    # sequence change detected at the eval step -> WarmUp re-entry
    assert any(why == "seq-change" for _, why, _s in tr.rt.machine.transitions)

    d2 = tempfile.mkdtemp()
    try:
        base = _trainer(d2, cham=False, eval_every=13, steps=40)
        rep2 = base.train(40)
        np.testing.assert_allclose(rep.losses, rep2.losses, rtol=2e-4,
                                   atol=2e-4)
    finally:
        shutil.rmtree(d2, ignore_errors=True)


def test_resume_bitexact(tmpdir):
    tr = _trainer(tmpdir, steps=20, seed=7)
    tr.tcfg = tr.tcfg.__class__(**{**tr.tcfg.__dict__,
                                   "checkpoint_every": 0,
                                   "checkpoint_dir": tmpdir})
    tr.train(10)
    tr._checkpoint(block=True)     # single checkpoint at step 10
    cont = tr.train(10)
    ref_losses = cont.losses[:]

    tr2 = _trainer(tmpdir, steps=20, seed=7)
    assert tr2.resume()
    assert tr2.step == 10
    rep2 = tr2.train(10)
    np.testing.assert_allclose(ref_losses[10:], rep2.losses, rtol=1e-6)


def test_emergency_checkpoint_on_failure(tmpdir):
    tr = _trainer(tmpdir, steps=50)

    def bomb(step):
        if step == 7:
            raise RuntimeError("injected node failure")

    with pytest.raises(RuntimeError, match="injected"):
        tr.train(50, fault_hook=bomb)
    assert tr.report.failures
    # the emergency checkpoint carries post-step-7 state as step 8, so
    # resume does NOT replay the already-applied update
    assert tr.ckpt.latest_step() == 8

    tr2 = _trainer(tmpdir, steps=50)
    assert tr2.resume() and tr2.step == 8


def test_loss_scale_skip_changes_sequence(tmpdir):
    """Force a gradient overflow: the optimizer dispatch is skipped and the
    iteration's op sequence shortens (§2.3's primary cause)."""
    tr = _trainer(tmpdir, steps=6)
    tr.loss_scale = tr.loss_scale._replace(scale=jnp.float32(1e38))
    rep = tr.train(4)
    assert rep.skipped_steps, "overflow must skip an optimizer step"
    assert float(tr.loss_scale.scale) < 1e38


def test_straggler_detection():
    det = StragglerDetector(threshold_sigma=4.0, warmup=3)
    rng = np.random.RandomState(0)
    for s in range(30):
        det.observe(s, 0.10 + abs(rng.randn()) * 0.004)
    assert not det.events
    det.observe(30, 0.50)   # 5x outlier
    assert len(det.events) == 1 and det.events[0].step == 30
    w = det.skew_map({0: 0.1, 1: 0.2})
    assert w[0] > w[1]
    assert abs(sum(w.values()) - 1.0) < 1e-9


def test_server_matches_single_request():
    cfg = C.get_reduced("llama2_paper")
    from repro.models.registry import get_api
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(6, dtype=np.int32) % cfg.vocab_size

    srv1 = Server(cfg, params, max_batch=1, max_len=32)
    r1 = srv1.submit(prompt, max_new_tokens=5)
    out1 = srv1.run_until_done()[r1]

    srv2 = Server(cfg, params, max_batch=3, max_len=32)
    ra = srv2.submit(prompt, max_new_tokens=5)
    rb = srv2.submit((np.arange(9) * 3) % cfg.vocab_size, max_new_tokens=4)
    out2 = srv2.run_until_done()
    assert out2[ra] == out1, "batched decode must match single-request"
    assert len(out2[rb]) == 4


def test_profiling_overhead_small(tmpdir):
    """Lightweight-mode bookkeeping must stay a small fraction of step time
    (paper Table 1: 0.9%).  CPU steps are ms-scale so allow generous 30%."""
    tr = _trainer(tmpdir, cham=True, steps=20)
    rep = tr.train(20)
    total = sum(rep.times[5:])
    assert tr.rt.profiling_overhead_s < 0.5 * total
