"""Detailed-mode profiler (§4) + memory-timeline reconstruction (Fig 3)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.memtrace import MemoryTimeline, build_timeline
from repro.core.mrl import MRL
from repro.core.profiler import ProfileData, TensorInstance


def test_profile_finds_candidates(llama_profile):
    prof, _ = llama_profile
    assert prof.n_ops > 500
    assert prof.scan_layers == 8
    sites = {t.site for t in prof.candidates}
    # the big residual families must all be tagged
    for s in ("ffn_pre", "qkv_proj", "resid_post", "attn_out"):
        assert s in sites, f"missing candidate site {s}"
    # per-layer instances exist
    layers = sorted({t.layer for t in prof.candidates if t.layer >= 0})
    assert layers == list(range(8))


def test_profile_sawtooth_liveness(llama_profile):
    """Residual slices born in fwd layer order die in reverse bwd order."""
    prof, _ = llama_profile
    by_site = {}
    for t in prof.candidates:
        if t.site == "ffn_pre" and t.layer >= 0:
            by_site.setdefault(t.layer, t)
    births = [by_site[i].birth for i in sorted(by_site)]
    deaths = [by_site[i].death for i in sorted(by_site)]
    assert births == sorted(births), "births must follow layer order"
    assert deaths == sorted(deaths, reverse=True), \
        "deaths must be reverse layer order (backward scan)"


def test_timeline_peak_in_middle(llama_profile):
    prof, _ = llama_profile
    tl = build_timeline(prof)
    # training memory peaks at the fwd->bwd boundary, not at the edges
    assert 0.2 * prof.n_ops < tl.peak_op < 0.8 * prof.n_ops
    assert tl.peak > prof.static_bytes


def test_static_bytes_counts_params(llama_profile, llama_small):
    prof, _ = llama_profile
    import jax
    import numpy as np
    _, _, params, _ = llama_small
    pbytes = sum(np.prod(x.shape) * x.dtype.itemsize
                 for x in jax.tree_util.tree_leaves(params))
    assert prof.static_bytes >= pbytes  # params (+batch) are static inputs


# ---------------------------- property tests on the timeline machinery ----
@st.composite
def tensor_sets(draw):
    n_ops = draw(st.integers(10, 200))
    n = draw(st.integers(1, 40))
    tensors = []
    for uid in range(n):
        b = draw(st.integers(0, n_ops - 1))
        d = draw(st.integers(b + 1, n_ops))
        nbytes = draw(st.integers(1, 10 ** 6))
        tensors.append(TensorInstance(uid, nbytes, b, d))
    return n_ops, tensors


@given(tensor_sets())
@settings(max_examples=60, deadline=None)
def test_timeline_invariants(ts):
    n_ops, tensors = ts
    prof = ProfileData(np.zeros(n_ops, np.int32), tensors, 1.0, 0)
    tl = build_timeline(prof)
    assert np.all(tl.usage >= 0)
    assert tl.peak == tl.usage.max()
    # peak equals the max over ops of the sum of live tensors
    manual = max(sum(t.nbytes for t in tensors if t.birth <= i < t.death)
                 for i in range(n_ops + 1))
    assert tl.peak == manual


@given(tensor_sets(), st.floats(0.3, 0.95))
@settings(max_examples=40, deadline=None)
def test_mrl_construction(ts, frac):
    n_ops, tensors = ts
    prof = ProfileData(np.zeros(n_ops, np.int32), tensors, 1.0, 0)
    tl = build_timeline(prof)
    budget = int(tl.peak * frac)
    mrl = MRL.from_timeline(tl, budget)
    if tl.peak > budget:
        assert not mrl.is_empty()
        assert mrl.max_required() == tl.peak - budget
    # decrementing the full range by the max requirement clears it
    mrl.decrement(0, n_ops + 1, mrl.max_required())
    assert mrl.is_empty()


def test_mrl_partial_decrement():
    usage = np.array([0, 10, 20, 30, 20, 10, 0], np.int64)
    tl = MemoryTimeline(usage, 0, 30, 3)
    mrl = MRL.from_timeline(tl, 15)
    assert list(mrl.ops) == [2, 3, 4]
    mrl.decrement(2, 3, 100)         # only op 2 covered
    assert not mrl.is_empty()
    assert list(mrl.remaining_ops) == [3, 4]
    assert mrl.covered_count(0, 10) == 2
