"""Unit + property tests for the Lightweight profiler (§4) and Algo 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import ChameleonConfig
from repro.core.stages import Stage, StageMachine
from repro.core.tokenizer import (OpVocab, sequence_signature, similarity,
                                  tokenize_jaxpr)


def test_tokenize_simple():
    cj = jax.make_jaxpr(lambda x: jnp.tanh(x) @ x.T)(jnp.ones((4, 4)))
    toks = tokenize_jaxpr(cj)
    assert toks.dtype == np.int32 and len(toks) >= 2


def test_tokenize_scan_unrolls():
    def f(x):
        return jax.lax.scan(lambda c, _: (jnp.tanh(c @ c.T) @ c, None),
                            x, None, length=7)[0]
    toks = tokenize_jaxpr(jax.make_jaxpr(f)(jnp.ones((4, 4))))
    # body ops appear 7x
    vals, counts = np.unique(toks, return_counts=True)
    assert counts.max() >= 7


def test_similarity_identical():
    a = np.array([1, 2, 3, 2, 1], np.int32)
    ld, cos = similarity(a, a.copy())
    assert ld == 0.0 and cos == pytest.approx(1.0)


def test_similarity_detects_extension():
    a = np.array([1, 2, 3] * 30, np.int32)
    b = np.concatenate([a, np.array([4, 5, 6] * 20, np.int32)])
    ld, cos = similarity(a, b)
    assert ld > 0.05
    assert cos < 1.0


@given(st.lists(st.integers(1, 20), min_size=5, max_size=200))
@settings(max_examples=50, deadline=None)
def test_similarity_permutation_invariant_histogram(seq):
    a = np.array(seq, np.int32)
    rng = np.random.RandomState(0)
    b = a.copy()
    rng.shuffle(b)
    ld, cos = similarity(a, b)
    assert ld == 0.0
    assert cos == pytest.approx(1.0, abs=1e-9)


@given(st.lists(st.integers(1, 10), min_size=10, max_size=100),
       st.lists(st.integers(1, 10), min_size=10, max_size=100))
@settings(max_examples=50, deadline=None)
def test_similarity_bounds(sa, sb):
    ld, cos = similarity(np.array(sa, np.int32), np.array(sb, np.int32))
    assert 0.0 <= ld <= 1.0
    assert -1e-9 <= cos <= 1.0 + 1e-9


def _seq(n, base=1):
    return np.full((n,), base, np.int32)


def test_stage_machine_algo1():
    cfg = ChameleonConfig(m_warmup_stable=2, n_genpolicy_steps=3)
    sm = StageMachine(cfg)
    a = np.array([1, 2, 3] * 50, np.int32)
    stages = [sm.observe(a, i).value for i in range(12)]
    # init, then 2 stable to leave WarmUp, then 3 to leave GenPolicy
    assert stages[0] == "WarmUp"
    assert "GenPolicy" in stages and "Stable" in stages
    assert stages.index("GenPolicy") == 3
    assert stages.index("Stable") == 7


def test_stage_machine_resets_on_change():
    cfg = ChameleonConfig(m_warmup_stable=1, n_genpolicy_steps=1)
    sm = StageMachine(cfg)
    a = np.array([1, 2, 3] * 50, np.int32)
    for i in range(6):
        sm.observe(a, i)
    assert sm.stage is Stage.STABLE
    b = np.concatenate([a, np.array([7, 8, 9] * 30, np.int32)])
    assert sm.observe(b, 6) is Stage.WARMUP
    assert sm.stable_step == 0


def test_stage_machine_tolerates_minor_change():
    """<5% length change with high cosine must NOT reset (fuzzy-matching
    territory, §6.1)."""
    cfg = ChameleonConfig(m_warmup_stable=1, n_genpolicy_steps=1)
    sm = StageMachine(cfg)
    a = np.array([1, 2, 3] * 100, np.int32)
    for i in range(6):
        sm.observe(a, i)
    b = np.concatenate([a, np.array([1, 2], np.int32)])  # +0.7%
    assert sm.observe(b, 7) is Stage.STABLE


def test_sequence_signature_concat():
    s = sequence_signature([np.array([1, 2], np.int32),
                            np.array([], np.int32),
                            np.array([3], np.int32)])
    np.testing.assert_array_equal(s, [1, 2, 3])
