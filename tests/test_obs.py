"""repro.obs tests (ISSUE 6).

Four families:

  * **boundedness guards** — the always-on bar, enforced the way the
    monitoring hot path enforces its own (deterministic counters first,
    a generous wall-clock ceiling second): the span ring never grows
    past capacity, name interning caps at ``max_names`` with an
    ``<other>`` overflow bucket, the audit deque and gauge series stay
    bounded;
  * **overlap math** — interval-union and overlap-efficiency identities
    on hand-computed cases, plus window clipping semantics;
  * **export schema** — a populated tracer round-trips through
    :func:`export_chrome_trace` and passes the same
    :func:`validate_chrome_trace` the nightly workflow runs;
  * **crash-proofing** — ``hostmem.metrics.format_summary`` formats
    partial/cold snapshots instead of raising (it runs in CLI
    ``finally`` blocks).
"""
import json
import time

import numpy as np
import pytest

from repro import obs
from repro.hostmem import metrics as hm_metrics
from repro.obs import (AuditLog, MetricsRegistry, SNAPSHOT_KEYS, SpanTracer,
                       interval_union, overlap_efficiency, window_efficiency)
from repro.obs.validate import validate_chrome_trace, validate_metrics_jsonl


@pytest.fixture()
def fresh_obs():
    """Isolated process-global obs state; restores the originals after."""
    old_t = obs.set_tracer(SpanTracer(capacity=1 << 10, max_names=64))
    old_m = obs.set_metrics(MetricsRegistry(series_len=32))
    old_a = obs.set_audit(AuditLog(capacity=256))
    try:
        yield obs.tracer(), obs.metrics(), obs.audit()
    finally:
        obs.set_tracer(old_t)
        obs.set_metrics(old_m)
        obs.set_audit(old_a)


# ------------------------------------------------------------- span tracer
def test_tracer_ring_is_bounded():
    tr = SpanTracer(capacity=64)
    buf_ids = (id(tr._t0), id(tr._t1), id(tr._lane))
    for i in range(64 * 2 + 5):
        tr.record(obs.LANE_COMPUTE, "step", float(i), float(i) + 0.5, arg=i)
    s = tr.stats()
    assert s["n_spans"] == 133
    assert s["retained"] == 64
    assert s["dropped"] == 69
    assert (id(tr._t0), id(tr._t1), id(tr._lane)) == buf_ids  # no realloc
    assert len(tr._arg) == 64
    # retained records are the newest, in recording order
    recs = tr.records()
    assert len(recs) == 64
    assert recs[0]["arg"] == 69 and recs[-1]["arg"] == 132


def test_tracer_name_interning_caps():
    tr = SpanTracer(capacity=256, max_names=8)
    for i in range(50):
        tr.record(obs.LANE_ADAPT, f"dyn-{i}", 0.0, 1.0)
    assert tr.stats()["names"] <= 9          # 8 real + "<other>"
    names = {r["name"] for r in tr.records()}
    assert "<other>" in names
    assert "dyn-0" in names                  # early names kept verbatim


def test_tracer_span_records_on_exception():
    tr = SpanTracer(capacity=64)
    with pytest.raises(RuntimeError):
        with tr.span(obs.LANE_CHECKPOINT, "boom"):
            raise RuntimeError("x")
    recs = tr.records()
    assert len(recs) == 1 and recs[0]["name"] == "boom"
    assert recs[0]["t1"] >= recs[0]["t0"]


def test_tracer_filters_by_lane_and_iteration():
    tr = SpanTracer(capacity=64)
    tr.set_iteration(3)
    tr.record(obs.LANE_COMPUTE, "c", 0.0, 1.0)
    tr.record(obs.LANE_KV_SPILL, "k", 1.0, 2.0)
    tr.set_iteration(4)
    tr.record(obs.LANE_COMPUTE, "c", 2.0, 3.0)
    tr.instant(obs.LANE_ADAPT, "marker", t=2.5)
    assert len(tr.spans(lanes=(obs.LANE_COMPUTE,))) == 2
    assert len(tr.spans(lanes=(obs.LANE_COMPUTE,), it=4)) == 1
    assert len(tr.spans(lanes=(obs.LANE_KV_SPILL,), it=3)) == 1
    # instants are excluded from the span view by default
    assert len(tr.spans(lanes=(obs.LANE_ADAPT,))) == 0
    tr.clear()
    assert tr.stats()["n_spans"] == 0 and tr.spans().size == 0


def test_tracer_disabled_records_nothing():
    tr = SpanTracer(capacity=64)
    tr.enabled = False
    tr.record(obs.LANE_COMPUTE, "c", 0.0, 1.0)
    tr.instant(obs.LANE_COMPUTE, "i")
    assert tr.stats()["n_spans"] == 0


def test_tracer_record_wall_clock_budget():
    """Generous always-on ceiling: recording must stay in the microsecond
    range (CI-tolerant bound — the deterministic boundedness guards above
    are the primary enforcement)."""
    tr = SpanTracer(capacity=1 << 12)
    n = 10_000
    t0 = time.perf_counter()
    for i in range(n):
        tr.record(obs.LANE_COMPUTE, "hot", 0.0, 1.0, arg=i)
    per_span = (time.perf_counter() - t0) / n
    assert per_span < 50e-6, f"record cost {per_span * 1e6:.1f}us/span"


# ------------------------------------------------------------ overlap math
def test_interval_union_merges_and_sorts():
    spans = np.array([[5.0, 7.0], [0.0, 2.0], [1.0, 3.0], [3.0, 4.0],
                      [6.0, 6.5]])
    u = interval_union(spans)
    # [0,2]+[1,3] merge; [3,4] touches 3 -> merges too; [5,7] absorbs [6,6.5]
    assert u.tolist() == [[0.0, 4.0], [5.0, 7.0]]
    assert interval_union(np.empty((0, 2))).shape == (0, 2)


def test_overlap_efficiency_hand_case():
    compute = np.array([[0.0, 10.0]])
    transfer = np.array([[2.0, 4.0], [8.0, 12.0]])
    eff, total, hidden = overlap_efficiency(compute, transfer)
    assert total == pytest.approx(6.0)
    assert hidden == pytest.approx(4.0)      # [2,4] fully + [8,10] of [8,12]
    assert eff == pytest.approx(4.0 / 6.0)


def test_overlap_efficiency_none_without_transfer():
    eff, total, hidden = overlap_efficiency(np.array([[0.0, 1.0]]),
                                            np.empty((0, 2)))
    assert eff is None and total == 0.0 and hidden == 0.0


def test_overlap_efficiency_zero_without_compute():
    eff, total, hidden = overlap_efficiency(np.empty((0, 2)),
                                            np.array([[0.0, 2.0]]))
    assert eff == 0.0 and total == 2.0 and hidden == 0.0


def test_window_efficiency_clips_to_window():
    tr = SpanTracer(capacity=64)
    # compute crosses the window start; transfer extends past the end
    tr.record(obs.LANE_COMPUTE, "c", 0.0, 6.0)
    tr.record(obs.LANE_POLICY_SWAP, "t", 4.0, 12.0)
    eff, total, hidden = window_efficiency(tr, 5.0, 10.0)
    assert total == pytest.approx(5.0)       # transfer clipped to [5,10]
    assert hidden == pytest.approx(1.0)      # compute covers [5,6] of it
    assert eff == pytest.approx(0.2)
    # transfer entirely outside the window -> no traffic -> None
    eff2, total2, _ = window_efficiency(tr, 20.0, 30.0)
    assert eff2 is None and total2 == 0.0


# ---------------------------------------------------------------- audit log
def test_audit_log_bounded_and_counted():
    log = AuditLog(capacity=8)
    for i in range(20):
        log.event("drift.classify", tier="reuse", i=i)
    log.event("policy.apply", policy_kind="baseline")
    s = log.stats()
    assert s["n_events"] == 21 and s["retained"] == 8
    assert log.counts() == {"drift.classify": 7, "policy.apply": 1}
    tail = log.tail(3, kind="drift.classify")
    assert [e["i"] for e in tail] == [17, 18, 19]
    assert all(e["seq"] for e in tail)


def test_audit_log_file_attach(tmp_path):
    p = str(tmp_path / "audit.jsonl")
    log = AuditLog(capacity=8, path=p)
    log.event("stage.transition", to="GenPolicy", step=3)
    log.event("drift.demote", why="match-miss")
    log.detach_file()
    lines = [json.loads(l) for l in open(p) if l.strip()]
    assert [e["kind"] for e in lines] == ["stage.transition", "drift.demote"]
    assert lines[0]["to"] == "GenPolicy"


# ---------------------------------------------------------- metrics registry
def test_metrics_counters_and_gauge_series():
    reg = MetricsRegistry(series_len=4)
    assert reg.counter("iters") == 1
    assert reg.counter("iters", 5) == 6
    for i in range(10):
        reg.gauge("eff", i / 10, t=float(i))
    snap = reg.snapshot()
    assert tuple(snap.keys()) == SNAPSHOT_KEYS
    assert snap["counters"]["iters"] == 6
    assert snap["gauges"]["eff"] == pytest.approx(0.9)
    assert len(snap["series"]["eff"]) == 4   # bounded by series_len
    assert snap["series"]["eff"][-1] == [9.0, 0.9] \
        or snap["series"]["eff"][-1] == (9.0, 0.9)


def test_metrics_provider_errors_are_contained():
    reg = MetricsRegistry()
    reg.register_provider("ok", lambda: {"x": np.int64(3)})
    reg.register_provider("bad", lambda: 1 / 0)
    snap = reg.snapshot()
    assert snap["providers"]["ok"] == {"x": 3}   # numpy made JSON-safe
    assert "error" in snap["providers"]["bad"]
    reg.register_provider("ok", lambda: {"x": 4})   # replace semantics
    assert reg.snapshot()["providers"]["ok"] == {"x": 4}
    reg.unregister_provider("bad")
    assert reg.provider_names() == ["ok"]


def test_metrics_jsonl_roundtrip(tmp_path):
    p = str(tmp_path / "m.jsonl")
    reg = MetricsRegistry()
    reg.counter("c")
    reg.gauge("g", 1.5)
    reg.write_jsonl(p)
    reg.write_jsonl(p)
    assert validate_metrics_jsonl(p) == {"snapshots": 2, "gauges": ["g"],
                                         "providers": []}
    assert validate_metrics_jsonl(p, require_gauges=("g",))["snapshots"] == 2
    with pytest.raises(ValueError, match="missing gauge"):
        validate_metrics_jsonl(p, require_gauges=("absent",))


# ------------------------------------------------------------ chrome export
def test_chrome_export_roundtrips_through_validator(tmp_path):
    tr = SpanTracer(capacity=256)
    tr.set_iteration(1)
    base = time.perf_counter()
    for i, lane in enumerate(obs.LANES):
        tr.record(lane, f"{lane}-work", base + i, base + i + 0.25,
                  arg=("tag", 123))
    tr.instant(obs.LANE_ADAPT, "stage:Stable", t=base + 9.0, arg=(7, "why"))
    p = str(tmp_path / "out.trace.json")
    obs.export_chrome_trace(
        p, tr,
        counters={"overlap_efficiency": [(base + 1.0, 0.5),
                                         (base + 2.0, 0.75)]},
        meta={"run": "unit"})
    obj = json.load(open(p))
    summary = validate_chrome_trace(obj, require_lanes=obs.LANES,
                                    require_counter="overlap_efficiency")
    assert summary["n_spans"] == len(obs.LANES)
    assert summary["n_instants"] == 1
    assert summary["counters"]["overlap_efficiency"] == 2
    assert obj["otherData"]["run"] == "unit"
    # every ts is normalized (non-negative) and spans carry their iter
    xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert all(e["ts"] >= 0 for e in xs)
    assert all(e["args"]["iter"] == 1 for e in xs)
    assert xs[0]["args"]["detail"] == ["tag", 123]


def test_validator_rejects_missing_lane():
    tr = SpanTracer(capacity=64)
    tr.record(obs.LANE_COMPUTE, "c", 0.0, 1.0)
    obj = {"traceEvents": obs.chrome_trace_events(tr)}
    with pytest.raises(ValueError, match="kv_spill"):
        validate_chrome_trace(obj, require_lanes=("compute", "kv_spill"))


# ------------------------------------------------------ global default swap
def test_global_defaults_swap_and_restore(fresh_obs):
    tr, reg, log = fresh_obs
    with obs.tracer().span(obs.LANE_COMPUTE, "x"):
        pass
    obs.metrics().counter("n")
    obs.audit().event("policy.apply")
    assert tr.stats()["n_spans"] == 1
    assert reg.snapshot()["counters"]["n"] == 1
    assert log.counts() == {"policy.apply": 1}


# ---------------------------------------------- format_summary crash-proofing
def test_format_summary_tolerates_cold_and_partial_stats():
    # entirely empty snapshot (engine never constructed)
    out = hm_metrics.format_summary({})
    assert "pool:" in out and "engine:" in out and "bwmodel:" in out
    # engine with no classes; bwmodel calibrated but zero points (the
    # regression: '%d points' used to assume points > 0 implied by the
    # calibrated flag)
    out = hm_metrics.format_summary({
        "pool": {"bytes_in_use": 0},
        "engine": {"n_out": 0, "classes": {}},
        "bwmodel": {"calibrated": True, "points": 0, "constant_gbps": 32.0},
    })
    assert "constant 32.0" in out
    # queued backlog renders depth + MiB
    out = hm_metrics.format_summary({
        "engine": {"classes": {"kv_spill": {
            "n_out": 2, "queued_bytes": 2 << 20, "queue_depth": 3}}},
    })
    assert "queued 3 (2.0 MiB)" in out


def test_format_summary_real_cold_tier():
    from repro.hostmem import HostMemTier
    tier = HostMemTier()
    out = hm_metrics.format_summary(hm_metrics.collect(tier))
    assert "pool:" in out and "bwmodel:" in out
