"""Unit tests for the roofline extraction machinery (HLO structural walk +
scan-aware jaxpr cost model) — these guard the numbers in EXPERIMENTS.md."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (collective_bytes, jaxpr_cost,
                                   model_flops_train, RooflineTerms)


SYNTH_HLO = """
%add.clone (x: f32[], y: f32[]) -> f32[] {
  ROOT %r = f32[] add(%x, %y)
}

%cond.1 (arg: (s32[], f32[128,256])) -> pred[] {
  %iv = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(24)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body.1 (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %x = f32[128,256]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[128,256]{1,0} all-reduce(%x), to_apply=%add.clone
  ROOT %t = (s32[], f32[128,256]) tuple(%iv, %ar)
}

ENTRY %main.1 (p0: f32[128,256]) -> f32[128,256] {
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[512,256]{1,0} all-gather(%p0), dimensions={0}
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_while_multiplier():
    out = collective_bytes(SYNTH_HLO)
    # all-reduce inside the 24-trip loop: 128*256*4 bytes * 2 (ring) * 24
    assert out["all-reduce"] == pytest.approx(128 * 256 * 4 * 2 * 24)
    # all-gather in main: result bytes * 1
    assert out["all-gather"] == pytest.approx(512 * 256 * 4)


def test_collective_bytes_no_collectives():
    assert collective_bytes("ENTRY %m (x: f32[4]) -> f32[4] {\n}") == {}


def test_jaxpr_cost_matmul():
    def f(a, b):
        return a @ b

    cj = jax.make_jaxpr(f)(jnp.ones((64, 128)), jnp.ones((128, 32)))
    fl, by = jaxpr_cost(cj)
    assert fl == pytest.approx(2 * 64 * 128 * 32)
    assert by == pytest.approx((64 * 128 + 128 * 32 + 64 * 32) * 4)


def test_jaxpr_cost_scan_multiplies():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]

    cj = jax.make_jaxpr(f)(jnp.ones((16, 64)), jnp.ones((10, 64, 64)))
    fl, _ = jaxpr_cost(cj)
    assert fl == pytest.approx(10 * 2 * 16 * 64 * 64)


def test_jaxpr_cost_counts_remat_recompute():
    def f(x, w):
        def g(x):
            return jnp.sum(jnp.tanh(x @ w) ** 2)
        return jax.grad(jax.checkpoint(g))(x)

    x, w = jnp.ones((32, 32)), jnp.ones((32, 32))
    fl_remat, _ = jaxpr_cost(jax.make_jaxpr(f)(x, w))

    def f2(x, w):
        return jax.grad(lambda x: jnp.sum(jnp.tanh(x @ w) ** 2))(x)

    fl_plain, _ = jaxpr_cost(jax.make_jaxpr(f2)(x, w))
    assert fl_remat > fl_plain  # the recompute is visible

def test_roofline_terms_bottleneck():
    t = RooflineTerms(
        flops_per_chip=197e12,       # 1 s compute
        bytes_per_chip=819e9 / 2,    # 0.5 s memory
        wire_bytes_per_chip=50e9 * 2,  # 2 s collective
        collectives={}, chips=256,
        model_flops=0.8 * 197e12 * 256).finalize()
    assert t.bottleneck == "collective"
    assert t.step_time_bound_s == pytest.approx(2.0)
    assert t.mfu_bound == pytest.approx(0.4)
    assert t.useful_flops_ratio == pytest.approx(0.8)


def test_model_flops():
    assert model_flops_train(10 ** 9, 10 ** 6) == 6e15
