"""Host-memory tier: pool alloc/free/reuse, engine completion ordering,
bandwidth-model curve, and the simulator's calibrated pricing."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import ChameleonConfig, HostMemConfig
from repro.hostmem import (BandwidthModel, HostMemError, HostMemTier,
                           PinnedSlabPool, TransferEngine)
from repro.hostmem.pool import size_class


# ------------------------------------------------------------------- pool
def test_pool_alloc_free_reuse():
    p = PinnedSlabPool()
    a = p.alloc(1000)
    assert a.class_bytes == size_class(1000) and a.nbytes == 1000
    p.free(a)
    b = p.alloc(700)                     # same 4 KiB class -> recycled slab
    assert b.class_bytes == a.class_bytes
    assert p.reuse_hits == 1 and p.slab_allocs == 1
    assert p.bytes_reserved == a.class_bytes
    p.check()


def test_pool_double_free_rejected():
    p = PinnedSlabPool()
    blk = p.alloc(64)
    p.free(blk)
    with pytest.raises(HostMemError):
        p.free(blk)


def test_pool_capacity_cap():
    p = PinnedSlabPool(capacity_bytes=1 << 14)
    p.alloc(1 << 13)
    with pytest.raises(HostMemError):
        p.alloc(1 << 14)                 # would exceed the cap
    # but a class that fits the remaining budget still succeeds
    p.alloc(1 << 12)


def test_pool_steady_state_zero_fresh_allocation():
    """After the first step touches every size, later steps are all hits."""
    p = PinnedSlabPool()
    sizes = [3 << 10, 70 << 10, 1 << 20, 5 << 20]
    for step in range(20):
        blocks = [p.alloc(s) for s in sizes]
        for b in blocks:
            p.free(b)
        if step == 0:
            fresh_after_warmup = p.slab_allocs
    assert p.slab_allocs == fresh_after_warmup   # zero fresh allocs later
    assert p.hit_rate > 0.9
    p.check()


def test_block_roundtrip_preserves_bits():
    p = PinnedSlabPool()
    arr = np.random.RandomState(0).randn(33, 7).astype(np.float32)
    blk = p.alloc(arr.nbytes).write(arr)
    out = blk.read()
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


@given(st.lists(st.integers(1, 1 << 20), min_size=1, max_size=60),
       st.lists(st.integers(0, 1 << 30), min_size=0, max_size=60))
@settings(max_examples=30, deadline=None)
def test_pool_never_double_books(sizes, free_picks):
    """Property: live bytes are exact, slab bytes never leak, every free
    returns the slab to a free list, and no two live blocks share a slab."""
    p = PinnedSlabPool()
    live = []
    picks = iter(free_picks)
    for s in sizes:
        live.append(p.alloc(s))
        k = next(picks, None)
        if k is not None and live and k % 3 == 0:    # interleave frees
            p.free(live.pop(k % len(live)))
    addrs = [b.data.ctypes.data for b in live]
    assert len(addrs) == len(set(addrs)), "two live blocks share a slab"
    assert p.bytes_in_use == sum(b.nbytes for b in live)
    p.check()
    n_free_before = sum(len(v) for v in p._free.values())
    for b in list(live):
        p.free(b)
    assert p.bytes_in_use == 0 and p.live_blocks == 0
    assert (sum(len(v) for v in p._free.values())
            == n_free_before + len(live)), "free didn't return to free list"
    p.check()


# ----------------------------------------------------------------- engine
def test_engine_fifo_completion_and_double_buffer():
    tier = HostMemTier(HostMemConfig(engine_depth=2))
    eng = tier.engine
    arrs = [np.full(256, i, np.float32) for i in range(5)]
    evs = [eng.submit_swap_out(a, f"t{i}") for i, a in enumerate(arrs)]
    # depth=2 window: submitting 5 forces the first 3 to retire, in order
    assert [e.done for e in evs] == [True, True, True, False, False]
    assert eng.forced_retires == 3
    eng.wait(evs[3])
    assert evs[3].done and not evs[4].done
    eng.synchronize()
    assert all(e.done for e in evs)
    # staged bytes round-trip through swap-in, FIFO again
    back = [eng.wait(eng.submit_swap_in(e)) for e in evs]
    for a, ev in zip(arrs, back):
        np.testing.assert_array_equal(np.asarray(ev.result), a)
    assert eng.n_out == 5 and eng.n_in == 5


def test_engine_release_point_drops_device_ref():
    tier = HostMemTier()
    eng = tier.engine
    a = np.ones(1024, np.float32)
    ev = eng.submit_swap_out(a, "resid")
    assert ev._source is a               # held until the copy retires
    eng.wait(ev)
    assert ev._source is None            # recordStream analogue: released


def test_engine_planned_release_tags():
    tier = HostMemTier()
    tier.engine.plan_release("ffn_pre:3:17", 412)
    ev = tier.engine.submit_swap_out(np.zeros(64, np.uint8), "ffn_pre:3:17")
    assert ev.release_op == 412


def test_engine_completion_callbacks_order():
    tier = HostMemTier(HostMemConfig(engine_depth=1))
    order = []
    for i in range(4):
        ev = tier.engine.submit_swap_out(np.zeros(128, np.uint8), f"t{i}")
        ev.on_done(lambda e: order.append(e.tag))
    tier.engine.synchronize()
    assert order == ["t0", "t1", "t2", "t3"]


# ---------------------------------------------------------------- bwmodel
def test_bwmodel_uncalibrated_equals_constant():
    m = BandwidthModel(32.0)
    assert not m.is_calibrated
    assert m.transfer_time(1 << 30) == pytest.approx((1 << 30) / 32e9)


def test_bwmodel_curve_interpolation():
    m = BandwidthModel(32.0)
    m.observe(1 << 16, 1e-4)             # latency-bound point
    m.observe(1 << 26, 4e-3)             # bandwidth-bound point
    assert m.is_calibrated
    assert m.transfer_time(1 << 10) == pytest.approx(1e-4)   # latency floor
    t_mid = m.transfer_time(1 << 21)     # geometric midpoint in log-size
    assert 1e-4 < t_mid < 4e-3
    # above the sweep: scales linearly with the top point's bandwidth
    assert m.transfer_time(1 << 27) == pytest.approx(8e-3)
    # monotone over the measured range
    ts = [m.transfer_time(1 << p) for p in range(16, 27)]
    assert all(a <= b + 1e-12 for a, b in zip(ts, ts[1:]))


def test_bwmodel_roundtrip_serialization():
    m = BandwidthModel(24.0)
    m.observe(1 << 16, 2e-4)
    m.observe(1 << 20, 5e-4)
    m2 = BandwidthModel.from_dict(m.to_dict())
    assert m2.is_calibrated
    assert m2.transfer_time(1 << 18) == pytest.approx(m.transfer_time(1 << 18))


def test_engine_observations_feed_bwmodel():
    tier = HostMemTier()
    assert not tier.bwmodel.is_calibrated
    for sz in (1 << 16, 1 << 20, 1 << 22):
        tier.engine.wait(tier.engine.submit_swap_out(np.zeros(sz, np.uint8)))
    assert tier.bwmodel.is_calibrated    # online samples calibrated it


# ------------------------------------------- simulator consumes the curve
def _toy_profile():
    from repro.core.profiler import ProfileData, TensorInstance
    tensors = [TensorInstance(i, 1 << 20, i, 100 - i, site="ffn_pre",
                              layer=i) for i in range(10)]
    return ProfileData(np.zeros(100, np.int32), tensors, 1.0, 0)


def test_simulator_prices_with_calibrated_curve():
    from repro.core.simulator import Simulator
    prof = _toy_profile()
    cfg = ChameleonConfig(groups_per_phase=8)
    # measured curve says the link is 100x slower than the constant claims
    bw = BandwidthModel(cfg.host_link_gbps)
    slow = 100 * (1 << 20) / (cfg.host_link_gbps * 1e9)
    bw.observe(1 << 16, slow / 16)
    bw.observe(1 << 20, slow)
    sim_const = Simulator(prof, 50, cfg)
    sim_meas = Simulator(prof, 50, cfg, bwmodel=bw)
    t_const, t_meas = sim_const.t_swap(1 << 20), sim_meas.t_swap(1 << 20)
    assert t_meas == pytest.approx(slow)
    assert t_meas > 50 * t_const
    # uncalibrated model falls back to the constant exactly
    sim_fallback = Simulator(prof, 50, cfg, bwmodel=BandwidthModel(
        cfg.host_link_gbps))
    assert sim_fallback.t_swap(1 << 20) == pytest.approx(t_const)


def test_policy_free_time_handoff(llama_profile):
    from repro.core.memtrace import build_timeline
    from repro.core.policy import generate_policy
    prof, _ = llama_profile
    tl = build_timeline(prof)
    tier = HostMemTier()
    pol = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                          int(tl.peak * 0.7), timeline=tl,
                          engine=tier.engine)
    assert pol.entries
    planned = tier.engine.planned_releases()
    assert len(planned) == len(pol.entries)
    for e in pol.entries:
        assert planned[pol.entry_tag(e)] == e.swap_out_done_op
        assert e.swap_out_done_op >= 0


def test_runtime_handoff_on_best_variant(llama_profile):
    """Only the *winning* GenPolicy variant's free-times reach the engine;
    losing variants must not leave stale release points behind."""
    from repro.core.memtrace import build_timeline
    from repro.core.policy import generate_policy
    from repro.core.runtime import ChameleonRuntime, PolicyVariant
    prof, _ = llama_profile
    tl = build_timeline(prof)
    rt = ChameleonRuntime(ChameleonConfig(), lambda pol: (lambda x: x))
    win = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                          int(tl.peak * 0.7), timeline=tl)
    lose = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                           int(tl.peak * 0.75), timeline=tl)
    applied = rt.executor.baseline()
    rt.variants = [PolicyVariant(applied, lose, 0.5, measured_t=2.0),
                   PolicyVariant(applied, win, 1.0, measured_t=1.0)]
    rt._select_best()
    assert rt.best.swap is win
    planned = rt.hostmem.engine.planned_releases()
    assert len(planned) == len(win.entries)
    for e in win.entries:
        assert planned[win.entry_tag(e)] == e.swap_out_done_op


def test_runtime_stats_surface_hostmem():
    from repro.core.runtime import ChameleonRuntime
    rt = ChameleonRuntime(ChameleonConfig(), lambda pol: (lambda x: x))
    s = rt.stats()
    assert s["hostmem"] is not None
    assert set(s["hostmem"]) >= {"pool", "engine", "bwmodel"}
    rt2 = ChameleonRuntime(
        ChameleonConfig(hostmem=HostMemConfig(enabled=False)),
        lambda pol: (lambda x: x))
    assert rt2.stats()["hostmem"] is None
