"""Memory-ledger tests (ISSUE 9): realized-occupancy replay parity with
``projected_peak``, byte-conservation under the fast chaos scenario, the
``memory`` health-class pressure path, counter-track export validation,
and a ``repro.obs.report`` smoke test."""
import json
import os
from types import SimpleNamespace

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import faults, obs
from repro.common.config import ResilienceConfig
from repro.core.policy import projected_peak
from repro.faults.health import DEGRADED, HEALTHY, MEM_CLASS, HealthMonitor
from repro.faults.plan import FaultPlan, FaultSpec
from repro.hostmem.engine import TC_POLICY_SWAP, TransferEngine
from repro.hostmem.pool import PinnedSlabPool
from repro.obs.memledger import LEDGER_TRACKS, MemoryLedger
from repro.obs.report import main as report_main


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Isolated obs singletons per test (and faults disarmed)."""
    faults.disarm()
    old_l = obs.set_ledger(MemoryLedger())
    old_m = obs.set_metrics(obs.MetricsRegistry())
    old_a = obs.set_audit(obs.AuditLog())
    yield
    faults.disarm()
    obs.set_ledger(old_l)
    obs.set_metrics(old_m)
    obs.set_audit(old_a)


# ----------------------------------------------------- fake profile bits
def _tensor(uid, birth, death, nbytes, layer=0, site="act"):
    return SimpleNamespace(uid=uid, birth=birth, death=death,
                           nbytes=nbytes, layer=layer, site=site)


def _profile(tensors, n_ops, static=1000):
    return SimpleNamespace(tensors=list(tensors), n_ops=n_ops,
                           static_bytes=static)


def _entry(t, out_op, in_op):
    return SimpleNamespace(uid=t.uid, layer=t.layer, site=t.site,
                           nbytes=t.nbytes, birth=t.birth,
                           swap_out_done_op=out_op, swap_in_op=in_op)


def _tag(e):
    return f"{e.site or 'tensor'}:{e.layer}:{e.uid}"


def _swap(prof, entries):
    return SimpleNamespace(entries=list(entries),
                           projected_peak=projected_peak(prof, entries))


def _scenario():
    """Three overlapping tensors; the two swap entries' off-device
    windows cover the baseline peak, so the policy genuinely lowers it
    (and a failed swap-out genuinely raises the realized peak back)."""
    ts = [_tensor(1, 0, 10, 4096), _tensor(2, 1, 9, 8192),
          _tensor(3, 3, 7, 2048)]
    prof = _profile(ts, n_ops=10)
    entries = [_entry(ts[1], out_op=2, in_op=8),
               _entry(ts[2], out_op=4, in_op=6)]
    return prof, ts, entries


# -------------------------------------------------------- replay parity
def test_realized_equals_projected_when_observed_on_plan():
    prof, _, entries = _scenario()
    swap = _swap(prof, entries)
    led = obs.ledger()
    for e in entries:
        led.note_transfer("out", TC_POLICY_SWAP, _tag(e), e.nbytes,
                          release_op=e.swap_out_done_op)
    rec = led.close_iteration(1, profile=prof, swap=swap,
                              budget=swap.projected_peak * 2)
    assert rec["realized_peak"] == swap.projected_peak
    assert rec["peak_error"] == 0.0
    assert rec["n_observed"] == 2 and rec["n_failed"] == 0
    assert rec["conservation"]["ok"]
    assert 0.4 < rec["headroom_frac"] <= 0.5


def test_unobserved_entries_fall_back_to_planned_windows():
    prof, _, entries = _scenario()
    swap = _swap(prof, entries)
    rec = obs.ledger().close_iteration(1, profile=prof, swap=swap)
    assert rec["realized_peak"] == swap.projected_peak
    assert rec["n_unobserved"] == 2


def test_failed_swap_out_retained_in_hbm_raises_realized_peak():
    prof, _, entries = _scenario()
    swap = _swap(prof, entries)
    led = obs.ledger()
    led.note_transfer("out", TC_POLICY_SWAP, _tag(entries[1]),
                      entries[1].nbytes, release_op=entries[1].swap_out_done_op)
    led.note_transfer("out", TC_POLICY_SWAP, _tag(entries[0]),
                      entries[0].nbytes, failed=True)
    rec = led.close_iteration(1, profile=prof, swap=swap,
                              budget=swap.projected_peak)
    assert rec["n_failed"] == 1
    assert rec["realized_peak"] > swap.projected_peak
    assert rec["peak_error"] > 0.0
    assert rec["headroom_frac"] < 0.0          # overshot the budget
    assert not rec["conservation"]["ok"]
    reasons = {s["reason"] for s in rec["conservation"]["suspects"]}
    assert "swap_out_failed" in reasons
    # the failed tensor shows up resident in the peak attribution
    assert any(a["tag"] == _tag(entries[0]) for a in rec["attribution"])


def test_attribution_names_topk_resident_tensors():
    prof, ts, entries = _scenario()
    rec = obs.ledger().close_iteration(1, profile=prof,
                                       swap=_swap(prof, entries))
    tags = [a["tag"] for a in rec["attribution"]]
    assert _tag(_entry(ts[0], 0, 0)) in tags   # never swapped: resident
    assert rec["attribution"] == sorted(rec["attribution"],
                                        key=lambda a: -a["nbytes"])


def test_scoreboard_aggregates_and_gauges():
    prof, _, entries = _scenario()
    swap = _swap(prof, entries)
    led = obs.ledger()
    for step in range(3):
        led.close_iteration(step, profile=prof, swap=swap,
                            budget=swap.projected_peak * 2)
    sb = led.scoreboard()
    assert sb["n"] == 3 and sb["max_abs_error"] == 0.0
    snap = obs.metrics().snapshot()
    assert "memory.realized_peak" in snap["gauges"]
    assert "memory.peak_error" in snap["gauges"]
    assert obs.audit().counts().get("memory.peak") == 3
    stats = led.stats()
    assert stats["iterations"] == 3
    assert stats["scoreboard"]["n"] == 3


# ------------------------------------------------- engine-fed conservation
def _engine(**rs_kw):
    pool = PinnedSlabPool()
    rs = ResilienceConfig(retry_backoff_s=0.0, **rs_kw)
    return pool, TransferEngine(pool, resilience=rs,
                                device_put=lambda a: np.asarray(a))


def _roundtrips(eng, n, nbytes=2048):
    for i in range(n):
        ev = eng.submit_swap_out(np.full(nbytes, i % 251, np.uint8),
                                 f"t:{i}")
        eng.wait(eng.submit_swap_in(ev, f"t:{i}"))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_conservation_holds_across_fast_chaos(seed):
    """The fast chaos scenario (everywhere-scatter, same shape as
    ``benchmarks/chaos_bench.py --fast``): the pool's byte ledger stays
    balanced every iteration, and only *terminal* transfer failures can
    appear as suspects — a clean iteration reports none."""
    faults.disarm()
    led = obs.set_ledger(MemoryLedger())
    try:
        pool, eng = _engine()
        plan = FaultPlan.everywhere(seed=seed, prob=0.2, seconds=0.0)
        with faults.injected(plan):
            for it in range(4):
                faults.tick(it)
                try:
                    _roundtrips(eng, 3)
                except Exception:
                    pass               # terminal H2D losses surface; fine
                rec = obs.ledger().close_iteration(
                    it, pool_stats=pool.stats())
                cons = rec["conservation"]
                # pool alloc/free byte accounting must balance even with
                # injected alloc failures and terminal transfer faults
                assert not any(s["reason"] == "pool_imbalance"
                               for s in cons["suspects"])
                pool.check()
        # clean epilogue: with faults disarmed, no new suspects appear
        _roundtrips(eng, 3)
        rec = obs.ledger().close_iteration(99, pool_stats=pool.stats())
        assert rec["conservation"]["ok"]
        pool.check()
    finally:
        obs.set_ledger(led)


def test_clean_run_has_no_leak_suspects():
    pool, eng = _engine()
    _roundtrips(eng, 8)
    rec = obs.ledger().close_iteration(1, pool_stats=pool.stats())
    assert rec["conservation"]["ok"]
    assert obs.ledger().n_leak_suspects == 0
    assert pool.stats()["peak_bytes_in_use"] > 0
    assert pool.stats()["bytes_alloc_total"] == pool.stats()[
        "bytes_freed_total"]


def test_injected_drop_fault_is_flagged_as_leak_suspect():
    pool, eng = _engine()
    plan = FaultPlan([FaultSpec("engine.transfer_drop", prob=1.0)], seed=7)
    with faults.injected(plan):
        ev = eng.submit_swap_out(np.ones(4096, np.uint8), "victim")
        eng.wait(ev)
    assert ev.failed                       # terminal: retained in HBM
    rec = obs.ledger().close_iteration(1, pool_stats=pool.stats())
    assert not rec["conservation"]["ok"]
    suspects = rec["conservation"]["suspects"]
    assert any(s["tag"].startswith("victim")
               and s["reason"] == "swap_out_failed" for s in suspects)
    assert obs.audit().counts().get("memory.leak_suspect") == 1
    pool.check()                           # the slab itself was recycled


# ------------------------------------------------ memory health pressure
def test_memory_pressure_degrades_and_recovers():
    hm = HealthMonitor([MEM_CLASS], degrade_score=2.0, fail_score=6.0,
                       recover_successes=8, decay=0.7)
    assert hm.worst() == HEALTHY
    for _ in range(6):                     # sustained mild margin erosion
        hm.note_pressure(MEM_CLASS, severe=False)
    assert hm.state(MEM_CLASS) == DEGRADED
    assert hm.links[MEM_CLASS].n_pressure == 6
    for _ in range(20):                    # comfortable iterations decay it
        hm.note_success(MEM_CLASS)
    assert hm.state(MEM_CLASS) == HEALTHY


def test_severe_pressure_scores_like_an_error():
    hm = HealthMonitor([MEM_CLASS], degrade_score=2.0)
    hm.note_pressure(MEM_CLASS, severe=True)
    hm.note_pressure(MEM_CLASS, severe=True)
    assert hm.state(MEM_CLASS) == DEGRADED


def test_engine_health_includes_memory_class():
    _, eng = _engine()
    assert MEM_CLASS in eng.health.links
    assert eng.health.worst() == HEALTHY


# -------------------------------------------- export + validate + report
def test_counter_tracks_export_passes_validator(tmp_path):
    prof, _, entries = _scenario()
    led = obs.ledger()
    led.close_iteration(1, profile=prof, swap=_swap(prof, entries),
                        pool_stats={"bytes_in_use": 512,
                                    "bytes_alloc_total": 512,
                                    "bytes_freed_total": 0})
    tracks = led.counter_tracks()
    assert set(tracks) == set(LEDGER_TRACKS)
    assert all(tracks[name] for name in LEDGER_TRACKS)
    path = str(tmp_path / "t.trace.json")
    obs.export_chrome_trace(path, obs.tracer(), counters=tracks)
    with open(path) as f:
        summary = obs.validate_chrome_trace(
            json.load(f), require_counters=LEDGER_TRACKS)
    for name in LEDGER_TRACKS:
        assert summary["counters"][name] >= 1
    with pytest.raises(ValueError, match="no 'nope' counter"):
        with open(path) as f:
            obs.validate_chrome_trace(json.load(f),
                                      require_counters=("nope",))


def test_metrics_validator_checks_gauges_and_providers(tmp_path):
    prof, _, entries = _scenario()
    obs.metrics().register_provider("memory",
                                    lambda: obs.ledger().stats())
    obs.ledger().close_iteration(1, profile=prof,
                                 swap=_swap(prof, entries))
    path = str(tmp_path / "m.jsonl")
    obs.metrics().write_jsonl(path)
    ms = obs.validate_metrics_jsonl(
        path, require_gauges=("memory.realized_peak", "memory.peak_error"),
        require_providers=("memory",))
    assert ms["snapshots"] == 1
    with pytest.raises(ValueError, match="missing provider"):
        obs.validate_metrics_jsonl(path, require_providers=("absent",))


def test_report_cli_renders_postmortem_and_gates(tmp_path, capsys):
    prof, _, entries = _scenario()
    swap = _swap(prof, entries)
    led = obs.ledger()
    obs.metrics().register_provider("memory", lambda: led.stats())
    audit_path = str(tmp_path / "a.jsonl")
    obs.audit().attach_file(audit_path)
    for e in entries:
        led.note_transfer("out", TC_POLICY_SWAP, _tag(e), e.nbytes,
                          release_op=e.swap_out_done_op)
    led.close_iteration(1, profile=prof, swap=swap,
                        budget=swap.projected_peak * 2)
    trace = str(tmp_path / "t.trace.json")
    obs.export_chrome_trace(trace, obs.tracer(),
                            counters=led.counter_tracks())
    metrics = str(tmp_path / "m.jsonl")
    obs.metrics().write_jsonl(metrics)
    obs.audit().detach_file()
    out_md = str(tmp_path / "report.md")
    out_js = str(tmp_path / "report.json")
    rc = report_main(["--trace", trace, "--metrics", metrics,
                      "--audit", audit_path, "--out", out_md,
                      "--json", out_js, "--check-peak-error", "0.10"])
    assert rc == 0
    md = open(out_md).read()
    assert "# Run post-mortem" in md
    assert "predicted vs realized" in md
    rep = json.load(open(out_js))
    assert rep["memory"]["max_abs_peak_error"] == 0.0
    assert set(rep["trace"]["ledger_tracks_present"]) == set(LEDGER_TRACKS)
    assert rep["audit"]["memory"].get("memory.peak") == 1


def test_report_gate_fails_without_scored_iterations(tmp_path, capsys):
    # snapshots exist but carry no memory.peak_error series — the gate
    # must fail loudly instead of passing on a run that never scored
    metrics = str(tmp_path / "m.jsonl")
    obs.metrics().gauge("overlap_efficiency", 0.9)
    obs.metrics().write_jsonl(metrics)
    rc = report_main(["--metrics", metrics, "--out",
                      str(tmp_path / "r.md"), "--check-peak-error", "0.10"])
    assert rc == 2
    assert "no memory.peak_error points" in capsys.readouterr().err


def test_runtime_mirrored_iterations_score_zero_error(llama_profile):
    """End-to-end through the runtime: the executed policy's mirrored
    policy_swap traffic feeds the ledger, and a clean iteration (every
    D2H retires at its promised release op) scores realized ==
    ``SwapPolicy.projected_peak`` — error exactly 0."""
    from repro.common.config import ChameleonConfig
    from repro.core.memtrace import build_timeline
    from repro.core.policy import generate_policy
    from repro.core.runtime import ChameleonRuntime

    prof, _ = llama_profile
    tl = build_timeline(prof)
    rt = ChameleonRuntime(ChameleonConfig(), lambda pol: (lambda x: x))
    pol = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                          int(tl.peak * 0.7), timeline=tl)
    rt.applied = rt.executor.lower(pol, prof)
    rt.executor.bind_release_points(rt.applied, rt.hostmem.engine)
    rt.profile = prof
    for _ in range(3):
        rt.end_iteration(0.01)
    led = obs.ledger()
    assert led.n_iterations == 3
    last = led.last()
    assert last["realized_peak"] == pol.projected_peak
    assert last["peak_error"] == 0.0
    assert last["n_failed"] == 0
    assert last["conservation"]["ok"]       # mirror slabs all recycled
    sb = led.scoreboard()
    assert sb["n"] == 3 and sb["max_abs_error"] == 0.0
    assert rt.stats()["obs"]["memory"]["iterations"] == 3
    # the counter tracks carry points for all four lanes after a real run
    tracks = led.counter_tracks()
    assert all(tracks[name] for name in LEDGER_TRACKS)
