"""Monitoring hot-path tests (ISSUE 5).

Three families:

  * **parity** — the vectorized implementations must produce *identical*
    results to their kept reference implementations on randomized inputs
    (``match_instances`` vs ``match_instances_reference``, incremental
    ``SignatureAccumulator`` vs from-scratch concat+bincount, LSH-probed
    ``nearest`` vs ``nearest_exhaustive``);
  * **satellites** — the scan-replication cap no longer hides deep-scan
    layer-count changes (virtual length stays exact), and degenerate
    token ids cannot size histogram buffers;
  * **guards** — deterministic operation-count invariants for CI: the
    signature update does work proportional to *changed* dispatches, and
    ``nearest`` at 1k records evaluates far fewer similarities than the
    record count (probe count ≪ records).  These are counters, not
    wall-clock, so they hold on shared runners.
"""
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import ChameleonConfig, PolicyStoreConfig
from repro.core import tokenizer
from repro.core.matching import (candidate_feature_arrays, match_instances,
                                 match_instances_reference)
from repro.core.profiler import ProfileData, TensorInstance
from repro.core.simulator import Simulator
from repro.core.stages import Stage, StageMachine
from repro.policystore import (LSHIndex, PolicyRecord, PolicyStore,
                               fingerprint_tokens)

from tests.test_simulator_policy import synth_profile

SITES = ("attn_out", "ffn_pre", "resid_post", "qkv_proj", "moe_gate")


# ------------------------------------------------------------------ helpers
def _rand_profile(seed, n_sites, n_layers, per, jitter, dtype_seed):
    r = np.random.RandomState(seed)
    tensors = []
    uid = 0
    n_ops = max(n_sites * n_layers * per, 1)
    for s in range(n_sites):
        shape = (32 + s, 8 * (1 + s % 3))
        for l in range(n_layers):
            birth = min((s * n_layers + l) * per
                        + int(r.randint(0, jitter + 1)), n_ops - 1)
            tensors.append(TensorInstance(
                uid, 1 << 16, birth, n_ops - birth, site=SITES[s % len(SITES)],
                layer=l, dtype_code=1 + (s + dtype_seed) % 3, shape=shape))
            uid += 1
    # a few duplicate-feature instances exercise the greedy bucket order
    for extra in range(min(n_layers, 3)):
        t = tensors[extra]
        tensors.append(TensorInstance(
            uid, t.nbytes, min(t.birth + 1, n_ops - 1), t.death,
            site=t.site, layer=t.layer, dtype_code=t.dtype_code,
            shape=t.shape))
        uid += 1
    return ProfileData(np.zeros(n_ops, np.int32), tensors, 1.0, 0)


def _record(fp, kind="conservative"):
    return PolicyRecord.from_policy(
        fingerprint=fp, prepare_fingerprint=fp, swap=None, candidates=[],
        n_ops=max(fp.length, 1), knob=1.0, measured_t=0.1, budget=1 << 30,
        policy_kind=kind)


def _assert_match_parity(old, new, tol=16):
    a = match_instances_reference(old, new, tol)
    b = match_instances(old, new, tol)
    assert a.mapping == b.mapping
    assert a.unmatched == b.unmatched
    assert a.moved == b.moved


# ----------------------------------------------------- matching: parity
@given(st.integers(0, 10_000), st.integers(1, 5), st.integers(1, 10),
       st.integers(2, 16), st.integers(0, 30))
@settings(max_examples=40, deadline=None)
def test_match_parity_random_pairs(seed, n_sites, n_layers, per, jitter):
    old = _rand_profile(seed, n_sites, n_layers, per, jitter=0, dtype_seed=0)
    new = _rand_profile(seed + 1, n_sites, n_layers, per + 1, jitter=jitter,
                        dtype_seed=0)
    _assert_match_parity(old, new)


@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_match_parity_structural_drift(seed, n_sites, n_layers):
    """Dtype changes, layer-count changes, and empty sides must agree with
    the reference too (all-unmatched cases included)."""
    old = _rand_profile(seed, n_sites, n_layers, 8, 0, dtype_seed=0)
    new = _rand_profile(seed, n_sites, max(n_layers - 1, 1), 8, 2,
                        dtype_seed=1)     # shifted dtype codes
    _assert_match_parity(old, new)
    empty = ProfileData(np.zeros(4, np.int32), [], 1.0, 0)
    _assert_match_parity(old, empty)
    _assert_match_parity(empty, new)


def test_match_tolerance_zero_and_features_cached():
    old = _rand_profile(3, 3, 6, 10, 0, 0)
    new = _rand_profile(4, 3, 6, 10, 5, 0)
    _assert_match_parity(old, new, tol=0)
    feats = candidate_feature_arrays(old)
    assert candidate_feature_arrays(old) is feats   # lazily cached
    assert old.feature_arrays() is feats


def test_feature_cache_dropped_on_tensor_replacement():
    """dryrun's per-chip rescale shallow-copies the profile and replaces
    ``tensors``; the derived candidate/feature caches must not leak the
    old (unscaled) instances through the copy."""
    import copy
    prof = _rand_profile(5, 2, 4, 8, 0, 0)
    _ = prof.candidates                     # populate caches
    prof.feature_arrays()
    prof2 = copy.copy(prof)
    prof2.tensors = prof.tensors[:3]
    assert len(prof2.candidates) == 3
    assert prof2.feature_arrays().n == 3
    assert len(prof.candidates) == len(prof.tensors)  # original intact


# ------------------------------------------ incremental signature: parity
@st.composite
def _stream_lists(draw):
    n = draw(st.integers(1, 5))
    return [draw(st.lists(st.integers(1, 30), min_size=0, max_size=120))
            for _ in range(n)]


@given(_stream_lists(), _stream_lists())
@settings(max_examples=40, deadline=None)
def test_signature_accumulator_matches_scratch(lists_a, lists_b):
    acc = tokenizer.SignatureAccumulator()
    for lists in (lists_a, lists_b, lists_a):
        streams = [tokenizer.TokenStream(np.asarray(l, np.int32))
                   for l in lists]
        sig = acc.update(streams)
        concat = (np.concatenate([np.asarray(l, np.int32) for l in lists])
                  if any(lists) else np.zeros(0, np.int32))
        assert sig.length == concat.size
        ref_hist = tokenizer.token_histogram(concat)
        m = max(sig.hist.size, ref_hist.size)
        np.testing.assert_array_equal(
            np.pad(sig.hist, (0, m - sig.hist.size)),
            np.pad(ref_hist, (0, m - ref_hist.size)))
        np.testing.assert_array_equal(sig.materialize(), concat)


@given(_stream_lists(), _stream_lists())
@settings(max_examples=30, deadline=None)
def test_sig_similarity_matches_legacy(lists_a, lists_b):
    sa = tokenizer.Signature.from_tokens(np.concatenate(
        [np.asarray(l, np.int32) for l in lists_a] or [np.zeros(0, np.int32)]))
    sb = tokenizer.Signature.from_tokens(np.concatenate(
        [np.asarray(l, np.int32) for l in lists_b] or [np.zeros(0, np.int32)]))
    ld_sig, cos_sig = tokenizer.sig_similarity(sa, sb)
    ld_ref, cos_ref = tokenizer.similarity(sa.materialize(), sb.materialize())
    assert ld_sig == pytest.approx(ld_ref, abs=1e-12)
    assert cos_sig == pytest.approx(cos_ref, abs=1e-12)


def test_stage_machine_accepts_signatures():
    cfg = ChameleonConfig(m_warmup_stable=1, n_genpolicy_steps=1)
    sm = StageMachine(cfg)
    acc = tokenizer.SignatureAccumulator()
    s = tokenizer.TokenStream(np.array([1, 2, 3] * 50, np.int32))
    for i in range(6):
        sm.observe(acc.update([s]), i)
    assert sm.stage is Stage.STABLE
    grown = tokenizer.TokenStream(
        np.array([1, 2, 3] * 50 + [7, 8, 9] * 30, np.int32))
    assert sm.observe(acc.update([grown]), 6) is Stage.WARMUP


# ------------------------------------------------ satellite: scan-cap fix
def test_virtual_length_sees_capped_scan_growth():
    """80 -> 96 scanned layers materialize identically (both capped at
    REPEAT_CAP copies) but the virtual length must still expose the 20%
    growth to Lightweight length-diff detection."""
    def make(n):
        def f(x):
            return jax.lax.scan(lambda c, _: (jnp.tanh(c @ c.T) @ c, None),
                                x, None, length=n)[0]
        return tokenizer.tokenize_jaxpr_stream(
            jax.make_jaxpr(f)(jnp.ones((4, 4))))

    s80, s96 = make(80), make(96)
    np.testing.assert_array_equal(s80.tokens, s96.tokens)   # cap collides
    assert s96.virtual_len > s80.virtual_len
    assert s96.virtual_len / s80.virtual_len == pytest.approx(96 / 80,
                                                              rel=0.05)
    assert s80.content_hash != s96.content_hash
    acc = tokenizer.SignatureAccumulator()
    a = acc.update([s80])
    b = acc.update([s96])
    len_diff, _cos = tokenizer.sig_similarity(a, b)
    assert len_diff >= 0.05        # Algo 1 must see the change

    sm = StageMachine(ChameleonConfig(m_warmup_stable=1,
                                      n_genpolicy_steps=1))
    acc2 = tokenizer.SignatureAccumulator()
    for i in range(6):
        sm.observe(acc2.update([s80]), i)
    assert sm.stage is Stage.STABLE
    assert sm.observe(acc2.update([s96]), 6) is Stage.WARMUP


def test_iteration_fingerprint_sees_capped_scan_growth():
    """The policystore iteration fingerprint must carry the virtual
    accounting too: 80 vs 96 deep-scan layers materialize identically
    under REPEAT_CAP, but their fingerprints must neither share an exact
    hash nor score reuse-grade (the length gate must see 80/96)."""
    from repro.policystore import fingerprint_signature, similarity

    def make(n):
        def f(x):
            return jax.lax.scan(lambda c, _: (jnp.tanh(c @ c.T) @ c, None),
                                x, None, length=n)[0]
        acc = tokenizer.SignatureAccumulator()
        return acc.update([tokenizer.tokenize_jaxpr_stream(
            jax.make_jaxpr(f)(jnp.ones((4, 4))))])

    s80, s96 = make(80), make(96)
    np.testing.assert_array_equal(s80.materialize(), s96.materialize())
    fp80 = fingerprint_signature(s80, cache=False)
    fp96 = fingerprint_signature(s96, cache=False)
    assert fp80.exact != fp96.exact
    assert fp80.length == len(s80) and fp96.length == len(s96)
    assert similarity(fp80, fp96) < 1.0
    from repro.policystore import length_ratio
    cfg = PolicyStoreConfig()
    assert length_ratio(fp80, fp96) < cfg.reuse_len_ratio  # no reuse tier

    # an *uncapped* signature still exact-matches the plain token form,
    # so iteration fingerprints keep hitting prepare fingerprints
    small = tokenizer.Signature.from_tokens(
        np.array([1, 2, 3, 4] * 10, np.int32))
    assert (fingerprint_signature(small, cache=False).exact
            == fingerprint_tokens(small.materialize(), cache=False).exact)


def test_degenerate_token_ids_bounded():
    """Huge token ids must not size the histogram by the largest id."""
    a = np.array([1, 2, (1 << 31) - 5], np.int64)
    b = np.array([1, 2, 3], np.int64)
    ld, cos = tokenizer.similarity(a, b)
    assert 0.0 <= ld <= 1.0 and 0.0 <= cos <= 1.0
    hist = tokenizer.token_histogram(a)
    assert hist.size <= tokenizer.MAX_DENSE_TOKEN + 1


# ----------------------------------------------------- LSH: recall/parity
@pytest.fixture
def lsh_store():
    rng = np.random.RandomState(42)
    store = PolicyStore(PolicyStoreConfig(max_records=256))
    streams = []
    for i in range(120):
        t = rng.randint(1, 50, size=300 + (i % 7) * 10).astype(np.int32)
        streams.append(t)
        store.put(_record(fingerprint_tokens(t, cache=False)))
    return store, streams


def test_lsh_nearest_recall_above_floor(lsh_store):
    """Every perturbed recurrence of a stored stream must be found at a
    similarity no worse than the exhaustive scan reports (recall 1.0 above
    the floor); below the reuse floor the result is *identical*."""
    store, streams = lsh_store
    cfg = store.cfg
    rng = np.random.RandomState(7)
    found = total = 0
    for i in range(0, 120, 5):
        base = streams[i]
        q = fingerprint_tokens(
            np.concatenate([base, base[: rng.randint(0, 8)]]), cache=False)
        rec, sim = store.nearest(q)
        ex_rec, ex_sim = store.nearest_exhaustive(q)
        if ex_sim >= cfg.warm_threshold:
            total += 1
            # either the same best, or some other reuse-grade record
            if sim >= min(ex_sim, cfg.reuse_threshold) - 1e-12:
                found += 1
        if ex_sim < cfg.reuse_threshold:    # fallback ran: exact parity
            assert sim == pytest.approx(ex_sim, abs=1e-12)
    assert total > 0
    assert found == total                  # recall 1.0 above the floor


def test_lsh_nearest_miss_is_exhaustive_exact(lsh_store):
    store, _streams = lsh_store
    q = fingerprint_tokens(
        np.arange(400, dtype=np.int32) % 9 + 200, cache=False)
    rec, sim = store.nearest(q)
    ex_rec, ex_sim = store.nearest_exhaustive(q)
    assert sim == pytest.approx(ex_sim, abs=1e-12)
    assert sim < store.cfg.warm_threshold


def test_lsh_index_tracks_puts_and_evictions():
    store = PolicyStore(PolicyStoreConfig(max_records=4))
    fps = [fingerprint_tokens(np.arange(200, dtype=np.int32) % k + 1,
                              cache=False) for k in (5, 7, 11, 13, 17, 19)]
    for fp in fps:
        store.put(_record(fp))
    assert len(store.index) == 4           # evicted keys removed
    assert store.index.keys() == set(r.key for r in store.records())


def test_lsh_index_persistence_and_rebuild():
    d = tempfile.mkdtemp()
    try:
        cfg = PolicyStoreConfig(dir=d)
        store = PolicyStore(cfg)
        fps = [fingerprint_tokens(np.arange(300, dtype=np.int32) % k + 1,
                                  cache=False) for k in (5, 9, 13)]
        for fp in fps:
            store.put(_record(fp))
        assert os.path.exists(os.path.join(d, "lsh.index"))

        # clean reload: the persisted index is used as-is (no rebuild)
        store2 = PolicyStore(cfg)
        assert store2.n_index_rebuilds == 0
        assert store2.index.keys() == set(r.key for r in store2.records())
        q = fingerprint_tokens(
            np.arange(300, dtype=np.int32) % 9 + 1, cache=False)
        rec, sim = store2.nearest(q)
        assert sim == 1.0                  # exact key via loaded index path

        # corrupt index: rebuilt from records, lookups still correct
        with open(os.path.join(d, "lsh.index"), "w") as f:
            f.write("{broken")
        store3 = PolicyStore(cfg)
        assert store3.n_index_rebuilds == 1
        rec, sim = store3.nearest(q)
        assert sim == 1.0

        # missing index: same story
        os.remove(os.path.join(d, "lsh.index"))
        store4 = PolicyStore(cfg)
        assert store4.n_index_rebuilds == 1
        assert os.path.exists(os.path.join(d, "lsh.index"))  # re-persisted
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_lsh_index_json_roundtrip():
    idx = LSHIndex(64, 16)
    rng = np.random.RandomState(0)
    sigs = {f"k{i}": rng.randint(0, 1 << 30, size=64).astype(np.int64)
            for i in range(10)}
    for k, s in sigs.items():
        idx.add(k, (s,))
    idx2 = LSHIndex.from_json(json.loads(json.dumps(idx.to_json())))
    for k, s in sigs.items():
        assert k in idx2.query(s)


# ------------------------------------------------- CI operation-count guards
def test_guard_signature_work_proportional_to_changed_dispatches():
    """The accumulator must do histogram work only for changed slots: an
    unchanged iteration costs zero update tokens, a one-dispatch change
    costs exactly that dispatch's (old + new) virtual length."""
    rng = np.random.RandomState(0)
    streams = [tokenizer.TokenStream(
        rng.randint(1, 90, size=2000).astype(np.int32)) for _ in range(8)]
    acc = tokenizer.SignatureAccumulator()
    acc.update(streams)
    base_tokens = acc.update_tokens
    for _ in range(5):                      # steady state: zero array work
        acc.update(streams)
    assert acc.update_tokens == base_tokens
    assert acc.changed_slots == len(streams)

    changed = list(streams)
    changed[3] = tokenizer.TokenStream(
        rng.randint(1, 90, size=1500).astype(np.int32))
    acc.update(changed)
    assert acc.changed_slots == len(streams) + 1
    assert (acc.update_tokens - base_tokens
            == streams[3].virtual_len + changed[3].virtual_len)


def test_guard_nearest_probe_count_at_1k_records():
    """At 1k records a recurring-stream lookup must evaluate the full
    calibrated similarity for a tiny fraction of the store (the LSH probe
    shortlists; the bounded fallback never runs on a reuse-grade hit)."""
    rng = np.random.RandomState(3)
    store = PolicyStore(PolicyStoreConfig(max_records=1024))
    base = None
    for i in range(1000):
        t = rng.randint(1, 40, size=350).astype(np.int32)
        if i == 700:
            base = t
        store.put(_record(fingerprint_tokens(t, cache=False)))
    assert len(store) == 1000
    q = fingerprint_tokens(np.concatenate([base, base[:4]]), cache=False)
    store.n_sim_evals = 0
    rec, sim = store.nearest(q)
    assert sim >= store.cfg.reuse_threshold
    assert store.n_sim_evals <= 32, store.n_sim_evals   # ≪ 1000 records


def test_guard_runtime_signature_stats_exposed():
    """The runtime reports the accumulator counters so regression guards
    (and operators) can see steady-state signature work."""
    from repro.core.runtime import ChameleonRuntime
    cfg = ChameleonConfig(enabled=False)
    rt = ChameleonRuntime(cfg, step_builder=lambda policy: (lambda *a: None))
    st_ = rt.stats()["signature"]
    assert set(st_) == {"iterations", "changed_slots", "update_tokens"}


# ------------------------------------------------- simulator search parity
@given(st.integers(0, 500), st.integers(2, 12), st.integers(4, 16),
       st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_simulator_backward_search_parity(seed, n_layers, groups, res_mb):
    """The vectorized backward/forward budget searches must pick exactly
    the layers the reference Python loops would."""
    rng = np.random.RandomState(seed)
    prof = synth_profile(n_layers=n_layers, ops_per_layer=10,
                         res_bytes=res_mb << 20,
                         t_iter=float(rng.uniform(0.01, 10.0)))
    cfg = ChameleonConfig(groups_per_phase=groups)
    sim = Simulator(prof, prof.n_ops // 2, cfg)
    peak_layer = sim.layer_of(sim.peak_op)
    for t in prof.tensors:
        ts = sim.t_swap(t.nbytes)
        first_use = sim.layer_of(t.death)
        expect = None
        for li in range(first_use - 1, peak_layer, -1):   # reference loop
            if sim.layers[li].remaining_time > ts:
                expect = li
                break
        from repro.core.candidates import Candidate
        e = sim.place_swap_in(Candidate(t, 1, 1.0))
        if expect is None:
            assert e is None
        else:
            assert e is not None
            assert e.swap_in_op == sim.layers[expect].start_op


def test_simulator_forward_search_parity():
    prof = synth_profile(t_iter=10.0)
    cfg = ChameleonConfig(groups_per_phase=8)
    from repro.core.candidates import build_candidate_list
    from repro.core.memtrace import build_timeline
    from repro.core.mrl import MRL
    sim = Simulator(prof, prof.n_ops // 2, cfg)
    tl = build_timeline(prof)
    mrl = MRL.from_timeline(tl, int(tl.peak * 0.6))
    cl = build_candidate_list(prof, mrl, cfg)
    entries = sim.simulate(cl, mrl)
    # replay the reference forward search on a fresh simulator
    ref = Simulator(prof, prof.n_ops // 2, cfg)
    for e in entries:                       # reapply swap-in budget spend
        li = ref.layer_of(e.swap_in_op)
        ref.layers[li].remaining_time = \
            ref.layers[li].remaining_time - ref.t_swap(e.nbytes)
    expected = {}
    for e in sorted(entries, key=lambda e: e.birth):
        ts = ref.t_swap(e.nbytes)
        li = ref.layer_of(e.birth)
        done = None
        for lj in range(li, len(ref.layers)):
            if ref.layers[lj].remaining_time > ts:
                ref.layers[lj].remaining_time = \
                    ref.layers[lj].remaining_time - ts
                done = ref.layers[lj]
                break
        if done is None:
            done = ref.layers[ref.layer_of(ref.peak_op)]
        expected[e.uid] = done.end_op
    sim.set_free_time(entries)
    assert {e.uid: e.swap_out_done_op for e in entries} == expected
