"""Multi-stream transfer engine: per-traffic-class FIFO, strict-priority
draining, release-op execution feedback, contention pricing, checkpoint
routing, and the ordering/lifetime regressions the split fixed."""
import collections

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import ChameleonConfig, HostMemConfig
from repro.hostmem import (HostMemError, HostMemTier, PinnedSlabPool,
                           TC_CHECKPOINT, TC_KV_SPILL, TC_POLICY_SWAP,
                           TRAFFIC_CLASSES, TransferEngine)
from repro.hostmem.engine import PRIORITY


def _tier(**class_depths):
    return HostMemTier(HostMemConfig(
        class_depths=tuple(class_depths.items())))


# ------------------------------------------------------------ regressions
def test_swap_in_autochains_queued_swap_out():
    """Regression: submit_swap_in on a still-queued swap-out used to raise
    ValueError (ev.block is None until execution); it must auto-chain by
    retiring the swap-out first."""
    tier = _tier(policy_swap=8)
    eng = tier.engine
    arr = np.arange(64, dtype=np.float32)
    ev_out = eng.submit_swap_out(arr, "t")
    assert not ev_out.done and ev_out.block is None    # still queued
    ev_in = eng.wait(eng.submit_swap_in(ev_out, "t"))
    assert ev_out.done                                 # dependency retired
    np.testing.assert_array_equal(np.asarray(ev_in.result), arr)


def test_swap_in_of_consumed_block_still_rejected():
    tier = HostMemTier()
    eng = tier.engine
    ev = eng.wait(eng.submit_swap_out(np.zeros(64, np.uint8), "t"))
    eng.wait(eng.submit_swap_in(ev))       # frees the slab, block consumed
    ev.block = None
    with pytest.raises(ValueError):
        eng.submit_swap_in(ev)


def _kv_state(L=2, B=3, D=4):
    import jax.numpy as jnp
    State = collections.namedtuple("State", ["pos", "attn_k", "attn_v"])
    rng = np.random.RandomState(0)
    return State(
        pos=jnp.asarray(np.arange(B, dtype=np.int32) + 5),
        attn_k=jnp.asarray(rng.randn(L, B, D).astype(np.float32)),
        attn_v=jnp.asarray(rng.randn(L, B, D).astype(np.float32)))


def test_restore_then_discard_is_not_double_free():
    """Regression: restore left retired events in the spill image, so a
    later discard double-freed the slabs and raised HostMemError."""
    tier = HostMemTier()
    state = _kv_state()
    sp = tier.kvspill.spill(state, 1, tag="req1")
    state2 = tier.kvspill.restore(state, sp, 1)
    np.testing.assert_array_equal(np.asarray(state2.attn_k),
                                  np.asarray(state.attn_k))
    tier.kvspill.discard(sp)               # must be a no-op, not a crash
    tier.kvspill.discard(sp)               # idempotent
    assert tier.pool.bytes_in_use == 0
    tier.pool.check()


def test_discard_frees_once_and_restore_of_discarded_raises():
    tier = HostMemTier()
    state = _kv_state()
    sp = tier.kvspill.spill(state, 0, tag="req0")
    tier.kvspill.discard(sp)
    assert tier.pool.bytes_in_use == 0 and tier.kvspill.n_discards == 1
    tier.kvspill.discard(sp)               # second discard: no-op
    assert tier.kvspill.n_discards == 1
    with pytest.raises(HostMemError):
        tier.kvspill.restore(state, sp, 0)
    tier.pool.check()


def test_spill_is_one_packed_slab_per_slot():
    """The packed layout stages one slab + one engine copy per spill, not
    one per state field."""
    tier = HostMemTier()
    state = _kv_state()
    sp = tier.kvspill.spill(state, 0, tag="req0")
    tier.engine.synchronize()
    assert tier.engine.n_out == 1          # one copy for two fields
    assert tier.pool.live_blocks == 1      # one slab holds the whole image
    assert len(sp.layout) == 2 and sp.nbytes == sum(
        fs.nbytes for fs in sp.layout)
    assert tier.engine.stats()["classes"]["kv_spill"]["n_out"] == 1
    tier.kvspill.discard(sp)


def test_read_before_write_raises_descriptive_error():
    """Regression: HostBlock.read() before write() failed with a bare
    AttributeError; it must raise HostMemError naming the block."""
    p = PinnedSlabPool()
    blk = p.alloc(256, tag="staging")
    with pytest.raises(HostMemError, match="read before write"):
        blk.read()
    blk.write(np.arange(64, dtype=np.int32))
    np.testing.assert_array_equal(blk.read(), np.arange(64, dtype=np.int32))


# ------------------------------------------------- priority scheduling
def test_strict_priority_policy_swap_preempts_checkpoint_drain():
    tier = _tier(checkpoint=16)
    eng = tier.engine
    ck = [eng.submit_swap_out(np.zeros(1 << 16, np.uint8), f"ck{i}",
                              cls=TC_CHECKPOINT) for i in range(6)]
    pol = eng.submit_swap_out(np.zeros(1 << 12, np.uint8), "pol",
                              cls=TC_POLICY_SWAP)
    # waiting on the *drain* must run the policy swap first
    eng.wait(ck[0])
    assert pol.done
    st_ck = eng.by_class[TC_CHECKPOINT]
    assert st_ck.stall_transfers >= 1 and st_ck.stall_s > 0.0
    assert eng.by_class[TC_POLICY_SWAP].preemptions >= 1
    eng.synchronize()
    assert all(e.done for e in ck)


def test_per_class_windows_are_independent():
    tier = _tier(policy_swap=1, checkpoint=4)
    eng = tier.engine
    ck = [eng.submit_swap_out(np.zeros(1 << 12, np.uint8), f"ck{i}",
                              cls=TC_CHECKPOINT) for i in range(4)]
    assert not any(e.done for e in ck)     # checkpoint window holds 4
    p0 = eng.submit_swap_out(np.zeros(1 << 12, np.uint8), "p0")
    p1 = eng.submit_swap_out(np.zeros(1 << 12, np.uint8), "p1")
    # policy depth=1: p1 overflows the window and forces p0 to retire,
    # without touching the queued checkpoint drain
    assert p0.done and not p1.done
    assert not any(e.done for e in ck)
    assert eng.by_class[TC_POLICY_SWAP].forced_retires == 1
    eng.synchronize()


def test_wait_on_kv_spill_jumps_checkpoint_not_policy():
    tier = _tier(policy_swap=8, kv_spill=8, checkpoint=8)
    eng = tier.engine
    ck = eng.submit_swap_out(np.zeros(1 << 12, np.uint8), "ck",
                             cls=TC_CHECKPOINT)
    kv = eng.submit_swap_out(np.zeros(1 << 12, np.uint8), "kv",
                             cls=TC_KV_SPILL)
    pol = eng.submit_swap_out(np.zeros(1 << 12, np.uint8), "pol",
                              cls=TC_POLICY_SWAP)
    eng.wait(kv)
    assert pol.done                        # higher class went first
    assert not ck.done                     # lower class still queued
    eng.synchronize()


def test_unknown_traffic_class_rejected():
    tier = HostMemTier()
    with pytest.raises(ValueError, match="unknown traffic class"):
        tier.engine.submit_swap_out(np.zeros(16, np.uint8), cls="gradients")


# --------------------------------------------- §5.4.2 release-op feedback
def test_advance_op_releases_at_promised_op():
    tier = _tier(policy_swap=8)
    eng = tier.engine
    eng.plan_release("resid:0:1", 5)
    a = np.ones(256, np.float32)
    ev = eng.submit_swap_out(a, "resid:0:1")
    assert ev.release_op == 5 and not ev.done
    assert eng.advance_op(4) == 0          # promised op not reached yet
    assert not ev.done and ev._source is a
    assert eng.advance_op(5) == 1          # released at the promised op
    assert ev.done and ev._source is None  # HBM ref dropped there
    assert eng.by_class[TC_POLICY_SWAP].released_at_op == 1
    eng.begin_iteration()
    assert eng.current_op == -1


def test_advance_op_keeps_fifo_unplanned_head_blocks():
    tier = _tier(policy_swap=8)
    eng = tier.engine
    first = eng.submit_swap_out(np.zeros(64, np.uint8), "unplanned")
    eng.plan_release("planned", 3)
    second = eng.submit_swap_out(np.zeros(64, np.uint8), "planned")
    # FIFO: the unplanned head blocks early release of the one behind it
    assert eng.advance_op(10) == 0
    assert not first.done and not second.done
    eng.synchronize()


def test_executor_release_plan_reaches_engine(llama_profile):
    from repro.core.executor import Executor
    from repro.core.memtrace import build_timeline
    from repro.core.policy import SwapPolicy, generate_policy
    prof, _ = llama_profile
    tl = build_timeline(prof)
    cfg = ChameleonConfig(groups_per_phase=8)
    pol = generate_policy(prof, cfg, int(tl.peak * 0.7), timeline=tl)
    applied = Executor(cfg).lower(pol, prof)
    assert applied.release_plan
    assert applied.release_plan == {
        SwapPolicy.entry_tag(e): e.swap_out_done_op
        for e in pol.entries if e.swap_out_done_op >= 0}
    tier = HostMemTier()
    n = Executor(cfg).bind_release_points(applied, tier.engine)
    assert n == len(applied.release_plan)
    assert tier.engine.planned_releases() == applied.release_plan


def test_runtime_end_iteration_drives_release_ops(llama_profile):
    """The runtime must retire planned swap-outs at iteration end (the op
    stream has passed every promised release point) and reset the cursor."""
    from repro.core.runtime import ChameleonRuntime
    rt = ChameleonRuntime(ChameleonConfig(), lambda pol: (lambda x: x))
    eng = rt.hostmem.engine
    rt.applied.release_plan = {"site:0:1": 7}
    eng.plan_release("site:0:1", 7)
    ev = eng.submit_swap_out(np.zeros(128, np.uint8), "site:0:1")
    assert not ev.done
    rt.end_iteration(0.01)
    assert ev.done and ev._source is None
    assert eng.current_op == -1            # fresh cursor for next iteration


# ---------------------------------------------------- contention pricing
def _toy_profile(n_ops=100):
    from repro.core.profiler import ProfileData, TensorInstance
    tensors = [TensorInstance(i, 1 << 20, i, n_ops - i, site="ffn_pre",
                              layer=i) for i in range(10)]
    return ProfileData(np.zeros(n_ops, np.int32), tensors, 1.0, 0)


def test_simulator_prices_link_contention():
    from repro.core.simulator import Simulator
    prof = _toy_profile()
    cfg = ChameleonConfig(groups_per_phase=8)
    tier = _tier(checkpoint=32)
    for i in range(8):                     # queued checkpoint drain
        tier.engine.submit_swap_out(np.zeros(4 << 20, np.uint8),
                                    f"ck{i}", cls=TC_CHECKPOINT)
    idle = Simulator(prof, 50, cfg)
    busy = Simulator(prof, 50, cfg, engine=tier.engine)
    assert idle.contention_s == 0.0
    assert busy.contention_s == pytest.approx(
        tier.engine.queued_delay(), rel=1e-6)
    assert busy.contention_s > 0.0
    # the backlog eats the earliest layers' overlap budget
    assert (busy.layers[0].remaining_time
            < idle.layers[0].remaining_time)
    tier.engine.synchronize()


def test_generate_policy_records_contention(llama_profile):
    from repro.core.memtrace import build_timeline
    from repro.core.policy import generate_policy
    prof, _ = llama_profile
    tl = build_timeline(prof)
    tier = _tier(checkpoint=32)
    for i in range(4):
        tier.engine.submit_swap_out(np.zeros(8 << 20, np.uint8),
                                    f"ck{i}", cls=TC_CHECKPOINT)
    pol = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                          int(tl.peak * 0.7), timeline=tl,
                          engine=tier.engine)
    assert pol.contention_s > 0.0
    idle = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                           int(tl.peak * 0.7), timeline=tl)
    assert idle.contention_s == 0.0
    tier.engine.synchronize()


# --------------------------------------------------- checkpoint routing
def test_checkpoint_manager_routes_through_checkpoint_class(tmp_path):
    from repro.checkpointing.manager import CheckpointManager
    tier = HostMemTier()
    mgr = CheckpointManager(str(tmp_path), engine=tier.engine)
    tree = {"w": np.arange(1024, dtype=np.float32).reshape(32, 32),
            "b": np.full(7, 3.5, np.float64)}
    mgr.save(3, {"params": tree}, extra={"step": 3}, block=False)
    mgr.wait()
    cs = tier.engine.stats()["classes"]
    assert cs["checkpoint"]["n_out"] == 2
    assert cs["policy_swap"]["n_out"] == 0
    assert tier.pool.bytes_in_use == 0     # writer recycled every slab
    restored, extra = mgr.restore(
        3, {"params": {"w": np.zeros((32, 32), np.float32),
                       "b": np.zeros(7, np.float64)}})
    np.testing.assert_array_equal(restored["params"]["w"], tree["w"])
    np.testing.assert_array_equal(np.asarray(restored["params"]["b"]),
                                  tree["b"])
    assert extra["step"] == 3
    tier.pool.check()


def test_checkpoint_drain_preempted_by_policy_swap(tmp_path):
    """While a checkpoint drain is queued, a policy swap submitted by the
    'training thread' completes ahead of it."""
    from repro.checkpointing.manager import CheckpointManager
    tier = _tier(checkpoint=64)
    mgr = CheckpointManager(str(tmp_path), engine=tier.engine)
    tree = {f"w{i}": np.zeros((256, 256), np.float32) for i in range(6)}
    mgr.save(1, {"params": tree}, block=False)   # drain queues async
    pol = tier.engine.submit_swap_out(np.ones(1 << 16, np.uint8), "swap")
    tier.engine.wait(pol)
    assert pol.done
    mgr.wait()                                   # writer finished its drain
    cs = tier.engine.stats()["classes"]
    assert cs["checkpoint"]["n_out"] == 6
    assert tier.pool.live_blocks == 1            # only the policy slab
    tier.engine.pool.free(pol.block)
    tier.pool.check()


def test_set_class_depth_widens_and_never_shrinks():
    tier = HostMemTier()
    eng = tier.engine
    eng.set_class_depth(TC_CHECKPOINT, 8)
    evs = [eng.submit_swap_out(np.zeros(64, np.uint8), f"c{i}",
                               cls=TC_CHECKPOINT) for i in range(8)]
    assert not any(e.done for e in evs)    # whole drain queued, no inline
    eng.set_class_depth(TC_CHECKPOINT, 2)  # must not shrink
    eng.submit_swap_out(np.zeros(64, np.uint8), "c8", cls=TC_CHECKPOINT)
    assert evs[0].done and not evs[1].done  # 9th overflows the 8-window
    eng.synchronize()


def test_checkpoint_save_widens_window_to_drain(tmp_path):
    from repro.checkpointing.manager import CheckpointManager
    tier = HostMemTier()                   # default depth 2
    mgr = CheckpointManager(str(tmp_path), engine=tier.engine)
    tree = {f"w{i}": np.zeros(128, np.float32) for i in range(10)}
    mgr.save(1, {"params": tree}, block=False)
    assert tier.engine._depths[TC_CHECKPOINT] >= 12   # 10 arrays + slack
    mgr.wait()
    assert tier.pool.bytes_in_use == 0


def test_runtime_mirrors_applied_swap_traffic(llama_profile):
    """The executed policy's swap schedule flows through the engine as
    real policy_swap traffic, released at the promised ops."""
    from repro.core.memtrace import build_timeline
    from repro.core.policy import generate_policy
    from repro.core.runtime import ChameleonRuntime
    prof, _ = llama_profile
    tl = build_timeline(prof)
    rt = ChameleonRuntime(ChameleonConfig(), lambda pol: (lambda x: x))
    pol = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                          int(tl.peak * 0.7), timeline=tl)
    rt.applied = rt.executor.lower(pol, prof)
    rt.executor.bind_release_points(rt.applied, rt.hostmem.engine)
    rt.end_iteration(0.01)
    cs = rt.hostmem.engine.stats()["classes"][TC_POLICY_SWAP]
    assert cs["n_out"] > 0 and cs["n_in"] == cs["n_out"]
    assert cs["released_at_op"] == cs["n_out"]   # freed at promised ops
    assert rt.hostmem.pool.bytes_in_use == 0     # slabs all recycled
    rt.hostmem.pool.check()


def test_mirror_disabled_by_config(llama_profile):
    from repro.core.memtrace import build_timeline
    from repro.core.policy import generate_policy
    from repro.core.runtime import ChameleonRuntime
    prof, _ = llama_profile
    tl = build_timeline(prof)
    cfg = ChameleonConfig(hostmem=HostMemConfig(mirror_swap_bytes=0))
    rt = ChameleonRuntime(cfg, lambda pol: (lambda x: x))
    pol = generate_policy(prof, ChameleonConfig(groups_per_phase=8),
                          int(tl.peak * 0.7), timeline=tl)
    rt.applied = rt.executor.lower(pol, prof)
    rt.end_iteration(0.01)
    assert rt.hostmem.engine.n_out == 0          # mirror off: no traffic


# ------------------------------------------------------- property tests
@given(st.lists(st.tuples(st.sampled_from(TRAFFIC_CLASSES),
                          st.integers(1, 1 << 16)),
                min_size=1, max_size=40))
@settings(max_examples=25, deadline=None)
def test_per_class_fifo_under_interleaved_traffic(subs):
    """Property: whatever the interleaving and forced retires, completion
    order *within* each class is submission order."""
    tier = HostMemTier(HostMemConfig(engine_depth=2))
    eng = tier.engine
    done = []
    evs = []
    for cls, size in subs:
        ev = eng.submit_swap_out(np.zeros(size, np.uint8), cls=cls)
        ev.on_done(lambda e: done.append((e.cls, e.eid)))
        evs.append(ev)
    eng.synchronize()
    per_class = {}
    for cls, eid in done:
        per_class.setdefault(cls, []).append(eid)
    for cls, eids in per_class.items():
        assert eids == sorted(eids), f"{cls} completed out of FIFO order"
    assert len(done) == len(subs)
    for ev in evs:
        tier.pool.free(ev.block)
    tier.pool.check()


@given(st.lists(st.tuples(st.sampled_from(TRAFFIC_CLASSES),
                          st.integers(1, 1 << 14)),
                min_size=2, max_size=24))
@settings(max_examples=25, deadline=None)
def test_strict_priority_drain_order(subs):
    """Property: with everything queued up front, the scheduler drains in
    (priority, submission) order."""
    tier = _tier(policy_swap=64, kv_spill=64, checkpoint=64)
    eng = tier.engine
    done = []
    for cls, size in subs:
        ev = eng.submit_swap_out(np.zeros(size, np.uint8), cls=cls)
        ev.on_done(lambda e: done.append((PRIORITY[e.cls], e.eid)))
    eng.synchronize()
    assert done == sorted(done), "drain violated strict priority order"
    eng_stats = eng.stats()
    assert eng_stats["forced_retires"] == 0
    tier.pool.check()


@given(st.lists(st.tuples(st.sampled_from(TRAFFIC_CLASSES),
                          st.integers(1, 1 << 16),
                          st.integers(0, 5)),
                min_size=1, max_size=40))
@settings(max_examples=25, deadline=None)
def test_pool_invariants_under_multiclass_churn(ops):
    """Property: random interleaved multi-class swap-out / swap-in /
    release traffic never double-books the pool and leaks nothing."""
    tier = HostMemTier(HostMemConfig(engine_depth=2))
    eng = tier.engine
    outstanding = []
    op_idx = 0
    for cls, size, action in ops:
        ev = eng.submit_swap_out(np.zeros(size, np.uint8),
                                 f"op{op_idx}", cls=cls)
        outstanding.append(ev)
        if action == 1 and outstanding:          # immediate round trip
            eng.wait(eng.submit_swap_in(outstanding.pop(0)))
        elif action == 2:
            eng.advance_op(op_idx)               # release-op sweep (no-op:
        elif action == 3 and outstanding:        #  nothing planned)
            eng.wait(outstanding[-1])
        op_idx += 1
        tier.pool.check()                        # invariant holds mid-churn
    eng.synchronize()
    for ev in outstanding:
        eng.wait(eng.submit_swap_in(ev))
    assert tier.pool.bytes_in_use == 0
    assert tier.pool.live_blocks == 0
    tier.pool.check()


def test_kv_spill_roundtrip_under_concurrent_classes():
    """A spill image restored while checkpoint traffic floods the link is
    still bit-exact, and its class counters stay separated."""
    tier = _tier(checkpoint=32)
    state = _kv_state(L=3, B=4, D=8)
    sp = tier.kvspill.spill(state, 2, tag="req")
    for i in range(6):
        tier.engine.submit_swap_out(np.zeros(1 << 18, np.uint8),
                                    f"ck{i}", cls=TC_CHECKPOINT)
    state2 = tier.kvspill.restore(state, sp, 2)
    np.testing.assert_array_equal(np.asarray(state2.attn_k),
                                  np.asarray(state.attn_k))
    np.testing.assert_array_equal(np.asarray(state2.attn_v),
                                  np.asarray(state.attn_v))
    cs = tier.engine.stats()["classes"]
    assert cs["kv_spill"]["n_out"] == 1 and cs["kv_spill"]["n_in"] == 1
    assert cs["kv_spill"]["bytes_out"] == sp.nbytes
    tier.engine.synchronize()
    tier.pool.check()
