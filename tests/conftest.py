"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device;
mesh-dependent tests spawn a child process (see tests/test_distributed.py).
"""
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hermetic environments may lack the hypothesis dev dependency — fall back
# to the seeded-sweep shim so property tests still collect and run
import importlib.util  # noqa: E402
if ("hypothesis" not in sys.modules
        and importlib.util.find_spec("hypothesis") is None):
    from repro.testing import hypothesis_shim  # noqa: E402
    hypothesis_shim.install()

import repro.configs as C  # noqa: E402
from repro.common.config import ChameleonConfig  # noqa: E402
from repro.models.registry import get_api  # noqa: E402


@pytest.fixture(scope="session")
def llama_small():
    """8-layer reduced llama2 — enough layers for meaningful policies."""
    cfg = C.get_reduced("llama2_paper").replace(num_layers=8)
    api = get_api(cfg)
    params, axes = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, api, params, axes


@pytest.fixture(scope="session")
def llama_profile(llama_small):
    """Baseline train-step profile of the small llama (shared: profiling is
    the slowest fixture)."""
    import jax.numpy as jnp
    from repro.core.profiler import profile_jaxpr
    cfg, api, params, _ = llama_small

    def train_step(params, batch):
        def lf(p):
            loss, _ = api.loss_fn(cfg, p, batch)
            return loss
        loss, g = jax.value_and_grad(lf)(params)
        return loss, jax.tree.map(lambda p, gg: p - 1e-3 * gg, params, g)

    batch = {"tokens": jnp.ones((4, 128), jnp.int32),
             "labels": jnp.ones((4, 128), jnp.int32)}
    cj = jax.make_jaxpr(train_step)(params, batch)
    prof = profile_jaxpr(cj, t_iter=1.0)
    return prof, (params, batch, train_step)


def run_child(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run a snippet in a child process with N host-platform devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(f"child failed:\nSTDOUT:\n{r.stdout}\n"
                             f"STDERR:\n{r.stderr[-4000:]}")
    return r.stdout
