"""repro.adapt service tests (ISSUE 7).

Five families:

  * **swap-in protocol stress** — hundreds of iteration boundaries racing
    enqueue/publish/discard on the worker against the install poll on the
    "training" thread, with injected drift: no torn install (every polled
    result is internally consistent and matches the live stream), the
    generation counter is monotone, and the job ledger balances exactly
    (jobs == installed + discarded once drained and flushed);
  * **async ≡ inline equivalence** — ``AdaptationPipeline.run`` is
    deterministic in the snapshot, so the worker's published result must
    equal a synchronous replay of the same snapshot bit-for-bit in
    everything that matters (knob, kind, policy fingerprint, swap size);
  * **crash hygiene** — a raising pipeline must not kill training: the
    worker publishes the conservative fallback, audits
    ``adaptation.failed``, stays alive for the next job, and ``submit``
    re-arms a dead thread;
  * **speculative pre-generation** — a recurring A/B phase cycle parks
    the successor's policy so the next switch installs with zero
    non-speculative jobs, and the chain keeps hitting from then on;
  * **satellites** — MRL slice-window parity against the O(n) masked
    reference (ISSUE 7 satellite), and the vectorized ``nearest`` miss
    path pruning to a handful of similarity evaluations while staying
    exhaustive-scan exact.
"""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import obs
from repro.adapt import (VARIANT_KNOBS, AdaptResult, AdaptSnapshot,
                         AdaptationPipeline, AdaptationService)
from repro.common.config import ChameleonConfig, PolicyStoreConfig
from repro.core.executor import AppliedPolicy, Executor
from repro.core.mrl import MRL
from repro.policystore import PolicyStore, fingerprint_tokens

from tests.test_monitor_hotpath import _record
from tests.test_simulator_policy import synth_profile


# ------------------------------------------------------------------ helpers
class _EchoPipeline:
    """Pipeline stand-in: returns a result that names the snapshot it was
    computed from (so a torn/mixed install is detectable), after an
    optional delay to widen the race window."""

    def __init__(self, delay=0.0, jitter=0.0, seed=0):
        self.executor = Executor(ChameleonConfig())
        self.delay, self.jitter = delay, jitter
        self._rng = np.random.RandomState(seed)   # worker thread only
        self.fail = False
        self.n_runs = 0

    def run(self, snap: AdaptSnapshot, *, pace_s: float = 0.0
            ) -> AdaptResult:
        self.n_runs += 1
        if self.delay or self.jitter:
            time.sleep(self.delay + self.jitter * float(self._rng.rand()))
        if self.fail:
            raise RuntimeError("injected pipeline crash")
        applied = AppliedPolicy(None, set(), set(), set(),
                                f"policy-for-{snap.iter_exact}")
        return AdaptResult(applied=applied, swap=None, knob=1.0,
                           kind="echo", tier="regen",
                           predicted_t=snap.t_iter, profile=None,
                           iter_exact=snap.iter_exact, step=snap.step)


def _snap(fp: str, step: int = 0) -> AdaptSnapshot:
    return AdaptSnapshot(t_iter=0.01, budget=1 << 30, iter_exact=fp,
                         step=step, profile=None)


# ------------------------------------------------- swap-in protocol stress
def test_stress_no_torn_install_monotone_epochs():
    """>=200 boundaries of drift/submit/poll racing the worker.  Every
    polled result must be current (epoch == live epoch, fingerprint ==
    live stream) and self-consistent (its policy names its own stream);
    epochs never move backwards; the job ledger balances."""
    pipe = _EchoPipeline(delay=0.0005, jitter=0.002)
    svc = AdaptationService(pipe, "async")
    rng = np.random.RandomState(1234)
    live = None
    installs = 0
    last_epoch = svc.epoch
    try:
        for i in range(300):
            assert svc.epoch >= last_epoch          # monotone generations
            last_epoch = svc.epoch
            r = rng.rand()
            if live is None or r < 0.30:
                # injected drift: a brand-new stream supersedes in-flight
                live = f"fp-{i}"
                svc.invalidate("injected-drift")
                svc.submit(_snap(live, step=i))
            elif r < 0.45:
                # drift the runtime re-submits without an epoch bump
                # (same settled phase, refreshed snapshot): older same-
                # epoch results must be rejected by the fingerprint check
                live = f"fp-{i}"
                svc.submit(_snap(live, step=i))
            time.sleep(float(rng.rand()) * 0.001)
            res = svc.poll()                        # iteration boundary
            if res is not None:
                installs += 1
                assert res.epoch == svc.epoch       # never a stale epoch
                assert res.iter_exact == live       # never a stale stream
                # internal consistency: the installed policy was computed
                # from the snapshot of the stream it claims (torn install)
                assert res.applied.fingerprint == f"policy-for-{live}"
        assert svc.drain(timeout=30.0)
        # flush: whatever is still parked in the mailbox is either
        # installable (count it) or stale (service discards it)
        res = svc.poll()
        if res is not None:
            installs += 1
        svc.invalidate("final-flush")
        assert installs == svc.n_installed
        assert installs > 0                         # the race wasn't vacuous
        assert svc.n_discarded > 0                  # drift really superseded
        # ledger: every job ends exactly once — run and installed, or
        # discarded (stale while queued, superseded in the mailbox, stale
        # or foreign-stream at the poll) — nothing leaks
        assert svc.n_jobs == svc.n_installed + svc.n_discarded
    finally:
        svc.close()


def test_poll_rejects_stale_epoch_and_foreign_fingerprint():
    """Deterministic unit coverage of both discard reasons the stress
    test exercises probabilistically."""
    pipe = _EchoPipeline()
    svc = AdaptationService(pipe, "async")
    try:
        svc.submit(_snap("A", step=1))
        assert svc.drain()
        svc.invalidate("drift")                     # supersedes A's result
        assert svc.poll() is None
        assert svc.n_discarded == 1

        svc.submit(_snap("B", step=2))
        assert svc.drain()
        svc.submit(_snap("C", step=3))              # same epoch, new stream
        deadline = time.monotonic() + 5.0
        while svc.poll() is None:                   # B (stale stream) is
            assert time.monotonic() < deadline      # discarded; C installs
            time.sleep(0.001)
        assert svc.n_installed == 1
        assert svc.n_discarded >= 2                 # A (epoch) + B (stream)
    finally:
        svc.close()


# ------------------------------------------------- async ≡ inline equivalence
def test_worker_result_equals_synchronous_replay():
    """The worker publishes exactly what a synchronous run of the same
    snapshot computes — the equivalence that makes async installs safe."""
    cfg = ChameleonConfig(enabled=True)
    prof = synth_profile(n_layers=8, ops_per_layer=10, res_bytes=1 << 20)
    budget = 3 << 20                                # force a swap policy
    pipe = AdaptationPipeline(cfg, Executor(cfg))
    inline = pipe.run(AdaptSnapshot(profile=prof, t_iter=1.0, budget=budget,
                                    iter_exact="stream", step=7))
    assert inline.kind == "genpolicy" and inline.swap is not None
    assert inline.n_variants == len(VARIANT_KNOBS)

    svc = AdaptationService(pipe, "async")
    try:
        svc.submit(AdaptSnapshot(profile=prof, t_iter=1.0, budget=budget,
                                 iter_exact="stream", step=7))
        assert svc.drain()
        res = svc.poll()
    finally:
        svc.close()
    assert res is not None
    assert res.knob == inline.knob
    assert res.kind == inline.kind
    assert res.predicted_t == pytest.approx(inline.predicted_t)
    assert res.applied.fingerprint == inline.applied.fingerprint
    assert res.applied.offload == inline.applied.offload
    assert len(res.swap.entries) == len(inline.swap.entries)
    assert ([e.uid for e in res.swap.entries]
            == [e.uid for e in inline.swap.entries])


# --------------------------------------------------------- crash hygiene
def test_worker_crash_publishes_conservative_and_stays_alive():
    pipe = _EchoPipeline()
    pipe.fail = True
    svc = AdaptationService(pipe, "async")
    try:
        svc.submit(_snap("A", step=1))
        assert svc.drain()
        assert svc.n_failed == 1
        assert svc.stats()["worker_alive"]          # the loop survived
        res = svc.poll()
        assert res is not None
        assert res.kind == "conservative-fallback" and res.tier == "failed"
        assert res.applied.offload                  # offload-all fallback
        assert obs.audit().tail(5, kind="adaptation.failed")

        # recovery: the very next job publishes normally
        pipe.fail = False
        svc.invalidate("retry")
        svc.submit(_snap("B", step=2))
        assert svc.drain()
        res = svc.poll()
        assert res is not None and res.kind == "echo"
        assert res.iter_exact == "B"
    finally:
        svc.close()


def test_submit_rearms_dead_worker():
    pipe = _EchoPipeline()
    svc = AdaptationService(pipe, "async")
    svc.submit(_snap("A", step=1))
    assert svc.drain()
    svc.close()                                     # worker thread exits
    assert not svc.stats()["worker_alive"]
    svc.invalidate("restart")
    svc.submit(_snap("B", step=2))                  # re-arms the thread
    try:
        assert svc.stats()["worker_alive"]
        assert svc.drain()
        res = svc.poll()
        assert res is not None and res.iter_exact == "B"
    finally:
        svc.close()


# --------------------------------------------------- speculative chaining
def test_speculative_recurring_cycle_parks_and_chains():
    """A/B/A/B phase cycle: after one full observed period the successor
    policy is parked before its phase arrives, and every later switch is
    a speculative hit with zero new non-speculative jobs."""
    pipe = _EchoPipeline()
    svc = AdaptationService(pipe, "speculative")

    def boundary(fp, step):
        """What the runtime does when a settled phase enters ADAPTING."""
        svc.invalidate("phase-switch")
        hit = svc.take_speculative(fp)
        if hit is not None:
            svc.note_adapted(fp)
            assert svc.drain()                      # let chained spec land
            return hit, True
        svc.submit(_snap(fp, step=step))
        assert svc.drain()
        return svc.poll(), False

    try:
        seq = ["A", "B", "A", "B", "A", "B"]
        hits = []
        for step, fp in enumerate(seq):
            res, was_spec = boundary(fp, step)
            assert res is not None
            assert res.iter_exact == fp             # right phase's policy
            assert res.applied.fingerprint == f"policy-for-{fp}"
            hits.append(was_spec)
        # cycle 1 (A, B) and the first re-visit of A run the worker; the
        # chain is primed after A->B->A is observed, so everything from
        # the 4th switch on is a parked pre-generated policy
        assert hits[:3] == [False, False, False]
        assert all(hits[3:])
        assert svc.n_spec_hits == len(seq) - 3
        non_spec_jobs = svc.n_jobs - svc.n_spec_jobs
        assert non_spec_jobs == 3                   # nothing inline after
    finally:
        svc.close()


def test_speculative_lru_bounds():
    """Parked results and retained snapshots stay LRU-bounded."""
    pipe = _EchoPipeline()
    svc = AdaptationService(pipe, "speculative", max_parked=2,
                            max_snapshots=3)
    try:
        for i in range(6):
            svc.submit(_snap(f"fp-{i}", step=i))
        assert svc.drain()
        st_ = svc.stats()
        assert st_["snapshots"] <= 3
        assert st_["parked"] <= 2
    finally:
        svc.close()


# ----------------------------------------------------- satellite: MRL parity
@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_mrl_window_parity_vs_masked_reference(seed):
    """covered_count/decrement via the sorted-ops searchsorted window must
    match the O(n) boolean-mask reference on arbitrary [birth, death)
    queries, including empty, inverted, and out-of-range windows."""
    r = np.random.RandomState(seed)
    ops = np.unique(r.randint(0, 200, size=r.randint(1, 64)))
    req = r.randint(-5, 1 << 20, size=ops.size).astype(np.int64)
    mrl = MRL(ops.copy(), req.copy())
    ref = req.copy()
    for _ in range(12):
        birth = int(r.randint(-10, 220))
        death = int(r.randint(-10, 220))
        mask = (ops >= birth) & (ops < death)
        assert (mrl.covered_count(birth, death)
                == int(np.count_nonzero(ref[mask] > 0)))
        nbytes = int(r.randint(0, 1 << 16))
        mrl.decrement(birth, death, nbytes)
        ref[mask] -= nbytes
        np.testing.assert_array_equal(mrl.required, ref)
    assert mrl.is_empty() == bool(np.all(ref <= 0))
    assert mrl.max_required() == int(ref.max(initial=0))


# ------------------------------------- satellite: nearest() miss-path prune
def test_nearest_true_miss_prunes_and_matches_exhaustive():
    """A query far from every record must return the exact exhaustive-scan
    answer after only a handful of similarity evaluations — the dense
    cosine rows make the upper bound tight, so the sorted-bound scan
    stops almost immediately."""
    rng = np.random.RandomState(3)
    store = PolicyStore(PolicyStoreConfig(max_records=512))
    for i in range(200):
        t = rng.randint(1, 40, size=250 + i % 9).astype(np.int32)
        store.put(_record(fingerprint_tokens(t, cache=False)))
    # disjoint token range + very different length: a true miss
    q = fingerprint_tokens(np.arange(500, dtype=np.int32) % 11 + 300,
                           cache=False)
    before = store.n_sim_evals
    rec, sim = store.nearest(q)
    evals = store.n_sim_evals - before
    ex_rec, ex_sim = store.nearest_exhaustive(q)
    assert sim == pytest.approx(ex_sim, abs=1e-9)   # parity with the oracle
    assert sim < store.cfg.warm_threshold           # really a miss
    assert evals <= 40                              # pruned: 200 records /
    #                         400 scoreable rows, only near-tied bounds score


def test_nearest_prune_never_changes_the_answer():
    """Randomized parity sweep: pruned nearest == exhaustive for queries
    across the hit/miss spectrum."""
    rng = np.random.RandomState(11)
    store = PolicyStore(PolicyStoreConfig(max_records=512))
    streams = []
    for i in range(80):
        t = rng.randint(1, 30, size=200 + (i % 5) * 17).astype(np.int32)
        streams.append(t)
        store.put(_record(fingerprint_tokens(t, cache=False)))
    for i in range(24):
        if i % 3 == 0:                              # near-recurrence
            base = streams[rng.randint(len(streams))]
            t = np.concatenate([base, base[: rng.randint(0, 9)]])
        elif i % 3 == 1:                            # mid-distance
            t = rng.randint(1, 60, size=rng.randint(150, 400))
        else:                                       # far miss
            t = rng.randint(100 + i, 140 + i, size=rng.randint(50, 600))
        q = fingerprint_tokens(t.astype(np.int32), cache=False)
        rec, sim = store.nearest(q)
        ex_rec, ex_sim = store.nearest_exhaustive(q)
        assert sim == pytest.approx(ex_sim, abs=1e-9)
