"""Simulator (§5.4) + policy generation (Algo 2) tests."""
import numpy as np
import pytest

from repro.common.config import ChameleonConfig
from repro.core.candidates import Candidate, build_candidate_list
from repro.core.memtrace import build_timeline
from repro.core.mrl import MRL
from repro.core.policy import ChameleonOOMError, generate_policy
from repro.core.profiler import ProfileData, TensorInstance
from repro.core.simulator import Simulator


def synth_profile(n_layers=8, ops_per_layer=10, res_bytes=64 << 20,
                  t_iter=1.0):
    """Symmetric fwd/bwd op stream with one tagged residual per layer."""
    n_fwd = n_layers * ops_per_layer
    n_ops = 2 * n_fwd
    tensors = []
    for i in range(n_layers):
        birth = (i + 1) * ops_per_layer - 1
        death = n_ops - (i + 1) * ops_per_layer
        tensors.append(TensorInstance(
            i, res_bytes, birth, death, site="resid_post", layer=i,
            dtype_code=1, shape=(res_bytes // 4,)))
    return ProfileData(np.zeros(n_ops, np.int32), tensors, t_iter, 0)


def test_eq1_group_time():
    prof = synth_profile(t_iter=2.0)
    sim = Simulator(prof, prof.n_ops // 2, ChameleonConfig(groups_per_phase=8))
    fwd_layers = [l for l in sim.layers if l.kind == "FWD"]
    assert len(fwd_layers) == 8
    t_op = 2.0 / prof.n_ops
    for lay in fwd_layers:
        assert lay.remaining_time == pytest.approx(
            (lay.end_op - lay.start_op) * t_op)


def test_swap_in_backward_search():
    """Candidate used late in bwd lands its swap-in in a layer strictly
    between the peak and first use (5.4.1)."""
    prof = synth_profile(t_iter=10.0)  # long iteration: plenty of budget
    cfg = ChameleonConfig(groups_per_phase=8)
    sim = Simulator(prof, prof.n_ops // 2, cfg)
    t = prof.tensors[0]  # layer-0 residual: first bwd use at the very end
    cand = Candidate(t, 5, 1.0)
    e = sim.place_swap_in(cand)
    assert e is not None and not e.stalled
    assert sim.peak_op <= e.swap_in_op < t.death


def test_swap_in_stall_fallback():
    """When no layer has budget (tiny t_iter), the top candidate is still
    swapped with a stall (paper: better than OOM)."""
    prof = synth_profile(t_iter=1e-6, res_bytes=1 << 30)
    cfg = ChameleonConfig(groups_per_phase=8)
    sim = Simulator(prof, prof.n_ops // 2, cfg)
    tl = build_timeline(prof)
    mrl = MRL.from_timeline(tl, int(tl.peak * 0.5))
    cl = build_candidate_list(prof, mrl, cfg)
    entries = sim.simulate(cl, mrl)
    assert any(e.stalled for e in entries)
    assert sim.stall_time > 0


def test_swap_out_completion_forward():
    prof = synth_profile(t_iter=10.0)
    cfg = ChameleonConfig(groups_per_phase=8)
    sim = Simulator(prof, prof.n_ops // 2, cfg)
    tl = build_timeline(prof)
    mrl = MRL.from_timeline(tl, int(tl.peak * 0.6))
    cl = build_candidate_list(prof, mrl, cfg)
    entries = sim.simulate(cl, mrl)
    sim.set_free_time(entries)
    for e in entries:
        assert e.swap_out_done_op > e.birth
    # custom-recordStream release happens (much) earlier than naive
    custom = sim.reuse_intervals(entries)
    naive = sim.naive_reuse_intervals(entries)
    assert np.all(custom <= naive)
    assert custom.mean() < naive.mean()


def test_policy_meets_budget():
    prof = synth_profile(n_layers=12, t_iter=30.0)
    tl = build_timeline(prof)
    for frac in (0.9, 0.7, 0.5):
        budget = int(tl.peak * frac)
        pol = generate_policy(prof, ChameleonConfig(groups_per_phase=12),
                              budget)
        assert pol.swapped_bytes >= tl.peak - budget - (64 << 20)
        assert len(pol.entries) >= 1
        # per 5.4.1 selection, MRL cleared => projected peak near budget
        assert pol.projected_peak <= tl.peak


def test_policy_raises_below_floor():
    """Budget below the unswappable floor must raise (Algo 2 line 8)."""
    prof = synth_profile()
    # one giant untagged tensor spanning everything: not a candidate
    prof.tensors.append(TensorInstance(
        999, 10 << 30, 0, prof.n_ops, site=None))
    with pytest.raises(ChameleonOOMError):
        generate_policy(prof, ChameleonConfig(groups_per_phase=8), 1 << 30)


def test_candidate_scoring_eq2():
    prof = synth_profile()
    tl = build_timeline(prof)
    mrl = MRL.from_timeline(tl, int(tl.peak * 0.5))
    cfg = ChameleonConfig(score_coef_c=1.0)
    cl = build_candidate_list(prof, mrl, cfg)
    assert cl, "candidates must exist"
    scores = [c.score for c in cl]
    assert scores == sorted(scores, reverse=True)
    # equal sizes: score ordering == MRE-coverage ordering
    mres = [c.n_mre for c in cl]
    assert mres == sorted(mres, reverse=True)


def test_remaining_time_never_double_booked():
    prof = synth_profile(n_layers=8, t_iter=5.0)
    cfg = ChameleonConfig(groups_per_phase=8)
    sim = Simulator(prof, prof.n_ops // 2, cfg)
    tl = build_timeline(prof)
    mrl = MRL.from_timeline(tl, int(tl.peak * 0.3))
    cl = build_candidate_list(prof, mrl, cfg)
    entries = sim.simulate(cl, mrl)
    booked = {}
    for e in entries:
        if not e.stalled:
            li = sim.layer_of(e.swap_in_op)
            booked[li] = booked.get(li, 0.0) + sim.t_swap(e.nbytes)
    t_op = prof.t_iter / prof.n_ops
    for li, t in booked.items():
        cap = (sim.layers[li].end_op - sim.layers[li].start_op) * t_op
        assert t <= cap + 1e-9
