"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention, flash_decode
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant_offload.ops import (compressed_offload, dequantize,
                                             quantize)
from repro.kernels.quant_offload.ref import dequantize_ref, quantize_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

RNG = np.random.RandomState(0)


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("B,Sq,Sk,H,Kh,D,causal", [
    (2, 256, 256, 4, 2, 64, True),
    (1, 128, 384, 4, 4, 32, False),
    (2, 100, 100, 2, 1, 64, True),      # non-multiple of block
    (1, 512, 512, 8, 1, 128, True),     # MQA, MXU-aligned head dim
    (1, 64, 192, 6, 3, 16, False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Sk, H, Kh, D, causal, dtype):
    q = jnp.asarray(RNG.randn(B, Sq, H, D) * 0.3, dtype)
    k = jnp.asarray(RNG.randn(B, Sk, Kh, D) * 0.3, dtype)
    v = jnp.asarray(RNG.randn(B, Sk, Kh, D) * 0.3, dtype)
    out = flash_attention(q, k, v, causal=causal)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), causal=causal,
                        sm_scale=1 / np.sqrt(D))
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(jnp.swapaxes(ref, 1, 2), np.float32), **_tol(dtype))


def test_flash_attention_grad():
    q = jnp.asarray(RNG.randn(1, 128, 2, 32) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(1, 128, 2, 32) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(1, 128, 2, 32) * 0.3, jnp.float32)

    def ref_fn(q):
        r = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), causal=True,
                          sm_scale=1 / np.sqrt(32))
        return jnp.sum(jnp.swapaxes(r, 1, 2) ** 2)

    g1 = jax.grad(lambda q: jnp.sum(flash_attention(q, k, v) ** 2))(q)
    g2 = jax.grad(ref_fn)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("Sk,lens", [(160, (100, 37)), (128, (128, 1)),
                                     (512, (512, 300))])
def test_flash_decode_sweep(Sk, lens):
    B, H, Kh, D = 2, 4, 2, 32
    q = jnp.asarray(RNG.randn(B, 1, H, D) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(B, Sk, Kh, D) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(B, Sk, Kh, D) * 0.3, jnp.float32)
    lens = jnp.asarray(lens, jnp.int32)
    out = flash_decode(q, k, v, lens)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), causal=False,
                        sm_scale=1 / np.sqrt(D), lens=lens)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.swapaxes(ref, 1, 2)),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 256, 3, 32, 16, 64),
    (1, 128, 2, 64, 32, 128),
    (1, 100, 1, 16, 8, 32),             # padded tail
    (2, 64, 4, 32, 128, 64),            # big state
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    x = jnp.asarray(RNG.randn(B, S, H, P) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, S, H)) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.randn(H)) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, S, N) * 0.3, jnp.float32)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    ref = jnp.transpose(
        ssd_ref(jnp.transpose(x, (0, 2, 1, 3)), jnp.transpose(dt, (0, 2, 1)),
                A, Bm, Cm), (0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_kernel_matches_model_impl():
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 128, 3, 32, 16
    x = jnp.asarray(RNG.randn(B, S, H, P) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, S, H)) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.randn(H)) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, S, N) * 0.3, jnp.float32)
    y1 = ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    y2, _ = ssd_chunked(x, dt, A, Bm, Cm, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------- quantization
@pytest.mark.parametrize("shape", [(4, 96, 128), (256, 64), (3, 7, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matches_ref(shape, dtype):
    x = jnp.asarray(RNG.randn(*shape), dtype)
    q, s = quantize(x)
    qr, sr = quantize_ref(x.reshape(-1, shape[-1]))
    qa = np.asarray(q).reshape(-1, shape[-1]).astype(np.int32)
    qb = np.asarray(qr).astype(np.int32)
    # XLA may fuse x/s into x*(1/s): tolerate 1-quantum flips at the
    # rounding boundary on <1% of entries
    diff = np.abs(qa - qb)
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s).reshape(-1, 1),
                               np.asarray(sr), rtol=1e-6)
    xh = dequantize(q, s, dtype)
    # compare against the ref dequant of the *kernel's own* q (1-quantum
    # rounding flips above would otherwise propagate a full int8 step)
    xr = dequantize_ref(np.asarray(q).reshape(-1, shape[-1]),
                        np.asarray(s).reshape(-1, 1), dtype).reshape(shape)
    np.testing.assert_allclose(np.asarray(xh, np.float32),
                               np.asarray(xr, np.float32), rtol=1e-5,
                               atol=1e-5)


@given(st.integers(1, 8), st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_quant_error_bound(rows, cols, seed):
    """|x - dq(q(x))| <= amax/127 per row (half-ulp of the int8 grid)."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(rows, cols) * 10 ** rng.uniform(-3, 3),
                    jnp.float32)
    q, s = quantize(x)
    xh = dequantize(q, s, jnp.float32)
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    err = np.abs(np.asarray(xh) - np.asarray(x))
    assert np.all(err <= amax / 127.0 + 1e-12)


@given(st.integers(1, 600), st.integers(2, 64),
       st.sampled_from([32, 64, 128, 256]), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_quant_ragged_rows_match_single_block(rows, cols, br, seed):
    """R % block_rows != 0 goes through the pad-and-slice path; each row
    is quantized independently, so the result must be bit-identical to
    quantizing with one unpadded block covering all rows."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(rows, cols), jnp.float32)
    q, s = quantize(x, block_rows=br)
    q1, s1 = quantize(x, block_rows=rows)
    assert q.shape == x.shape and s.shape[0] == rows
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q1))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s1))
    xh = dequantize(q, s, jnp.float32, block_rows=br)
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    err = np.abs(np.asarray(xh) - np.asarray(x))
    assert np.all(err <= amax / 127.0 + 1e-12)


def test_compressed_offload_grad_flows():
    x = jnp.asarray(RNG.randn(8, 64), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(compressed_offload(x, "ffn_act") ** 2))(x)
    assert g.shape == x.shape
    assert np.all(np.isfinite(np.asarray(g)))
