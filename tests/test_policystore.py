"""repro.policystore: fingerprint stability properties, store round-trip /
eviction / corruption handling, drift-tier routing, and the runtime
integration bar from ISSUE 4 (recurring sequences skip GenPolicy; a cold
start with a warm on-disk store never enters GenPolicy)."""
import json
import os
import shutil
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs as C
from repro.common.config import (ChameleonConfig, PolicyStoreConfig,
                                 TrainConfig)
from repro.core.simulator import PolicyEntry
from repro.data.synthetic import SyntheticTokens
from repro.hostmem.bwmodel import BandwidthModel
from repro.policystore import (DriftClassifier, PolicyRecord, PolicyStore,
                               Tier, bandwidth_drift, fingerprint_tokens,
                               similarity)
from repro.runtime.trainer import Trainer

CFG = PolicyStoreConfig()

# tight enough that swap policies really generate for the reduced llama2
# (baseline peak ~12 MiB at seq 64)
BUDGET = 8 << 20


def _fp(tokens, **kw):
    return fingerprint_tokens(np.asarray(tokens, np.int32), **kw)


def _record(fp, *, budget=BUDGET, knob=1.0, kind="conservative",
            bw_curve=()):
    rec = PolicyRecord.from_policy(
        fingerprint=fp, prepare_fingerprint=fp, swap=None, candidates=[],
        n_ops=max(fp.length, 1), knob=knob, measured_t=0.1, budget=budget,
        policy_kind=kind)
    rec.bw_curve = list(bw_curve)
    return rec


def _store_with(fp, **kw):
    store = PolicyStore(PolicyStoreConfig())
    store.put(_record(fp, **kw))
    return store


# ------------------------------------------------------------ fingerprints
def test_fingerprint_identity_and_determinism():
    toks = np.arange(500) % 17 + 1
    a, b = _fp(toks), _fp(toks.copy())
    assert a.exact == b.exact
    np.testing.assert_array_equal(a.minhash, b.minhash)
    assert similarity(a, b) == 1.0


def test_fingerprint_site_bytes_separate_shape_buckets():
    """Identical token streams with different per-site byte totals (the
    seq-len bucket case) must get distinct exact keys."""
    toks = np.arange(300) % 11 + 1
    a = fingerprint_tokens(toks, {"attn_out": 1 << 20})
    b = fingerprint_tokens(toks, {"attn_out": 3 << 19})
    assert a.exact != b.exact
    assert similarity(a, b) > 0.9          # still near-identical content


def test_similarity_one_requires_exact_hash():
    """1.0 is the exclusive mark of hash equality: a token-identical
    program with different aggregates must score strictly below it (the
    reuse tier uses hash identity to gate conservative-record reuse)."""
    toks = np.arange(300) % 11 + 1
    a = fingerprint_tokens(toks, {"attn_out": 1000})
    b = fingerprint_tokens(toks)            # same tokens, no aggregates
    assert a.exact != b.exact
    assert similarity(a, b) < 1.0


def test_fingerprint_dict_roundtrip():
    fp = fingerprint_tokens(np.arange(200) % 9 + 1, {"ffn_pre": 4096})
    fp2 = type(fp).from_dict(json.loads(json.dumps(fp.to_dict())))
    assert fp2.exact == fp.exact and fp2.length == fp.length
    np.testing.assert_array_equal(fp2.minhash, fp.minhash)
    assert similarity(fp, fp2) == 1.0


@given(st.lists(st.integers(1, 25), min_size=300, max_size=600),
       st.integers(0, 6))
@settings(max_examples=25, deadline=None)
def test_minor_perturbation_stays_reuse(seq, extra):
    """<= ~2% appended ops keep the sequence in the reuse tier."""
    base = np.asarray(seq, np.int32)
    fp = _fp(base)
    store = _store_with(fp)
    perturbed = np.concatenate([base, base[: extra]])
    dec = DriftClassifier(CFG).classify(_fp(perturbed), store)
    assert dec.tier is Tier.REUSE, (dec.tier, dec.similarity, dec.reason)


@given(st.lists(st.integers(1, 25), min_size=300, max_size=600))
@settings(max_examples=25, deadline=None)
def test_layer_doubling_falls_to_regen(seq):
    """A layer-count change ~tiles the scanned region: the shingle set
    barely moves but the length gate must refuse reuse AND warm-start."""
    base = np.asarray(seq, np.int32)
    store = _store_with(_fp(base))
    doubled = np.concatenate([base, base])
    dec = DriftClassifier(CFG).classify(_fp(doubled), store)
    assert dec.tier is Tier.REGEN, (dec.tier, dec.similarity, dec.reason)


@given(st.lists(st.integers(1, 20), min_size=300, max_size=500))
@settings(max_examples=25, deadline=None)
def test_model_change_falls_to_regen(seq):
    base = np.asarray(seq, np.int32)
    store = _store_with(_fp(base))
    other = np.asarray(seq, np.int32) + 40        # disjoint op vocabulary
    dec = DriftClassifier(CFG).classify(_fp(other), store)
    assert dec.tier is Tier.REGEN


# ------------------------------------------------------------------- store
@pytest.fixture
def tmpdir():
    d = tempfile.mkdtemp()
    yield d
    shutil.rmtree(d, ignore_errors=True)


def _swap_record(fp, n_entries=3, budget=BUDGET):
    entries = [PolicyEntry(uid=i, site="attn_out", layer=i, nbytes=1 << 16,
                           birth=10 * i, death=10 * i + 100,
                           swap_in_op=10 * i + 80, swap_out_done_op=10 * i + 5,
                           stalled=False, score=0.5 + i)
               for i in range(n_entries)]

    class _Swap:
        pass

    sw = _Swap()
    sw.entries = entries
    sw.projected_peak, sw.baseline_peak, sw.budget = 1 << 20, 2 << 20, budget
    sw.stall_time, sw.t_iter, sw.n_ops, sw.contention_s = 0.0, 0.1, 500, 0.0
    return PolicyRecord.from_policy(
        fingerprint=fp, prepare_fingerprint=fp, swap=sw, candidates=[],
        n_ops=500, knob=2.0, measured_t=0.123, budget=budget)


def test_store_disk_roundtrip(tmpdir):
    fp = _fp(np.arange(400) % 13 + 1)
    store = PolicyStore(PolicyStoreConfig(dir=tmpdir))
    store.put(_swap_record(fp))

    store2 = PolicyStore(PolicyStoreConfig(dir=tmpdir))
    assert len(store2) == 1 and store2.n_loaded == 1
    rec = store2.get_exact(fp.exact)
    assert rec is not None and rec.knob == 2.0 and rec.measured_t == 0.123
    sw = rec.swap_policy()
    assert sw is not None and len(sw.entries) == 3
    assert sw.entries[1].swap_out_done_op == 15
    assert similarity(fp, rec.prepare_fingerprint) == 1.0


def test_store_eviction_is_lru_and_removes_files(tmpdir):
    store = PolicyStore(PolicyStoreConfig(dir=tmpdir, max_records=2))
    fps = [_fp(np.arange(300) % k + 1) for k in (7, 11, 13)]
    for fp in fps:
        store.put(_record(fp))
    assert len(store) == 2 and store.n_evictions == 1
    assert store.get_exact(fps[0].exact) is None       # oldest evicted
    on_disk = {n[:-5] for n in os.listdir(tmpdir) if n.endswith(".json")}
    assert on_disk == {fps[1].exact, fps[2].exact}


def test_store_corrupt_and_wrong_schema_skipped(tmpdir):
    fp = _fp(np.arange(200) % 5 + 1)
    store = PolicyStore(PolicyStoreConfig(dir=tmpdir))
    store.put(_record(fp))
    with open(os.path.join(tmpdir, "garbage.json"), "w") as f:
        f.write("{not json!!")
    bad = _record(_fp(np.arange(100) % 3 + 1)).to_json()
    bad["schema"] = 99
    with open(os.path.join(tmpdir, "badschema.json"), "w") as f:
        json.dump(bad, f)

    store2 = PolicyStore(PolicyStoreConfig(dir=tmpdir))
    assert len(store2) == 1
    assert store2.n_corrupt == 2
    assert store2.get_exact(fp.exact) is not None


def test_store_touch_bumps_lru(tmpdir):
    store = PolicyStore(PolicyStoreConfig(dir=tmpdir, max_records=2))
    fps = [_fp(np.arange(300) % k + 1) for k in (7, 11, 13)]
    store.put(_record(fps[0]))
    store.put(_record(fps[1]))
    store.touch(store.get_exact(fps[0].exact))         # 0 now most recent
    store.put(_record(fps[2]))                         # evicts 1, not 0
    assert store.get_exact(fps[0].exact) is not None
    assert store.get_exact(fps[1].exact) is None
    assert store.get_exact(fps[0].exact).uses == 1


def test_readonly_store_never_deletes_shared_records(tmpdir):
    """A serving process attaching a shared training store with a smaller
    capacity must not evict other writers' on-disk records."""
    writer = PolicyStore(PolicyStoreConfig(dir=tmpdir))
    fps = [_fp(np.arange(300) % k + 1) for k in (7, 11, 13)]
    for fp in fps:
        writer.put(_record(fp))
    reader = PolicyStore(PolicyStoreConfig(dir=tmpdir, max_records=2),
                         readonly=True)
    assert len(reader) == 2                         # memory side trimmed
    on_disk = [n for n in os.listdir(tmpdir) if n.endswith(".json")]
    assert len(on_disk) == 3                        # disk side untouched
    reader.touch(reader.records()[0])               # no writes either
    assert len([n for n in os.listdir(tmpdir) if n.endswith(".json")]) == 3


def test_nearest_exact_key_fast_path():
    fp = _fp(np.arange(400) % 13 + 1)
    store = _store_with(fp)
    rec, sim = store.nearest(fp)
    assert sim == 1.0 and rec.key == fp.exact
    assert store.n_exact_hits == 1 and store.n_sim_hits == 0


def test_nearest_below_warm_floor_counts_as_miss():
    """A best match the classifier can't use must not report as a hit."""
    fp = _fp(np.arange(400) % 13 + 1)
    store = _store_with(fp)
    unrelated = _fp(np.arange(400) % 7 + 60)
    rec, sim = store.nearest(unrelated)
    assert rec is not None and sim < CFG.warm_threshold
    assert store.n_misses == 1 and store.n_sim_hits == 0
    store.nearest(fp)
    assert store.n_exact_hits == 1


def test_projected_peak_replay():
    """The reuse tier re-verifies a remapped schedule with the same
    timeline replay generate_policy prices a fresh one with."""
    from repro.core.policy import projected_peak
    from repro.core.profiler import ProfileData, TensorInstance
    tensors = [TensorInstance(0, 100, birth=1, death=9, site="a"),
               TensorInstance(1, 100, birth=3, death=6, site="a")]
    prof = ProfileData(np.zeros(10, np.int32), tensors, t_iter=0.1,
                       static_bytes=7)
    assert projected_peak(prof, []) == 207          # both live at op 3
    e = PolicyEntry(uid=0, site="a", layer=0, nbytes=100, birth=1, death=9,
                    swap_in_op=8, swap_out_done_op=2)
    assert projected_peak(prof, [e]) == 107         # t0 absent during [2,8)


# ------------------------------------------------------------------- drift
def test_budget_mismatch_caps_reuse_at_warm_start():
    fp = _fp(np.arange(400) % 13 + 1)
    store = _store_with(fp, budget=8 << 20)
    dec = DriftClassifier(CFG).classify(fp, store, budget=16 << 20)
    assert dec.tier is Tier.WARM_START and "budget" in dec.reason


def test_bandwidth_drift_guard():
    fp = _fp(np.arange(400) % 13 + 1)
    snapshot = [(1 << 20, 1e-4), (1 << 22, 4e-4)]
    store = _store_with(fp, bw_curve=snapshot)
    rec = store.records()[0]

    drifted = BandwidthModel(32.0)
    drifted.observe(1 << 20, 1e-3)          # 10x slower than the snapshot
    drifted.observe(1 << 22, 4e-3)
    assert bandwidth_drift(rec, drifted) > CFG.bw_drift_limit
    dec = DriftClassifier(CFG).classify(fp, store, bwmodel=drifted)
    assert dec.tier is Tier.WARM_START and "bw_drift" in dec.reason

    # an uncalibrated live model is the constant fallback, not drift
    assert bandwidth_drift(rec, BandwidthModel(32.0)) == 1.0
    dec2 = DriftClassifier(CFG).classify(fp, store,
                                         bwmodel=BandwidthModel(32.0))
    assert dec2.tier is Tier.REUSE


def test_demote_counts():
    dc = DriftClassifier(CFG)
    fp = _fp(np.arange(100) % 5 + 1)
    dec = dc.classify(fp, _store_with(fp))
    dec2 = dc.demote(dec, "match-miss")
    assert dec2.tier is Tier.WARM_START
    assert dc.counters["demoted"] == 1 and dc.counters["warm_start"] == 1
    # the failed reuse is taken back: tiers sum to the adaptation count
    assert dc.counters["reuse"] == 0


# ------------------------------------------------- runtime integration bar
# eval period must exceed one cold adaptation (m warmup + n genpolicy
# steps ~ 9-10) or the first adaptation never completes and stores
def _trainer(store_dir, ckdir, *, steps=40, eval_every=13, seed=0):
    cfg = C.get_reduced("llama2_paper")
    tcfg = TrainConfig(steps=steps, checkpoint_every=0, checkpoint_dir=ckdir,
                       eval_every=eval_every, warmup_steps=2,
                       learning_rate=1e-3)
    cham = ChameleonConfig(
        enabled=True, hbm_budget_bytes=BUDGET,
        policystore=PolicyStoreConfig(enabled=store_dir is not None,
                                      dir=store_dir or ""))
    data = SyntheticTokens(cfg.vocab_size, 64, 4, seed=seed)
    return Trainer(cfg, tcfg, cham, data=data)


@pytest.fixture(scope="module")
def warm_run():
    """One store-backed training run with eval interleave (shared by the
    recurring-sequence and cold-restart tests)."""
    store_dir, ckdir = tempfile.mkdtemp(), tempfile.mkdtemp()
    tr = _trainer(store_dir, ckdir)
    rep = tr.train(40)
    yield store_dir, tr, rep
    shutil.rmtree(store_dir, ignore_errors=True)
    shutil.rmtree(ckdir, ignore_errors=True)


def test_recurring_sequence_skips_genpolicy(warm_run, tmpdir):
    """ISSUE 4 acceptance: train->eval->train with the store enabled takes
    strictly fewer GenPolicy steps than with it disabled, and the math is
    unchanged."""
    _store_dir, tr_on, rep_on = warm_run
    tr_off = _trainer(None, tmpdir)
    rep_off = tr_off.train(40)
    assert rep_on.genpolicy_steps < rep_off.genpolicy_steps, (
        rep_on.genpolicy_steps, rep_off.genpolicy_steps)
    tiers = rep_on.policystore["tiers"]
    assert tiers["reuse"] + tiers["warm_start"] >= 1
    assert rep_off.policystore is None
    np.testing.assert_allclose(rep_on.losses, rep_off.losses,
                               rtol=2e-4, atol=2e-4)
    # reuse adaptations recover in strictly fewer steps than cold ones
    on_steps = [a["steps"] for a in rep_on.policystore["adaptations"]
                if a["tier"] == "reuse"]
    off_steps = [a["steps"] for a in tr_off.rt.adaptations]
    if on_steps and off_steps:
        assert max(on_steps) < min(s for s in off_steps if s > 0)


def test_cold_restart_applies_cached_policy(warm_run, tmpdir):
    """ISSUE 4 acceptance: a cold-started process with a warm on-disk
    store applies a cached policy without entering GenPolicy."""
    store_dir, _tr, _rep = warm_run
    tr = _trainer(store_dir, tmpdir, steps=8, eval_every=0)
    assert len(tr.rt.store) >= 1           # loaded from disk
    rep = tr.train(8)
    assert rep.genpolicy_steps == 0, rep.stages
    assert set(rep.stages) == {"Stable"}
    assert rep.policystore["tiers"]["reuse"] >= 1
    assert rep.policystore["store"]["loaded"] >= 1


def test_shape_drift_triggers_readaptation(tmpdir):
    """Seq-len bucket switches are invisible to the token stream; the
    runtime must still re-enter WarmUp (and the store must key the two
    buckets separately)."""
    tr = _trainer(os.path.join(tmpdir, "store"),
                  os.path.join(tmpdir, "ck"), steps=24, eval_every=0)
    cfg = tr.cfg
    other = SyntheticTokens(cfg.vocab_size, 96, 4, seed=1)

    def hook(step):
        if step == 11:
            tr.data = other

    rep = tr.train(24, fault_hook=hook)
    assert any(why == "shape-change"
               for _s, why, _to in tr.rt.machine.transitions), \
        tr.rt.machine.transitions
    assert not rep.failures
    assert len(tr.rt.store) >= 1
