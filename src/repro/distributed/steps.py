"""Train / serve step builders with production sharding.

Everything the dry-run lowers and the Trainer executes is built here:

  * ``make_train_step``  — fused loss+grad+clip+AdamW+loss-scale iteration
                           (what the paper's profiler sees as one sequence)
  * ``make_grad_step`` / ``make_apply_step`` — the *split* dispatch pair the
                           eager-style trainer uses so host-side loss-scale
                           skips really change the operator stream (§2.3)
  * ``make_prefill_step`` / ``make_decode_step`` — serving
  * sharding-spec derivation for params / optimizer state (ZeRO stages)

ZeRO mapping (DeepSpeed-analogue the paper builds on): stage 1/2 shard the
AdamW m/v/master tensors across (pod, data) by remapping the logical
``embed`` axis; stage 3 (FSDP) also shards the parameters themselves.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed import sharding as shd
from repro.models.registry import ModelApi, get_api
from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               clip_by_global_norm)
from repro.optim.schedules import warmup_cosine

ZERO_OPT_RULES = {"embed": ("pod", "data"), "layers": None}
ZERO3_PARAM_RULES = {"embed": ("pod", "data")}


# --------------------------------------------------------- sharding specs
def sanitize_specs(spec_tree, sds_tree, mesh: Optional[Mesh]):
    """Drop sharding on dims the mesh cannot divide evenly.  jit *argument*
    shardings (unlike internal constraints) reject uneven partitions, so
    e.g. vocab=49155 or heads=20 fall back to replication on that dim."""
    if mesh is None:
        return spec_tree

    def axis_size(entry) -> int:
        if entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in names:
            n *= mesh.shape[a]
        return n

    def one(spec: P, sds):
        shape = getattr(sds, "shape", None)
        if shape is None:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, entry in zip(shape, entries[: len(shape)]):
            out.append(entry if dim % axis_size(entry) == 0 else None)
        return P(*out)

    return jax.tree.map(one, spec_tree, sds_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_specs(axes_tree, mesh: Optional[Mesh], zero3: bool = False,
                sds_tree=None):
    rules = ZERO3_PARAM_RULES if zero3 else None
    with shd.use_mesh(mesh, rules):
        spec = shd.tree_spec(axes_tree, mesh)
    if sds_tree is not None:
        spec = sanitize_specs(spec, sds_tree, mesh)
    return spec


def opt_specs(axes_tree, mesh: Optional[Mesh], zero_stage: int,
              opt_sds: Optional[AdamWState] = None):
    rules = ZERO_OPT_RULES if zero_stage >= 1 else None
    with shd.use_mesh(mesh, rules):
        p_spec = shd.tree_spec(axes_tree, mesh)
    out = AdamWState(P(), p_spec, p_spec, p_spec)
    if opt_sds is not None:
        out = AdamWState(
            P(),
            sanitize_specs(out.m, opt_sds.m, mesh),
            sanitize_specs(out.v, opt_sds.v, mesh),
            sanitize_specs(out.master, opt_sds.master, mesh)
            if opt_sds.master is not None else None)
    return out


def batch_specs_sharding(batch_tree, mesh: Optional[Mesh]):
    if mesh is None:
        return None
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    def one(x):
        spec = [None] * getattr(x, "ndim", len(x.shape))
        spec[0] = tuple(axes)
        return P(*spec)
    return jax.tree.map(one, batch_tree)


def to_shardings(spec_tree, mesh: Optional[Mesh]):
    if mesh is None or spec_tree is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------- state init
def abstract_params(cfg: ModelConfig, api: Optional[ModelApi] = None):
    """ShapeDtypeStructs for params — no allocation (dry-run safe)."""
    api = api or get_api(cfg)
    return jax.eval_shape(lambda k: api.init(cfg, k)[0], jax.random.PRNGKey(0))


def abstract_train_state(cfg: ModelConfig):
    params_sds = abstract_params(cfg)
    opt_sds = jax.eval_shape(adamw_init, params_sds)
    return params_sds, opt_sds


@functools.lru_cache(maxsize=64)
def param_axes(cfg: ModelConfig):
    """Logical-axes tree for params.  The axes are plain Python built as a
    side effect of init, so an abstract eval_shape trace captures them
    without allocating a single parameter."""
    api = get_api(cfg)
    box = {}

    def f(k):
        p, a = api.init(cfg, k)
        box["axes"] = a
        return p

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["axes"]


# ----------------------------------------------------------------- steps
def make_loss_fn(cfg: ModelConfig, policy=None):
    api = get_api(cfg)

    def loss_fn(params, batch, loss_scale):
        loss, metrics = api.loss_fn(cfg, params, batch, policy=policy)
        return loss * loss_scale, (loss, metrics)

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, policy=None,
                    grad_shardings=None) -> Callable:
    """Fused iteration: grads + clip + AdamW + schedule (+ scaled loss).
    ``grad_shardings`` (a params-shaped tree of NamedShardings) pins the
    gradients to the optimizer-state layout right at the backward output —
    XLA then emits a reduce-scatter instead of a full all-reduce (§Perf
    cell B iteration 2)."""
    loss_fn = make_loss_fn(cfg, policy)

    def train_step(params, opt_state: AdamWState, batch, loss_scale):
        (scaled, (loss, _m)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, loss_scale)
        if grad_shardings is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, grad_shardings)
        grads = jax.tree.map(lambda g: g / loss_scale.astype(g.dtype), grads)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = warmup_cosine(opt_state.step, tcfg.learning_rate,
                           tcfg.warmup_steps, tcfg.steps)
        new_params, new_opt = adamw_update(params, grads, opt_state, tcfg, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_opt, metrics

    return train_step


def make_grad_step(cfg: ModelConfig, tcfg: TrainConfig, policy=None):
    loss_fn = make_loss_fn(cfg, policy)

    def grad_step(params, batch, loss_scale):
        (_, (loss, _m)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, loss_scale)
        grads = jax.tree.map(lambda g: g / loss_scale, grads)
        finite = jnp.all(jnp.stack([
            jnp.all(jnp.isfinite(g)) for g in jax.tree_util.tree_leaves(grads)]))
        return loss, grads, finite

    return grad_step


def make_apply_step(cfg: ModelConfig, tcfg: TrainConfig):
    def apply_step(params, opt_state: AdamWState, grads):
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = warmup_cosine(opt_state.step, tcfg.learning_rate,
                           tcfg.warmup_steps, tcfg.steps)
        new_params, new_opt = adamw_update(params, grads, opt_state, tcfg, lr)
        return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}
    return apply_step


def make_eval_step(cfg: ModelConfig, policy=None):
    api = get_api(cfg)

    def eval_step(params, batch):
        loss, _ = api.loss_fn(cfg, params, batch, policy=policy)
        return loss

    return eval_step


def make_prefill_step(cfg: ModelConfig, policy=None):
    api = get_api(cfg)

    def prefill_step(params, batch):
        logits, _ = api.forward(cfg, params, batch["tokens"],
                                memory=batch.get("memory"), policy=policy)
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    api = get_api(cfg)

    def decode_step(params, tokens, state):
        return api.decode_step(cfg, params, tokens, state)

    return decode_step
