"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Models annotate activations/params with *logical* axis names; a rules table
maps logical names to mesh axes.  Outside of a mesh context every helper is a
no-op so the same model code runs in single-device tests, the Chameleon
runtime, and the 512-chip dry-run unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, **kw):
    """Version-portable shard_map: ``jax.shard_map`` on new JAX, the
    ``jax.experimental`` spelling (with ``check_vma`` -> ``check_rep``)
    on older releases."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    if "check_vma" in kw:
        kw["check_rep"] = kw.pop("check_vma")
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

# logical axis -> mesh axis (or tuple of mesh axes, or None = replicate)
DEFAULT_RULES = {
    "batch": ("pod", "data"),       # DP across pods and the data axis
    "seq": None,
    "act_embed": None,
    "act_heads": "model",           # activation head dim (TP)
    "act_kv_heads": None,           # GQA: few kv heads -> replicated
    "act_mlp": "model",
    "act_vocab": "model",
    "kv_seq": "model",              # decode-time sequence parallelism over KV
    # --- parameters ---
    "embed": None,                  # param d_model dim
    "fsdp_embed": ("pod", "data"),  # ZeRO-3/FSDP shard dim for big params
    "heads": "model",
    "kv_heads": None,
    "q_dim": "model",               # fused num_heads*head_dim
    "kv_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",             # expert parallelism
    "expert_mlp": None,
    "layers": None,                 # stacked scan dim
    "conv": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "pos": None,
    "scalar": None,
}

# §Perf hillclimb: swap frees the memory that forced tensor parallelism, so
# the whole mesh becomes a DP domain (paper Table 2's TP->DP substitution).
# Params/optimizer shard over every axis (ZeRO-3 via rules); activations
# shard on batch only; all per-layer TP collectives disappear in favor of
# ZeRO param all-gathers + grad reduce-scatters.
DP_ONLY_RULES = {
    "batch": ("pod", "data", "model"),
    "embed": ("pod", "data", "model"),
    "fsdp_embed": ("pod", "data", "model"),
    "heads": None, "q_dim": None, "kv_dim": None, "mlp": None,
    "vocab": None, "experts": None, "expert_mlp": None,
    "ssm_inner": None, "ssm_heads": None,
    "act_heads": None, "act_mlp": None, "act_vocab": None, "kv_seq": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Install mesh + logical rules for model sharding annotations.
    Nested calls inherit the enclosing context's rules (so e.g. a dp_only
    outer context composes with the ZeRO overrides applied inside
    spec-building helpers)."""
    prev = (_CTX.mesh, _CTX.rules)
    base = _CTX.rules if _CTX.mesh is not None else DEFAULT_RULES
    _CTX.mesh = mesh
    merged = dict(base)
    if rules:
        merged.update(rules)
    _CTX.rules = merged
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _resolve(name: Optional[str], mesh: Mesh):
    if name is None:
        return None
    ax = _CTX.rules.get(name, None)
    if ax is None:
        return None
    if isinstance(ax, tuple):
        present = tuple(a for a in ax if a in mesh.axis_names)
        return present if present else None
    return ax if ax in mesh.axis_names else None


def spec(logical: Sequence[Optional[str]]) -> P:
    mesh = _CTX.mesh
    if mesh is None:
        return P()
    return P(*[_resolve(n, mesh) for n in logical])


def sharding(logical: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    mesh = _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(logical))


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint against the active mesh; no-op without one."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec(logical)))


def tree_sharding(axes_tree, mesh: Optional[Mesh] = None):
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None

    def one(axes):
        return NamedSharding(mesh, P(*[_resolve(n, mesh) for n in axes]))

    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, type(None))) for e in x))


def tree_spec(axes_tree, mesh: Optional[Mesh] = None):
    mesh = mesh or _CTX.mesh

    def one(axes):
        if mesh is None:
            return P()
        return P(*[_resolve(n, mesh) for n in axes])

    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, type(None))) for e in x))
