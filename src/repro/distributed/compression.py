"""Cross-pod gradient compression with error feedback (beyond-paper).

The pod axis is the slow (DCI) link at multi-pod scale.  Instead of an f32/
bf16 all-reduce across pods, each pod quantizes its local gradient partial
to int8 (+ per-row f32 scales), all-gathers the *int8* payload across the
pod axis (wire bytes ÷ 2–4), and reduces locally after dequantization.
Error feedback accumulates the quantization residual into the next step so
the compression bias telescopes away (EF-SGD).

Implemented with ``jax.shard_map`` over the pod axis so the all-gather
really carries int8 on the wire — visible in the dry-run HLO as
``all-gather`` ops with s8 operands (the roofline's collective term drops
accordingly).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def _quant_rows(x2d):
    amax = jnp.max(jnp.abs(x2d), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x2d / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _compressed_allreduce_leaf(g, axis: str):
    shape = g.shape
    F = shape[-1] if g.ndim > 1 else g.size
    x2d = g.reshape(-1, F).astype(jnp.float32)
    q, s = _quant_rows(x2d)
    qg = jax.lax.all_gather(q, axis)          # (pods, R, F) int8 on the wire
    sg = jax.lax.all_gather(s, axis)          # (pods, R, 1) f32 (tiny)
    summed = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
    return summed.reshape(shape).astype(g.dtype)


def compressed_psum_tree(grads, axis: str):
    return jax.tree.map(lambda g: _compressed_allreduce_leaf(g, axis), grads)


def make_compressed_grad_sync(mesh: Mesh, axis: str = "pod"):
    """Returns sync(grads_local, err) -> (grads_synced, new_err).

    Call inside a shard_map'ed step whose grads are per-pod partials; the
    error-feedback state `err` has the same structure as grads."""
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}")

    def sync(grads, err):
        def leaf(g, e):
            corrected = g.astype(jnp.float32) + e
            shape = corrected.shape
            F = shape[-1] if corrected.ndim > 1 else corrected.size
            x2d = corrected.reshape(-1, F)
            q, s = _quant_rows(x2d)
            new_e = (x2d - q.astype(jnp.float32) * s).reshape(shape)
            qg = jax.lax.all_gather(q, axis)
            sg = jax.lax.all_gather(s, axis)
            summed = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
            return (summed / n).reshape(shape).astype(g.dtype), new_e

        pairs = jax.tree.map(leaf, grads, err)
        synced = jax.tree.map(lambda t: t[0], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return synced, new_err

    return sync
