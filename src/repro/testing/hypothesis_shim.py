"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface
this repo's property tests use.

The real package is a dev dependency (see pyproject.toml); hermetic
environments without it would otherwise fail test *collection*.  When
:func:`install` runs (from ``tests/conftest.py``, only if the genuine
package is absent), ``import hypothesis`` resolves here and the property
tests run as seeded random sweeps: ``@given`` draws ``max_examples``
pseudo-random examples from a fixed-seed RNG — deterministic across
runs, no shrinking, same assertion surface.

Supported subset: ``given``, ``settings`` (``max_examples`` honored,
``deadline`` ignored), ``strategies.integers/floats/booleans/
sampled_from/lists/tuples/composite``.
"""
from __future__ import annotations

import functools
import random
import sys
import types
from typing import Any, Callable, List, Sequence

_SEED = 0
_DEFAULT_MAX_EXAMPLES = 50


# --------------------------------------------------------------- strategies
class SearchStrategy:
    def do_draw(self, rnd: random.Random) -> Any:
        raise NotImplementedError

    def map(self, fn: Callable) -> "SearchStrategy":
        return _Mapped(self, fn)


class _Mapped(SearchStrategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def do_draw(self, rnd):
        return self.fn(self.base.do_draw(rnd))


class _Integers(SearchStrategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(1 << 30) if min_value is None else min_value
        self.hi = (1 << 30) if max_value is None else max_value

    def do_draw(self, rnd):
        # bias toward the boundaries, like real hypothesis
        r = rnd.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return rnd.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value=None, max_value=None, **_kw):
        self.lo = 0.0 if min_value is None else min_value
        self.hi = 1.0 if max_value is None else max_value

    def do_draw(self, rnd):
        return self.lo + (self.hi - self.lo) * rnd.random()


class _Booleans(SearchStrategy):
    def do_draw(self, rnd):
        return rnd.random() < 0.5


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence):
        self.elements = list(elements)

    def do_draw(self, rnd):
        return rnd.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size=0, max_size=None,
                 **_kw):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def do_draw(self, rnd):
        n = rnd.randint(self.min_size, self.max_size)
        return [self.elements.do_draw(rnd) for _ in range(n)]


class _Tuples(SearchStrategy):
    def __init__(self, *elements: SearchStrategy):
        self.elements = elements

    def do_draw(self, rnd):
        return tuple(s.do_draw(rnd) for s in self.elements)


class _Composite(SearchStrategy):
    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def do_draw(self, rnd):
        def draw(strategy: SearchStrategy):
            return strategy.do_draw(rnd)
        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn: Callable) -> Callable:
    @functools.wraps(fn)
    def make(*args, **kwargs):
        return _Composite(fn, args, kwargs)
    return make


# ------------------------------------------------------------- given/settings
def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strategies: SearchStrategy):
    def deco(fn):
        n = getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kwargs):
            _run(fn, strategies, n, fixture_args, fixture_kwargs)

        # drawn values fill the LAST len(strategies) params; anything before
        # them is a pytest fixture and must stay visible in the signature
        import inspect
        sig = inspect.signature(fn)
        keep = list(sig.parameters.values())[: len(sig.parameters)
                                             - len(strategies)]
        wrapper.__signature__ = sig.replace(parameters=keep)
        del wrapper.__wrapped__           # stop pytest unwrapping to fn
        wrapper._shim_max_examples = n
        return wrapper
    return deco


def _run(fn, strategies, n, fixture_args, fixture_kwargs):
    rnd = random.Random(_SEED)
    for i in range(n):
        drawn = [s.do_draw(rnd) for s in strategies]
        try:
            fn(*fixture_args, *drawn, **fixture_kwargs)
        except _Unsatisfied:
            continue
        except Exception as e:
            raise AssertionError(
                f"property failed on shim example {i}: {drawn!r}") from e


class HealthCheck:           # referenced by suppress_health_check= kwargs
    all = classmethod(lambda cls: [])
    too_slow = data_too_large = filter_too_much = None


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


# ------------------------------------------------------------------ install
def install() -> None:
    """Register this shim as ``hypothesis`` / ``hypothesis.strategies``
    in ``sys.modules`` (no-op if the real package ever got there first)."""
    if "hypothesis" in sys.modules:
        return
    import importlib.machinery
    hyp = types.ModuleType("hypothesis")
    hyp.__spec__ = importlib.machinery.ModuleSpec("hypothesis", None)
    hyp.given, hyp.settings, hyp.assume = given, settings, assume
    hyp.HealthCheck = HealthCheck
    hyp.__shim__ = True
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _Integers
    st.floats = _Floats
    st.booleans = _Booleans
    st.sampled_from = _SampledFrom
    st.lists = _Lists
    st.tuples = _Tuples
    st.composite = composite
    st.SearchStrategy = SearchStrategy
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
