"""repro.adapt — the adaptation pipeline as an async background service.

The paper's §5 cycle (Detailed profiling → GenPolicy variant search →
policy application) extracted out of ``ChameleonRuntime`` into:

  * :class:`AdaptSnapshot` — the immutable inputs one adaptation reads
    (traced program, frozen bandwidth curve, per-class link backlog,
    budget, knobs, source fingerprint);
  * :class:`AdaptationPipeline` — the cycle itself as deterministic
    computation, shared by the inline reference mode and the worker;
  * :class:`AdaptationService` — job queue + single worker thread +
    single-slot mailbox + generation-counter staleness, plus speculative
    pre-generation of policies for predicted-recurring fingerprints.

See ``docs/adaptation.md`` for the job lifecycle and swap-in protocol.
"""
from repro.adapt.pipeline import (VARIANT_KNOBS, AdaptResult,
                                  AdaptationPipeline, CachedApply,
                                  PolicyVariant)
from repro.adapt.service import (AdaptJob, AdaptationService,
                                 RecurrencePredictor)
from repro.adapt.snapshot import AdaptSnapshot, FrozenBacklog

__all__ = [
    "AdaptJob",
    "AdaptResult",
    "AdaptSnapshot",
    "AdaptationPipeline",
    "AdaptationService",
    "CachedApply",
    "FrozenBacklog",
    "PolicyVariant",
    "RecurrencePredictor",
    "VARIANT_KNOBS",
]
