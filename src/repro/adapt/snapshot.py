"""Immutable adaptation inputs (repro.adapt).

An :class:`AdaptSnapshot` freezes everything the §5 adaptation cycle
reads, at the moment drift settles, so the background worker never
touches live runtime state:

  * the traced baseline jaxpr (or an already-materialized
    :class:`~repro.core.profiler.ProfileData`) plus the measured
    ``t_iter`` it should be priced at;
  * a *copy* of the bandwidth-model curve
    (:meth:`~repro.hostmem.bwmodel.BandwidthModel.snapshot`) — variant
    pricing must not chase the live EMA mid-search;
  * the transfer engine's per-class backlog at snapshot time
    (``queued_delay`` seconds + per-class queued bytes) — the sustained
    contention the simulator charges, frozen the same way;
  * the HBM budget and the grouping knobs the search will try;
  * the iteration fingerprint (exact hash) identifying the op stream the
    snapshot was taken from — the staleness check compares a published
    result's source fingerprint against the live stream before install.

The profile is materialized lazily (:meth:`ensure_profile`): the common
recurring-drift case snapshots a *cached* jaxpr on the training thread
(cheap), and the worker pays the ``profile_jaxpr`` traversal off the
critical path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.core.profiler import ProfileData, profile_jaxpr


class FrozenBacklog:
    """Engine stand-in for snapshot-time contention: answers
    ``queued_delay`` with the frozen per-class estimate so
    ``generate_policy`` prices the backlog the snapshot saw, not whatever
    the live engine is doing when the worker happens to run."""

    def __init__(self, delays: Optional[Dict[str, float]] = None,
                 default: float = 0.0,
                 occupancy: Optional[Dict[str, float]] = None):
        self._delays = dict(delays or {})
        self._default = float(default)
        self._occupancy = dict(occupancy or {})

    def queued_delay(self, cls: str = "policy_swap",
                     kind: str = "swap_out") -> float:
        return self._delays.get(cls, self._default)

    def sustained_contention(self, cls: str = "policy_swap") -> float:
        """Frozen per-class link occupancy (arrival-rate EWMA × seconds
        per byte of the *other* classes, as the engine computed it at
        snapshot time) — keeps async adaptation pricing identical to an
        inline run against the live engine."""
        return self._occupancy.get(cls, 0.0)


@dataclass
class AdaptSnapshot:
    """One adaptation's frozen inputs.  Treated as immutable after
    construction; ``profile`` is the only field written later (the lazy
    ``ensure_profile`` memo) and only ever from the worker thread."""
    jaxpr: Any = None                    # traced baseline program
    t_iter: float = 1.0                  # measured iteration time to price at
    budget: int = 0                      # HBM budget (bytes)
    bwmodel: Any = None                  # frozen BandwidthModel copy (or None)
    contention_s: float = 0.0            # queued_delay at snapshot time
    backlog: Dict[str, dict] = field(default_factory=dict)  # per-class gauges
    gen_knobs: Tuple[float, ...] = ()    # grouping knobs the search tries
    iter_exact: Optional[str] = None     # live-stream fingerprint (exact hash)
    iter_fp: Any = None                  # full iteration Fingerprint (or None)
    step: int = 0                        # step the snapshot was taken at
    profile: Optional[ProfileData] = None

    def ensure_profile(self) -> ProfileData:
        """Materialize the Detailed-mode profile (worker-side cost)."""
        if self.profile is None:
            if self.jaxpr is None:
                raise ValueError("snapshot carries neither profile nor jaxpr")
            self.profile = profile_jaxpr(self.jaxpr, t_iter=self.t_iter)
        return self.profile

    def engine_view(self) -> FrozenBacklog:
        """The frozen-contention engine stand-in for policy generation."""
        delays = {c: float(d.get("queued_delay", 0.0))
                  for c, d in self.backlog.items()}
        occ = {c: float(d.get("occupancy", 0.0))
               for c, d in self.backlog.items()}
        return FrozenBacklog(delays, default=self.contention_s,
                             occupancy=occ)
