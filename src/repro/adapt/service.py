"""Async adaptation service (repro.adapt).

Drift never stalls an iteration: detection *enqueues* an
:class:`AdaptJob` (an immutable :class:`AdaptSnapshot` plus the
generation epoch it belongs to) and the training loop keeps serving the
old policy — or the conservative fallback on first sight — while a
single daemon worker runs :meth:`AdaptationPipeline.run` against the
snapshot.

**Swap-in protocol.**  The worker publishes each completed
:class:`AdaptResult` to a single-slot mailbox (newest wins — a stale
unconsumed result is replaced, and counted as discarded).  The runtime
polls the mailbox only at the iteration boundary, *after*
``end_iteration``'s mirror swaps drain, so an install never races the
engine feedback of the policy that just ran.  Every result carries the
epoch of the job that produced it; :meth:`invalidate` (called on every
new drift event) bumps the monotone generation counter so in-flight
results for a superseded stream are discarded at publish or poll time —
whichever sees the mismatch first.  The source fingerprint rides along
too: a result only installs onto the stream it was computed for.

**Speculative pre-generation.**  Completed adaptations feed a
first-order recurrence predictor over iteration fingerprints
(train→eval interleaves are periodic: ...A,B,A,B...).  When the
successor of the fingerprint just adapted is known and its snapshot is
still retained, the worker pre-generates that policy during idle
background time and parks it outside the mailbox; the next phase switch
installs it with **zero** inline GenPolicy steps and nothing in flight.

**Crash hygiene.**  A worker exception must never kill training: the
loop catches it, emits an ``adaptation.failed`` audit event and metrics
counter, publishes the conservative fallback for the job's snapshot
(guaranteed to fit by construction), and keeps consuming jobs.  If the
thread itself ever dies, :meth:`submit` re-arms it.
"""
from __future__ import annotations

import collections
import queue
import sys
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro import faults, obs
from repro.adapt.pipeline import AdaptationPipeline, AdaptResult
from repro.adapt.snapshot import AdaptSnapshot

_SHUTDOWN = None                         # queue sentinel


@dataclass
class AdaptJob:
    snapshot: AdaptSnapshot
    epoch: int
    speculative: bool = False


class RecurrencePredictor:
    """First-order transition table over iteration fingerprints: after
    adapting to stream ``A``, predict the stream that followed ``A`` last
    time.  Bounded: only the last ``history`` transitions are kept."""

    def __init__(self, history: int = 64):
        self._succ: "collections.OrderedDict[str, str]" = \
            collections.OrderedDict()
        self._last: Optional[str] = None
        self.history = max(int(history), 1)

    def observe(self, fp_exact: Optional[str]) -> None:
        if not fp_exact:
            return
        if self._last is not None and self._last != fp_exact:
            self._succ[self._last] = fp_exact
            self._succ.move_to_end(self._last)
            while len(self._succ) > self.history:
                self._succ.popitem(last=False)
        self._last = fp_exact

    def predict(self, fp_exact: Optional[str]) -> Optional[str]:
        return self._succ.get(fp_exact) if fp_exact else None


class AdaptationService:
    """Owns the adaptation state machine around the pipeline: the inline
    variant bookkeeping (GenPolicy list, pending measurement, knob
    seeding) *and* the async worker/mailbox/speculative machinery.  One
    instance per runtime; thread ownership is strict — the runtime calls
    everything except ``_worker_loop``."""

    def __init__(self, pipeline: AdaptationPipeline, mode: str = "inline",
                 *, max_parked: int = 8, max_snapshots: int = 16,
                 history: int = 64, pace_s: float = 0.0,
                 pace_cap_s: float = 0.25):
        assert mode in ("inline", "async", "speculative"), mode
        self.pipeline = pipeline
        self.mode = mode
        # GIL-cooperative pacing between worker-side variant simulations:
        # at least pace_s, at least one snapshot t_iter, capped, so an
        # overlapped training step contends with at most one variant
        self.pace_s = max(float(pace_s), 0.0)
        self.pace_cap_s = max(float(pace_cap_s), 0.0)
        # ---- shared adaptation bookkeeping (both placements)
        self.variants: List = []
        self.best = None
        self.adaptations: List[dict] = []
        self._adapt_mark: Optional[Tuple[int, float]] = None
        self._last_decision = None
        # ---- async machinery
        self.epoch = 0                   # generation counter (monotone)
        self._mb_lock = threading.Lock()
        # stat counters are bumped from both the runtime thread and the
        # worker (e.g. n_jobs via submit vs a chained speculative enqueue)
        self._ct_lock = threading.Lock()
        self._mailbox: Optional[AdaptResult] = None
        self._jobs: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._live_exact: Optional[str] = None
        # speculative: parked results + retained snapshots, LRU-bounded
        self._parked: "collections.OrderedDict[str, AdaptResult]" = \
            collections.OrderedDict()
        self._snapshots: "collections.OrderedDict[str, AdaptSnapshot]" = \
            collections.OrderedDict()
        self.max_parked = max(int(max_parked), 1)
        self.max_snapshots = max(int(max_snapshots), 1)
        self.predictor = RecurrencePredictor(history)
        self.n_jobs = self.n_published = self.n_discarded = 0
        self.n_failed = self.n_installed = 0
        self.n_spec_jobs = self.n_spec_hits = 0
        # hung-worker watchdog (repro.faults): wall-clock of the live
        # (non-speculative) job's submission; cleared on poll/invalidate
        self._live_submit_t: Optional[float] = None
        self.n_watchdog = 0

    # --------------------------------------------------------- accounting
    def begin(self, step_idx: int) -> None:
        """Open the adaptation-latency window (idempotent until closed)."""
        if self._adapt_mark is None:
            self._adapt_mark = (step_idx, time.perf_counter())

    def finish(self, tier: str, step_idx: int) -> None:
        """Close the adaptation-latency window opened by :meth:`begin`."""
        if self._adapt_mark is None:
            return
        start_step, t0 = self._adapt_mark
        self._adapt_mark = None
        rec = {
            "trigger_step": start_step,
            "end_step": step_idx,
            "steps": step_idx - start_step,
            "seconds": time.perf_counter() - t0,
            "tier": tier,
            "genpolicy_steps": len(self.variants),
        }
        self.adaptations.append(rec)
        obs.audit().event("adaptation.done", tier=tier,
                          trigger_step=start_step, end_step=step_idx,
                          seconds=round(rec["seconds"], 6),
                          genpolicy_steps=rec["genpolicy_steps"])
        obs.metrics().counter("adaptations")
        obs.metrics().gauge("adaptation_seconds", rec["seconds"])

    def reset_search(self) -> None:
        self.variants, self.best = [], None

    # ------------------------------------------------------ async: intake
    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._worker_loop, name="adapt-worker", daemon=True)
            self._worker.start()

    def invalidate(self, reason: str = "drift") -> int:
        """A new drift event supersedes everything in flight: bump the
        generation counter and drop any unconsumed mailbox result."""
        self.epoch += 1
        self._live_submit_t = None
        with self._mb_lock:
            stale, self._mailbox = self._mailbox, None
        if stale is not None:
            self._discard(stale, f"invalidate:{reason}")
        return self.epoch

    def submit(self, snap: AdaptSnapshot, *, speculative: bool = False
               ) -> AdaptJob:
        """Enqueue one adaptation job for the worker (re-arming it if a
        previous crash killed the thread).  The job is stamped with the
        current epoch; results from older epochs never install."""
        self._ensure_worker()
        if snap.iter_exact:
            self._snapshots[snap.iter_exact] = snap
            self._snapshots.move_to_end(snap.iter_exact)
            while len(self._snapshots) > self.max_snapshots:
                self._snapshots.popitem(last=False)
            if not speculative:
                self._live_exact = snap.iter_exact
        if not speculative:
            self._live_submit_t = time.monotonic()
        job = AdaptJob(snap, self.epoch, speculative)
        with self._ct_lock:
            self.n_jobs += 1
            self.n_spec_jobs += int(speculative)
        obs.audit().event("adaptation.enqueue", step=snap.step,
                          epoch=job.epoch, speculative=speculative,
                          fp=(snap.iter_exact or "")[:12],
                          t_iter=round(snap.t_iter, 6))
        obs.metrics().counter("adaptation_jobs")
        self._jobs.put(job)
        return job

    # ------------------------------------------------------ async: worker
    def _worker_loop(self) -> None:
        while True:
            job = self._jobs.get()
            if job is _SHUTDOWN:
                self._jobs.task_done()
                return
            try:
                self._run_job(job)
            except Exception as e:  # noqa: BLE001 — never kill training
                self._on_failure(job, e)
            finally:
                self._jobs.task_done()

    def _run_job(self, job: AdaptJob) -> None:
        f = faults.inject("adapt.hang", key=str(job.snapshot.step))
        if f is not None and f.seconds > 0:
            time.sleep(f.seconds)       # hung worker: watchdog territory
        if faults.inject("adapt.worker", key=str(job.snapshot.step)):
            raise RuntimeError(
                f"injected adaptation-worker crash (step {job.snapshot.step})")
        if not job.speculative and job.epoch != self.epoch:
            # superseded while queued: don't burn background time on it
            with self._ct_lock:
                self.n_discarded += 1
            obs.audit().event("adaptation.discard", why="stale-epoch",
                              epoch=job.epoch, live_epoch=self.epoch,
                              step=job.snapshot.step)
            return
        pace = 0.0
        if self.pace_s > 0.0:
            pace = min(max(self.pace_s, job.snapshot.t_iter),
                       self.pace_cap_s)
        # while the search runs, drop the interpreter switch interval
        # (process-wide, restored after) so the training thread's
        # dispatch never waits a full default 5 ms GIL slice behind a
        # pure-Python stretch of policy generation
        prev_switch = sys.getswitchinterval()
        sys.setswitchinterval(min(prev_switch, 0.001))
        try:
            with obs.tracer().span(obs.LANE_ADAPT,
                                   "adapt_worker" if not job.speculative
                                   else "adapt_speculative",
                                   arg=job.snapshot.step):
                res = self.pipeline.run(job.snapshot, pace_s=pace)
        finally:
            sys.setswitchinterval(prev_switch)
        res.epoch = job.epoch
        res.speculative = job.speculative
        if job.speculative:
            self._park(res)
        else:
            self._publish(res)
            self._maybe_speculate(res)

    def _on_failure(self, job: AdaptJob, err: Exception) -> None:
        with self._ct_lock:
            self.n_failed += 1
        obs.audit().event("adaptation.failed", step=job.snapshot.step,
                          epoch=job.epoch, speculative=job.speculative,
                          error=repr(err)[:200])
        obs.metrics().counter("adaptation_failures")
        if job.speculative:
            return                       # nothing depends on a parked result
        try:
            prof = job.snapshot.profile   # may be None if profiling crashed
            applied = self.pipeline.executor.conservative(prof)
            self._publish(AdaptResult(
                applied=applied, swap=None, knob=None,
                kind="conservative-fallback", tier="failed",
                predicted_t=float("inf"), profile=prof,
                iter_exact=job.snapshot.iter_exact,
                step=job.snapshot.step, epoch=job.epoch))
        except Exception:  # noqa: BLE001 — give up on this job, stay alive
            pass

    # --------------------------------------------------- async: publish
    def _publish(self, res: AdaptResult) -> None:
        with self._mb_lock:
            if res.epoch != self.epoch:
                stale = res
                replaced = None
            else:
                replaced, self._mailbox = self._mailbox, res
                stale = None
        if stale is not None:
            self._discard(stale, "stale-epoch")
            return
        if replaced is not None:
            self._discard(replaced, "superseded")
        with self._ct_lock:
            self.n_published += 1
        obs.audit().event("adaptation.publish", kind=res.kind,
                          tier=res.tier, epoch=res.epoch, step=res.step,
                          knob=res.knob, n_variants=res.n_variants,
                          predicted_t=(round(res.predicted_t, 6)
                                       if res.predicted_t != float("inf")
                                       else None))
        obs.metrics().counter("adaptation_published")

    def _discard(self, res: AdaptResult, why: str) -> None:
        with self._ct_lock:
            self.n_discarded += 1
        obs.audit().event("adaptation.discard", why=why, epoch=res.epoch,
                          live_epoch=self.epoch, step=res.step,
                          kind=res.kind)
        obs.metrics().counter("adaptation_discarded")

    def poll(self) -> Optional[AdaptResult]:
        """Take the mailbox result if it is still current (epoch matches
        and it was computed for the live stream).  Called by the runtime
        at the iteration boundary only."""
        with self._mb_lock:
            res, self._mailbox = self._mailbox, None
        if res is None:
            return None
        if res.epoch != self.epoch:
            self._discard(res, "stale-epoch")
            return None
        if (res.iter_exact and self._live_exact
                and res.iter_exact != self._live_exact):
            self._discard(res, "fingerprint-mismatch")
            return None
        with self._ct_lock:
            self.n_installed += 1
        self._live_submit_t = None
        return res

    def watchdog(self, timeout_s: float) -> bool:
        """True when the live (non-speculative) job has been in flight
        longer than ``timeout_s`` — a hung or lost worker.  Fires at most
        once per job (the runtime responds by invalidating the epoch and
        un-wedging the ADAPTING stage); 0 disables."""
        t = self._live_submit_t
        if timeout_s <= 0 or t is None:
            return False
        if time.monotonic() - t <= timeout_s:
            return False
        self._live_submit_t = None
        with self._ct_lock:
            self.n_watchdog += 1
        obs.audit().event("adaptation.watchdog", timeout_s=timeout_s,
                          queue_depth=self._jobs.qsize(),
                          worker_alive=bool(self._worker is not None
                                            and self._worker.is_alive()))
        obs.metrics().counter("adaptation_watchdog")
        return True

    # ------------------------------------------------- async: speculative
    def _park(self, res: AdaptResult) -> None:
        if not res.iter_exact:
            return
        self._parked[res.iter_exact] = res
        self._parked.move_to_end(res.iter_exact)
        while len(self._parked) > self.max_parked:
            self._parked.popitem(last=False)
        obs.audit().event("adaptation.publish", kind=res.kind,
                          tier=res.tier, epoch=res.epoch, step=res.step,
                          knob=res.knob, speculative=True,
                          parked=len(self._parked))

    def _maybe_speculate(self, res: AdaptResult) -> None:
        """After a real adaptation completes, pre-generate the predicted
        successor stream's policy if we still hold its snapshot."""
        if self.mode != "speculative":
            return
        self.predictor.observe(res.iter_exact)
        self._speculate_successor(res.iter_exact)

    def _speculate_successor(self, fp_exact: Optional[str]) -> None:
        if self.mode != "speculative" or not fp_exact:
            return
        nxt = self.predictor.predict(fp_exact)
        if (nxt and nxt != fp_exact and nxt not in self._parked
                and nxt in self._snapshots):
            snap = self._snapshots[nxt]
            job = AdaptJob(snap, self.epoch, speculative=True)
            with self._ct_lock:
                self.n_jobs += 1
                self.n_spec_jobs += 1
            obs.audit().event("adaptation.enqueue", step=snap.step,
                              epoch=job.epoch, speculative=True,
                              fp=nxt[:12], why="recurrence-predicted")
            self._jobs.put(job)

    def take_speculative(self, fp_exact: Optional[str]
                         ) -> Optional[AdaptResult]:
        """Pop a parked pre-generated result for the observed stream.
        Accepting it is a conscious act at the boundary, so it is
        re-stamped with the live epoch."""
        if not fp_exact:
            return None
        res = self._parked.pop(fp_exact, None)
        if res is None:
            return None
        res.epoch = self.epoch
        self._live_exact = fp_exact
        with self._ct_lock:
            self.n_spec_hits += 1
            self.n_installed += 1
        obs.metrics().counter("adaptation_speculative_hits")
        # chain: a hit on B means the B->successor policy is wanted next
        self.predictor.observe(fp_exact)
        self._speculate_successor(fp_exact)
        return res

    def note_adapted(self, fp_exact: Optional[str]) -> None:
        """Feed the recurrence predictor from the training thread (used
        for phases resolved without a worker round-trip, e.g. a
        speculative install or an inline adaptation in mixed flows)."""
        self.predictor.observe(fp_exact)

    # ------------------------------------------------------------- admin
    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every submitted job has been fully processed
        (tests/bench).  Returns False on timeout."""
        deadline = time.monotonic() + timeout
        while self._jobs.unfinished_tasks:       # pragma: no branch
            if time.monotonic() > deadline:
                return False
            time.sleep(0.002)
        return True

    def close(self) -> None:
        if self._worker is not None and self._worker.is_alive():
            self._jobs.put(_SHUTDOWN)
            self._worker.join(timeout=5.0)

    def stats(self) -> dict:
        return {
            "mode": self.mode,
            "epoch": self.epoch,
            "jobs": self.n_jobs,
            "published": self.n_published,
            "discarded": self.n_discarded,
            "failed": self.n_failed,
            "installed": self.n_installed,
            "speculative_jobs": self.n_spec_jobs,
            "speculative_hits": self.n_spec_hits,
            "watchdog_fired": self.n_watchdog,
            "parked": len(self._parked),
            "snapshots": len(self._snapshots),
            "queue_depth": self._jobs.qsize(),
            "worker_alive": bool(self._worker is not None
                                 and self._worker.is_alive()),
        }
