"""Adaptation pipeline (repro.adapt) — the §5 cycle as pure computation.

Everything the old 600-line ``ChameleonRuntime`` did between "drift
settled" and "policy chosen" lives here, factored so the *same code*
runs in all three placements (``inline`` / ``async`` / ``speculative``):

  * :meth:`classify` — fingerprint the profiled program and route it to
    a drift tier against the policy store;
  * :meth:`apply_cached` — §6.1 fuzzy re-association of a cached policy
    with the observed program (reuse tier), with the same verification
    guards as the inline path and **no engine side effects** — binding
    release points is the caller's install step;
  * :meth:`variant` — one GenPolicy variant for one grouping knob
    (Detailed profile → Algo-2 generation → lowering), byte-identical to
    what an inline GenPolicy iteration builds for the same inputs;
  * :meth:`run` — the whole cycle against an immutable
    :class:`~repro.adapt.snapshot.AdaptSnapshot`: classify, reuse if the
    store allows it, otherwise generate every knob's variant and select
    by simulator-predicted time.  This is what the background worker
    executes — and, because it is deterministic in the snapshot, what
    the equivalence tests replay synchronously to assert async ≡ inline
    for identical inputs.

Selection differs between placements by necessity: inline runs each
variant for one real iteration and keeps the best *measured* time
(§7.1); a background worker cannot run candidates on the training
stream, so it ranks by the simulator's predicted stall (same ordering
the generator optimizes).  Policy *construction* is shared either way.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro import obs
from repro.adapt.snapshot import AdaptSnapshot
from repro.core.executor import AppliedPolicy, Executor
from repro.core.matching import remap_policy
from repro.core.memtrace import build_timeline
from repro.core.policy import (ChameleonOOMError, SwapPolicy,
                               generate_policy, projected_peak)
from repro.core.profiler import ProfileData
from repro.policystore import (PolicyRecord, Tier, fingerprint_profile,
                               fingerprint_signature)

# grouping knobs tried across the n GenPolicy steps (variant selection)
VARIANT_KNOBS = (1.0, 2.0, 0.5, 4.0, 0.25)


@dataclass
class PolicyVariant:
    applied: AppliedPolicy
    swap: Optional[SwapPolicy]
    knob: float
    measured_t: Optional[float] = None


@dataclass
class CachedApply:
    """A reuse-tier hit, lowered but not yet installed."""
    applied: AppliedPolicy
    profile: Optional[ProfileData]       # set when the schedule remapped
    record: PolicyRecord


@dataclass
class AdaptResult:
    """What the pipeline concluded for one snapshot.  ``epoch`` is
    stamped by the service; the install step checks it against the live
    generation counter before trusting anything here."""
    applied: AppliedPolicy
    swap: Optional[SwapPolicy]
    knob: Optional[float]
    kind: str                            # reuse | genpolicy | baseline |
    tier: str                            # conservative(-fallback)
    predicted_t: float
    profile: Optional[ProfileData]
    iter_exact: Optional[str]            # source-stream fingerprint
    step: int = 0                        # snapshot step (job identity)
    epoch: int = -1
    n_variants: int = 0
    speculative: bool = False


class AdaptationPipeline:
    """Stateless with respect to the iteration loop: holds only the
    long-lived collaborators (config, executor, store, drift classifier,
    host tier).  All of them are individually thread-safe, so pipeline
    methods may run on the training thread or the worker."""

    def __init__(self, cfg, executor: Executor, store=None, drift=None,
                 hostmem=None):
        self.cfg = cfg
        self.executor = executor
        self.store = store
        self.drift = drift
        self.hostmem = hostmem

    # -------------------------------------------------------- fingerprints
    def fingerprint(self, prof: ProfileData):
        ps = self.cfg.policystore
        return fingerprint_profile(prof, n_perms=ps.minhash_perms,
                                   shingle=ps.shingle)

    def iteration_fingerprint(self, sig):
        ps = self.cfg.policystore
        return fingerprint_signature(sig, n_perms=ps.minhash_perms,
                                     shingle=ps.shingle)

    # ------------------------------------------------------ classification
    def classify(self, prof: ProfileData, budget: int, bwmodel=None):
        """Drift-tier the profiled program.  ``bwmodel`` should be the
        model the adaptation prices with (live for inline, the snapshot
        copy for async) so the bw-drift guard compares like with like."""
        fp = self.fingerprint(prof)
        decision = self.drift.classify(fp, self.store, budget=budget,
                                       bwmodel=bwmodel)
        return fp, decision

    def apply_cached(self, record: PolicyRecord, prof: ProfileData, tl,
                     budget: int, exact_hit: bool = False
                     ) -> Optional[CachedApply]:
        """Re-associate a cached policy with the observed program (§6.1
        fuzzy matching) and lower it.  None -> the record does not carry
        over (low match hit-rate, or a cached no-swap decision that no
        longer fits) and the caller falls back a tier."""
        swap = record.swap_policy()
        if swap is None:
            if record.policy_kind == "conservative":
                # the winner was the offload-all fallback: guaranteed to
                # fit by construction, but it carries no remappable
                # evidence — only the *identical* program may reuse it
                if not exact_hit:
                    return None
                return CachedApply(self.executor.conservative(prof), None,
                                   record)
            # cached adaptation concluded the baseline fits — verify that
            # still holds for the observed program before trusting it
            if tl.peak > budget:
                return None
            return CachedApply(self.executor.baseline(), None, record)
        entries, hit = remap_policy(swap, record.profile_stub(), prof)
        if not entries or hit < self.cfg.policystore.min_reuse_hit_rate:
            return None
        # a partially remapped schedule offloads fewer bytes than the one
        # that was priced to fit — re-verify against the observed timeline
        # before trusting it (same guard as the cached-baseline path)
        projected = projected_peak(prof, entries)
        if projected > budget:
            return None
        new_swap = dataclasses.replace(swap, entries=entries,
                                       projected_peak=projected,
                                       baseline_peak=tl.peak, budget=budget)
        return CachedApply(self.executor.lower(new_swap, prof), prof, record)

    @staticmethod
    def warm_knobs(decision) -> Tuple[float, ...]:
        """Knob sequence for one adaptation: a warm-start hit seeds the
        search from the cached winner + one alternative (converges in 1-2
        GenPolicy steps instead of five, §7.1); otherwise the full bank."""
        if (decision is not None and decision.tier is Tier.WARM_START
                and decision.record is not None):
            seed = decision.record.knob
            alt = next((k for k in VARIANT_KNOBS if k != seed),
                       VARIANT_KNOBS[0])
            return (seed, alt)
        return VARIANT_KNOBS

    # ------------------------------------------------------------ variants
    def variant(self, prof: ProfileData, knob: float, budget: int, *,
                bwmodel=None, engine=None, tl=None) -> PolicyVariant:
        """One GenPolicy variant: Algo-2 generation under ``knob`` groups
        per phase.  ``bwmodel``/``engine`` price transfers and link
        backlog — live objects inline, frozen snapshot views async."""
        groups = max(1, int((prof.scan_layers or 32) * knob))
        cfg_v = dataclasses.replace(self.cfg, groups_per_phase=groups)
        tl = tl if tl is not None else build_timeline(prof)
        try:
            if tl.peak > budget:
                swap = generate_policy(
                    prof, cfg_v, budget, timeline=tl, bwmodel=bwmodel,
                    engine=engine, register_free_times=False)
                applied = self.executor.lower(swap, prof)
            else:
                swap, applied = None, self.executor.baseline()
        except ChameleonOOMError:
            swap, applied = None, self.executor.conservative(prof)
        return PolicyVariant(applied, swap, knob)

    @staticmethod
    def predicted_time(var: PolicyVariant, prof: ProfileData) -> float:
        """Simulator-predicted iteration time for ranking variants when
        they cannot each run a measured iteration (async placement).  A
        conservative fallback ranks last — it only wins unopposed."""
        if var.swap is not None:
            return prof.t_iter + var.swap.stall_time
        if var.applied.offload:              # conservative (offload-all)
            return float("inf")
        return prof.t_iter                   # baseline fits as-is

    # ----------------------------------------------------------- write-back
    def build_record(self, best: PolicyVariant, prof: ProfileData,
                     budget: int, iter_fp=None, bwmodel=None,
                     measured_t: Optional[float] = None) -> PolicyRecord:
        """The store record for an adaptation winner, keyed by the
        profiled train-step stream and carrying the full iteration
        signature when one is available (mid-run drift similarity)."""
        prep_fp = self.fingerprint(prof)
        kind = ("swap" if best.swap is not None
                else "conservative" if best.applied.offload
                else "baseline")
        return PolicyRecord.from_policy(
            fingerprint=iter_fp if iter_fp is not None else prep_fp,
            prepare_fingerprint=prep_fp, swap=best.swap,
            candidates=prof.candidates, n_ops=prof.n_ops, knob=best.knob,
            measured_t=(measured_t if measured_t is not None
                        else best.measured_t or 0.0),
            budget=budget, bwmodel=bwmodel, policy_kind=kind)

    # ------------------------------------------------------------ full run
    def run(self, snap: AdaptSnapshot, *, pace_s: float = 0.0) -> AdaptResult:
        """The whole adaptation cycle against one immutable snapshot.
        Deterministic in the snapshot: running it on the worker thread or
        synchronously on the training thread yields the same policy —
        ``pace_s`` (worker-only) inserts sleeps between variant
        simulations and never changes the selection."""
        prof = snap.ensure_profile()
        tl = build_timeline(prof)
        decision = None
        if self.store is not None and self.drift is not None:
            fp, decision = self.classify(prof, snap.budget,
                                         bwmodel=snap.bwmodel)
            if decision.tier is Tier.REUSE:
                rec = decision.record
                exact = rec is not None and fp.exact in (
                    rec.prepare_fingerprint.exact, rec.fingerprint.exact)
                hit = self.apply_cached(rec, prof, tl, snap.budget,
                                        exact_hit=exact)
                if hit is not None:
                    self.store.touch(rec)
                    return AdaptResult(
                        applied=hit.applied,
                        swap=hit.applied.swap, knob=rec.knob,
                        kind="reuse", tier=Tier.REUSE.value,
                        predicted_t=prof.t_iter, profile=hit.profile,
                        iter_exact=snap.iter_exact, step=snap.step)
                decision = self.drift.demote(decision, "match-miss")
        knobs = snap.gen_knobs or self.warm_knobs(decision)
        engine = snap.engine_view()
        variants: List[PolicyVariant] = []
        for i, knob in enumerate(knobs):
            if pace_s > 0.0 and i:       # yield the GIL to the training
                time.sleep(pace_s)       # thread between simulations
            with obs.tracer().span(obs.LANE_ADAPT, "genpolicy_variant",
                                   arg=knob):
                variants.append(self.variant(prof, knob, snap.budget,
                                             bwmodel=snap.bwmodel,
                                             engine=engine, tl=tl))
        best = min(variants,
                   key=lambda v: (self.predicted_time(v, prof), v.knob))
        predicted = self.predicted_time(best, prof)
        kind = ("genpolicy" if best.swap is not None
                else "conservative" if best.applied.offload else "baseline")
        tier = (decision.tier.value if decision is not None
                else Tier.REGEN.value)
        if self.store is not None:
            rec = self.build_record(
                best, prof, snap.budget, iter_fp=snap.iter_fp,
                bwmodel=snap.bwmodel,
                measured_t=predicted if predicted != float("inf") else 0.0)
            self.store.put(rec)
            obs.audit().event(
                "policy.store_put", key=rec.key[:12], policy_kind=rec.policy_kind,
                knob=best.knob, measured_t=round(rec.measured_t, 6),
                step=snap.step)
        return AdaptResult(
            applied=best.applied, swap=best.swap, knob=best.knob,
            kind=kind, tier=tier, predicted_t=predicted, profile=prof,
            iter_exact=snap.iter_exact, step=snap.step,
            n_variants=len(variants))
