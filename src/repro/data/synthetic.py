"""Deterministic synthetic token pipeline.

Production-shaped: per-host sharding (each host materializes only its slice
of the global batch), a background prefetch thread with a bounded queue, and
a resumable cursor (saved in checkpoints, so restarts are sample-exact).
Tokens are a cheap stateless hash of (seed, position) — deterministic across
restarts and host counts, with a Zipf-ish marginal so losses move.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.common.config import ModelConfig, ShapeConfig


def _hash_tokens(seed: int, start: int, count: int, vocab: int) -> np.ndarray:
    mix = (seed * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    idx = (np.arange(start, start + count, dtype=np.uint64)
           + np.uint64(mix))
    x = idx
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    u = (x % np.uint64(1 << 24)).astype(np.float64) / float(1 << 24)
    # Zipf-ish marginal: heavier mass on low token ids
    toks = np.minimum((vocab * (u ** 2.2)).astype(np.int64), vocab - 1)
    return toks.astype(np.int32)


@dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    prefetch: int = 2

    def __post_init__(self):
        assert self.global_batch % self.host_count == 0
        self.local_batch = self.global_batch // self.host_count
        self.cursor = 0
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ direct
    def batch_at(self, cursor: int) -> Dict[str, np.ndarray]:
        span = self.seq_len + 1
        out = np.empty((self.local_batch, span), np.int32)
        for b in range(self.local_batch):
            row = cursor * self.global_batch + self.host_index * self.local_batch + b
            out[b] = _hash_tokens(self.seed, row * span, span, self.vocab_size)
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}

    def next_batch(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.cursor)
        self.cursor += 1
        return b

    # ---------------------------------------------------------- prefetch
    def start(self):
        if self._thread is not None:
            return self
        self._q = queue.Queue(maxsize=self.prefetch)

        def worker():
            c = self.cursor
            while not self._stop.is_set():
                batch = self.batch_at(c)
                while not self._stop.is_set():
                    try:
                        self._q.put((c, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                c += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def get(self) -> Dict[str, np.ndarray]:
        if self._q is None:
            return self.next_batch()
        c, batch = self._q.get()
        self.cursor = c + 1
        return batch

    def stop(self):
        self._stop.set()
        if self._q is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    # ------------------------------------------------------------- state
    def state(self) -> dict:
        return {"cursor": self.cursor, "seed": self.seed}

    def restore(self, state: dict):
        self.cursor = int(state["cursor"])
        self.seed = int(state["seed"])


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for a training batch (used by input_specs)."""
    import jax
    import jax.numpy as jnp
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        specs["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs
