"""Chameleon core — the paper's contribution as a composable JAX module."""
from repro.core.executor import AppliedPolicy, Executor  # noqa: F401
from repro.core.policy import ChameleonOOMError, SwapPolicy, generate_policy  # noqa: F401
from repro.core.profiler import ProfileData, profile_jaxpr  # noqa: F401
from repro.core.runtime import ChameleonRuntime  # noqa: F401
from repro.core.stages import Stage, StageMachine  # noqa: F401
