"""Global swap simulator (paper §5.4).

Logical layers: the operator stream is split into evenly sized groups per
phase (forward = ops before the memory peak, backward+optimizer = after).
Eq. 1 assigns every group the average group time
``T̄_group = T_iter / N_iter × N_group`` — the Fig-4 insight that makes the
whole system work *without per-operator timings*.  Each layer's
``remaining_time`` is the transfer budget that can overlap its compute.

Swap-in (§5.4.1): search **backward** from the logical layer preceding the
tensor's first backward use, stopping at the peak, for a layer with
``T_remaining > T_swap`` (Eq. 3: ``T_swap = S/B``).  If nothing fits, the
highest-score candidate is still swapped (stalled) right before first use —
preferable to OOM.

Swap-out (§5.4.2): triggered at last forward use; completion layer found
searching **forward** for spare transfer budget; this release point feeds the
custom-recordStream analogue (early reuse) and the Fig-8 metric.

Hot-path layout: per-layer transfer budgets live in one float64 numpy
array (``LogicalLayer.remaining_time`` is a view into it), layer starts in
one int64 array, so the backward/forward budget searches are single
``flatnonzero`` calls over slices instead of Python loops, and transfer
times are memoized per tensor size.  GenPolicy runs the simulator once per
variant (2–5 per adaptation), so this is what bounds per-variant cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.common.config import ChameleonConfig
from repro.core.candidates import Candidate
from repro.core.mrl import MRL
from repro.core.profiler import ProfileData


class LogicalLayer:
    """One logical layer; ``remaining_time`` reads/writes the simulator's
    shared per-layer budget array, so vectorized searches and this object
    view never disagree."""

    __slots__ = ("index", "start_op", "end_op", "kind", "candidates", "_rem")

    def __init__(self, index: int, start_op: int, end_op: int, kind: str,
                 rem: np.ndarray):
        self.index = index
        self.start_op = start_op
        self.end_op = end_op
        self.kind = kind
        self.candidates: List[int] = []   # tensor uids
        self._rem = rem

    @property
    def remaining_time(self) -> float:
        return float(self._rem[self.index])

    @remaining_time.setter
    def remaining_time(self, v: float) -> None:
        self._rem[self.index] = v

    def __repr__(self):
        return (f"LogicalLayer({self.index}, [{self.start_op},{self.end_op})"
                f", {self.kind}, rem={self.remaining_time:.3g})")


@dataclass
class PolicyEntry:
    uid: int
    site: Optional[str]
    layer: int                    # scan slice index of the residual
    nbytes: int
    birth: int
    death: int
    swap_in_op: int               # op index where swap-in is pre-triggered
    swap_out_done_op: int = -1    # op index where swap-out completes
    stalled: bool = False
    score: float = 0.0

    @property
    def t_swap(self):             # filled by simulator for reporting
        return getattr(self, "_t_swap", 0.0)


def _phase_splits(lo: int, hi: int, g: int) -> np.ndarray:
    """Boundaries of ``min(g, hi-lo)`` near-equal groups of [lo, hi)."""
    total = hi - lo
    g = min(g, total)
    # first `total % g` groups get one extra op (same as serial divmod fill)
    return lo + np.concatenate(
        [[0], np.cumsum(np.full(g, total // g)
                        + (np.arange(g) < total % g))])


class Simulator:
    def __init__(self, prof: ProfileData, peak_op: int, cfg: ChameleonConfig,
                 bwmodel=None, engine=None):
        self.prof = prof
        self.cfg = cfg
        self.peak_op = peak_op
        self.bandwidth = cfg.host_link_gbps * 1e9        # B in Eq. 3
        # measured host-link curve (repro.hostmem.bwmodel) — when calibrated
        # it prices transfers size-dependently instead of with the constant
        self.bwmodel = bwmodel
        self._tswap_cache: Dict[int, float] = {}
        # live transfer engine (repro.hostmem.engine): its per-class backlog
        # prices link *contention* — the paper's Eq. 3 assumes an idle link,
        # but a queued checkpoint/kv-spill drain eats into the transfer
        # budget of the earliest logical layers
        self.contention_s = (engine.queued_delay() if engine is not None
                             else 0.0)
        # sustained contention: the engine's per-class arrival-rate EWMA
        # gives the fraction of link time other traffic classes occupy in
        # steady state — a *rate*, not the point-in-time backlog above
        # (which only sees what happens to be queued at generation time)
        occ = 0.0
        if engine is not None:
            sc = getattr(engine, "sustained_contention", None)
            if sc is not None:
                occ = float(sc())
        self.occupancy = occ
        self.layers = self._build_layers()
        self._peak_layer = self.layer_of(self.peak_op)
        self._charge_contention()
        if occ > 0.0 and self._remaining.size:
            # every overlap window loses the sustained-traffic fraction
            self._remaining *= (1.0 - occ)
        self.stall_time = 0.0

    def _charge_contention(self) -> None:
        """Deduct the current link backlog from the earliest layers'
        transfer budgets: the link is busy draining it when the iteration
        starts, so early overlap windows are not actually free."""
        left = self.contention_s
        if left <= 0.0 or not self.layers:
            return
        # prefix drain in one pass: layer i keeps the part of its budget
        # that the backlog (spread over the cumulative prefix) leaves over
        rem = self._remaining
        cum = np.cumsum(rem)
        np.subtract(np.clip(cum - left, 0.0, None),
                    np.clip(cum - rem - left, 0.0, None), out=rem)

    # ------------------------------------------------------------- layers
    def _build_layers(self) -> List[LogicalLayer]:
        n = self.prof.n_ops
        t_op = self.prof.t_iter / max(n, 1)              # Eq. 1 per-op average
        G = self.cfg.groups_per_phase or self.prof.scan_layers or 32
        bounds: List[np.ndarray] = []
        kinds: List[str] = []
        for lo, hi, kind in ((0, self.peak_op, "FWD"), (self.peak_op, n, "BWD")):
            if hi - lo <= 0:
                continue
            b = _phase_splits(lo, hi, G)
            bounds.append(b)
            kinds.extend([kind] * (b.size - 1))
        if not bounds:
            self._remaining = np.zeros(0, np.float64)
            self._starts_arr = np.zeros(0, np.int64)
            return []
        starts = np.concatenate([b[:-1] for b in bounds])
        ends = np.concatenate([b[1:] for b in bounds])
        kinds[-1] = "OPT"
        self._remaining = (ends - starts).astype(np.float64) * t_op
        self._starts_arr = starts.astype(np.int64)
        return [LogicalLayer(i, int(s), int(e), k, self._remaining)
                for i, (s, e, k) in enumerate(zip(starts, ends, kinds))]

    def layer_of(self, op: int) -> int:
        i = int(np.searchsorted(self._starts_arr, op, side="right")) - 1
        return max(0, min(i, len(self.layers) - 1))

    def layers_of(self, ops: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`layer_of` for an array of op indices."""
        i = np.searchsorted(self._starts_arr, ops, side="right") - 1
        return np.clip(i, 0, max(len(self.layers) - 1, 0))

    def t_swap(self, nbytes: int) -> float:
        ts = self._tswap_cache.get(nbytes)
        if ts is None:
            if self.bwmodel is not None and self.bwmodel.is_calibrated:
                ts = self.bwmodel.transfer_time(nbytes)   # measured curve
            else:
                # Eq. 3 constant, derated by the autotuner's measured
                # link efficiency when a bandwidth model carries one
                eff = getattr(self.bwmodel, "link_efficiency", 1.0)
                ts = nbytes / (self.bandwidth * eff)
            self._tswap_cache[nbytes] = ts
        return ts

    # -------------------------------------------------- §5.4.1 swap-in
    def place_swap_in(self, cand: Candidate) -> Optional[PolicyEntry]:
        t = cand.tensor
        ts = self.t_swap(t.nbytes)
        first_use_layer = self.layer_of(t.death)
        # backward search over (peak_layer, first_use_layer): one
        # flatnonzero over the budget slice, picking the latest fit
        lo = self._peak_layer + 1
        fit = np.flatnonzero(self._remaining[lo:first_use_layer] > ts)
        if fit.size == 0:
            return None
        li = lo + int(fit[-1])
        lay = self.layers[li]
        self._remaining[li] -= ts
        lay.candidates.append(t.uid)
        e = PolicyEntry(t.uid, t.site, t.layer, t.nbytes, t.birth,
                        t.death, swap_in_op=lay.start_op,
                        score=cand.score)
        e._t_swap = ts
        return e

    def place_stalled(self, cand: Candidate) -> PolicyEntry:
        """Fallback: swap anyway right before first use, accept the stall."""
        t = cand.tensor
        ts = self.t_swap(t.nbytes)
        li = max(self.layer_of(t.death) - 1, 0)
        lay = self.layers[li]
        stall = max(0.0, ts - max(self._remaining[li], 0.0))
        self._remaining[li] -= ts
        lay.candidates.append(t.uid)
        self.stall_time += stall
        e = PolicyEntry(t.uid, t.site, t.layer, t.nbytes, t.birth, t.death,
                        swap_in_op=lay.start_op, stalled=True,
                        score=cand.score)
        e._t_swap = ts
        return e

    # ------------------------------------------------- Algo 2 inner loop
    def simulate(self, cl: List[Candidate], mrl: MRL) -> List[PolicyEntry]:
        entries: List[PolicyEntry] = []
        placed_any = False
        for cand in cl:
            if mrl.is_empty():
                break
            t = cand.tensor
            if mrl.covered_count(t.birth, t.death) == 0:
                continue
            e = self.place_swap_in(cand)
            if e is None:
                continue
            # §5.4.1: decrement tensor size from MREs across its lifecycle
            mrl.decrement(t.birth, e.swap_in_op, t.nbytes)
            entries.append(e)
            placed_any = True
        if not placed_any and cl and not mrl.is_empty():
            # nobody fits without stalls: paper picks the top-score candidate
            cand = cl[0]
            e = self.place_stalled(cand)
            mrl.decrement(cand.tensor.birth, e.swap_in_op, cand.tensor.nbytes)
            entries.append(e)
        return entries

    # ------------------------------------------------ §5.4.2 swap-out
    def set_free_time(self, entries: List[PolicyEntry]) -> None:
        if not entries:
            return
        order = sorted(entries, key=lambda e: e.birth)
        lis = self.layers_of(
            np.fromiter((e.birth for e in order), np.int64, len(order)))
        for e, li in zip(order, lis):
            ts = self.t_swap(e.nbytes)
            li = int(li)
            # forward search: earliest layer from birth with spare budget
            fit = np.flatnonzero(self._remaining[li:] > ts)
            if fit.size:
                lj = li + int(fit[0])
                self._remaining[lj] -= ts
                done = self.layers[lj]
            else:                 # saturated: completes at end of fwd stream
                done = self.layers[self._peak_layer]
            e.swap_out_done_op = done.end_op

    # --------------------------------------------------------- reporting
    def reuse_intervals(self, entries: List[PolicyEntry]) -> np.ndarray:
        """Ops between swap-out dispatch and memory release — the custom
        recordStream releases at swap_out_done_op (simulator-known), the
        naive recordStream analogue holds until first backward use."""
        return np.asarray([max(e.swap_out_done_op - e.birth, 0)
                           for e in entries], np.int64)

    def naive_reuse_intervals(self, entries: List[PolicyEntry]) -> np.ndarray:
        return np.asarray([max(e.death - e.birth, 0) for e in entries],
                          np.int64)
