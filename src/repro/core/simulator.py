"""Global swap simulator (paper §5.4).

Logical layers: the operator stream is split into evenly sized groups per
phase (forward = ops before the memory peak, backward+optimizer = after).
Eq. 1 assigns every group the average group time
``T̄_group = T_iter / N_iter × N_group`` — the Fig-4 insight that makes the
whole system work *without per-operator timings*.  Each layer's
``remaining_time`` is the transfer budget that can overlap its compute.

Swap-in (§5.4.1): search **backward** from the logical layer preceding the
tensor's first backward use, stopping at the peak, for a layer with
``T_remaining > T_swap`` (Eq. 3: ``T_swap = S/B``).  If nothing fits, the
highest-score candidate is still swapped (stalled) right before first use —
preferable to OOM.

Swap-out (§5.4.2): triggered at last forward use; completion layer found
searching **forward** for spare transfer budget; this release point feeds the
custom-recordStream analogue (early reuse) and the Fig-8 metric.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.common.config import ChameleonConfig
from repro.core.candidates import Candidate
from repro.core.mrl import MRL
from repro.core.profiler import ProfileData


@dataclass
class LogicalLayer:
    index: int
    start_op: int
    end_op: int
    kind: str                     # FWD | BWD | OPT
    remaining_time: float
    candidates: List[int] = field(default_factory=list)   # tensor uids


@dataclass
class PolicyEntry:
    uid: int
    site: Optional[str]
    layer: int                    # scan slice index of the residual
    nbytes: int
    birth: int
    death: int
    swap_in_op: int               # op index where swap-in is pre-triggered
    swap_out_done_op: int = -1    # op index where swap-out completes
    stalled: bool = False
    score: float = 0.0

    @property
    def t_swap(self):             # filled by simulator for reporting
        return getattr(self, "_t_swap", 0.0)


class Simulator:
    def __init__(self, prof: ProfileData, peak_op: int, cfg: ChameleonConfig,
                 bwmodel=None, engine=None):
        self.prof = prof
        self.cfg = cfg
        self.peak_op = peak_op
        self.bandwidth = cfg.host_link_gbps * 1e9        # B in Eq. 3
        # measured host-link curve (repro.hostmem.bwmodel) — when calibrated
        # it prices transfers size-dependently instead of with the constant
        self.bwmodel = bwmodel
        # live transfer engine (repro.hostmem.engine): its per-class backlog
        # prices link *contention* — the paper's Eq. 3 assumes an idle link,
        # but a queued checkpoint/kv-spill drain eats into the transfer
        # budget of the earliest logical layers
        self.contention_s = (engine.queued_delay() if engine is not None
                             else 0.0)
        self.layers = self._build_layers()
        self._starts = [l.start_op for l in self.layers]
        self._charge_contention()
        self.stall_time = 0.0

    def _charge_contention(self) -> None:
        """Deduct the current link backlog from the earliest layers'
        transfer budgets: the link is busy draining it when the iteration
        starts, so early overlap windows are not actually free."""
        left = self.contention_s
        for lay in self.layers:
            if left <= 0.0:
                break
            take = min(lay.remaining_time, left)
            lay.remaining_time -= take
            left -= take

    # ------------------------------------------------------------- layers
    def _build_layers(self) -> List[LogicalLayer]:
        n = self.prof.n_ops
        t_op = self.prof.t_iter / max(n, 1)              # Eq. 1 per-op average
        G = self.cfg.groups_per_phase or self.prof.scan_layers or 32
        layers: List[LogicalLayer] = []

        def split(lo: int, hi: int, kind: str):
            total = hi - lo
            if total <= 0:
                return
            g = min(G, total)
            base, rem = divmod(total, g)
            cur = lo
            for i in range(g):
                size = base + (1 if i < rem else 0)
                layers.append(LogicalLayer(
                    len(layers), cur, cur + size, kind,
                    remaining_time=size * t_op))
                cur += size

        split(0, self.peak_op, "FWD")
        split(self.peak_op, n, "BWD")
        if layers:
            layers[-1].kind = "OPT"
        return layers

    def layer_of(self, op: int) -> int:
        i = bisect.bisect_right(self._starts, op) - 1
        return max(0, min(i, len(self.layers) - 1))

    def t_swap(self, nbytes: int) -> float:
        if self.bwmodel is not None and self.bwmodel.is_calibrated:
            return self.bwmodel.transfer_time(nbytes)     # measured curve
        return nbytes / self.bandwidth                    # Eq. 3 constant

    # -------------------------------------------------- §5.4.1 swap-in
    def place_swap_in(self, cand: Candidate) -> Optional[PolicyEntry]:
        t = cand.tensor
        ts = self.t_swap(t.nbytes)
        first_use_layer = self.layer_of(t.death)
        peak_layer = self.layer_of(self.peak_op)
        for li in range(first_use_layer - 1, peak_layer, -1):
            lay = self.layers[li]
            if lay.remaining_time > ts:
                lay.remaining_time -= ts
                lay.candidates.append(t.uid)
                e = PolicyEntry(t.uid, t.site, t.layer, t.nbytes, t.birth,
                                t.death, swap_in_op=lay.start_op,
                                score=cand.score)
                e._t_swap = ts
                return e
        return None

    def place_stalled(self, cand: Candidate) -> PolicyEntry:
        """Fallback: swap anyway right before first use, accept the stall."""
        t = cand.tensor
        ts = self.t_swap(t.nbytes)
        li = max(self.layer_of(t.death) - 1, 0)
        lay = self.layers[li]
        stall = max(0.0, ts - max(lay.remaining_time, 0.0))
        lay.remaining_time -= ts
        lay.candidates.append(t.uid)
        self.stall_time += stall
        e = PolicyEntry(t.uid, t.site, t.layer, t.nbytes, t.birth, t.death,
                        swap_in_op=lay.start_op, stalled=True,
                        score=cand.score)
        e._t_swap = ts
        return e

    # ------------------------------------------------- Algo 2 inner loop
    def simulate(self, cl: List[Candidate], mrl: MRL) -> List[PolicyEntry]:
        entries: List[PolicyEntry] = []
        placed_any = False
        for cand in cl:
            if mrl.is_empty():
                break
            t = cand.tensor
            if mrl.covered_count(t.birth, t.death) == 0:
                continue
            e = self.place_swap_in(cand)
            if e is None:
                continue
            # §5.4.1: decrement tensor size from MREs across its lifecycle
            mrl.decrement(t.birth, e.swap_in_op, t.nbytes)
            entries.append(e)
            placed_any = True
        if not placed_any and cl and not mrl.is_empty():
            # nobody fits without stalls: paper picks the top-score candidate
            cand = cl[0]
            e = self.place_stalled(cand)
            mrl.decrement(cand.tensor.birth, e.swap_in_op, cand.tensor.nbytes)
            entries.append(e)
        return entries

    # ------------------------------------------------ §5.4.2 swap-out
    def set_free_time(self, entries: List[PolicyEntry]) -> None:
        for e in sorted(entries, key=lambda e: e.birth):
            ts = self.t_swap(e.nbytes)
            li = self.layer_of(e.birth)
            done = None
            for lj in range(li, len(self.layers)):
                lay = self.layers[lj]
                if lay.remaining_time > ts:
                    lay.remaining_time -= ts
                    done = lay
                    break
            if done is None:      # saturated: completes at end of fwd stream
                done = self.layers[self.layer_of(self.peak_op)]
            e.swap_out_done_op = done.end_op

    # --------------------------------------------------------- reporting
    def reuse_intervals(self, entries: List[PolicyEntry]) -> np.ndarray:
        """Ops between swap-out dispatch and memory release — the custom
        recordStream releases at swap_out_done_op (simulator-known), the
        naive recordStream analogue holds until first backward use."""
        return np.asarray([max(e.swap_out_done_op - e.birth, 0)
                           for e in entries], np.int64)

    def naive_reuse_intervals(self, entries: List[PolicyEntry]) -> np.ndarray:
        return np.asarray([max(e.death - e.birth, 0) for e in entries],
                          np.int64)
