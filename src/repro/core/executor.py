"""Executor (paper §6): apply a generated SwapPolicy to the training program.

On TPU/XLA the application mechanism is a ``save_and_offload_only_these_names``
remat policy threaded into the model's scanned blocks and a re-``jit`` of the
step — the compile-time analogue of re-routing the dispatch stream.  XLA's
static schedule plays the role of the paper's custom recordStream: the
simulator's swap-out completion points become buffer release points that the
latency-hiding scheduler honors without host polling (§6.2); we additionally
donate input buffers so optimizer-state memory is reused in place.

``offload_mode="compressed"`` (beyond-paper, CSWAP-inspired) wraps offloaded
sites in an int8 quantize/dequantize pair so swapped tensors cross the host
link at half/quarter width — see ``repro.kernels.quant_offload``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, Optional, Sequence, Set

import jax

from repro.common.config import ChameleonConfig
from repro.core.policy import SwapPolicy
from repro.core.profiler import ProfileData
from repro.core.sites import OFFLOAD_SITES

# Sites that are cheap to recompute from their saved neighbors (elementwise):
# the beyond-paper 3-way save/offload/remat decision drops these from the
# saved set when host bandwidth is the binding constraint.
CHEAP_RECOMPUTE_SITES: Set[str] = {"ffn_act", "ssm_gate", "ln_in"}


def jax_offload_policy(offload_sites: Iterable[str],
                       save_sites: Iterable[str]):
    return jax.checkpoint_policies.save_and_offload_only_these_names(
        names_which_can_be_saved=sorted(set(save_sites)),
        names_which_can_be_offloaded=sorted(set(offload_sites)),
        offload_src="device", offload_dst="pinned_host")


def jax_save_policy(save_sites: Iterable[str]):
    return jax.checkpoint_policies.save_only_these_names(
        *sorted(set(save_sites)))


@dataclass
class AppliedPolicy:
    swap: Optional[SwapPolicy]
    offload: Set[str]
    save: Set[str]
    remat: Set[str]
    fingerprint: str
    raw: bool = False    # save *everything* incl. untagged f32 temporaries
    # §5.4.2 feedback: tag -> simulator-promised swap-out completion op.
    # The execution path hands this to the transfer engine so HBM is freed
    # at the promised op (engine.advance_op) instead of at first reuse.
    release_plan: Dict[str, int] = field(default_factory=dict)

    def to_jax(self):
        if self.raw:
            return None  # no checkpoint wrapper at all
        if not self.offload:
            return jax_save_policy(self.save)
        return jax_offload_policy(self.offload, self.save)


class Executor:
    def __init__(self, cfg: ChameleonConfig):
        self.cfg = cfg

    def site_universe(self, prof: Optional[ProfileData]) -> Set[str]:
        if prof is None:
            return set(OFFLOAD_SITES)
        sites = {t.site for t in prof.candidates if t.site}
        return sites or set(OFFLOAD_SITES)

    def lower(self, swap: SwapPolicy, prof: ProfileData,
              remat_fallback: Optional[bool] = None) -> AppliedPolicy:
        """SwapPolicy (per-tensor decisions) -> site-level applied policy."""
        offload = swap.offload_sites(prof)
        universe = self.site_universe(prof)
        save = universe - offload
        remat: Set[str] = set()
        use_remat = (self.cfg.allow_remat_fallback
                     if remat_fallback is None else remat_fallback)
        if use_remat:
            remat = (save & CHEAP_RECOMPUTE_SITES)
            save -= remat
        fp = ("off=" + ",".join(sorted(offload))
              + "|save=" + ",".join(sorted(save)))
        plan = {SwapPolicy.entry_tag(e): e.swap_out_done_op
                for e in swap.entries if e.swap_out_done_op >= 0}
        return AppliedPolicy(swap, offload, save, remat, fp,
                             release_plan=plan)

    def bind_release_points(self, applied: AppliedPolicy, engine) -> int:
        """Hand the applied policy's release plan to the transfer engine
        (superseding any previous policy's): swap-outs tagged with a
        planned tensor carry ``release_op`` and are retired by
        ``engine.advance_op`` at the simulator-promised op."""
        engine.clear_planned_releases()
        for tag, op in applied.release_plan.items():
            engine.plan_release(tag, op)
        return len(applied.release_plan)

    def conservative(self, prof: Optional[ProfileData] = None) -> AppliedPolicy:
        """WarmUp-stage fallback: offload every candidate site (guaranteed
        fit analogue of passive swap; see core.oom for the targeted loop)."""
        universe = self.site_universe(prof)
        return AppliedPolicy(None, set(universe), set(), set(),
                             "warmup-offload-all")

    def baseline(self) -> AppliedPolicy:
        """PyTorch-equivalent no-swap baseline: every named activation site
        is saved in its stored dtype; elementwise internals (f32 upcasts of
        norms/rope/softmax) are recomputed in the backward — what fused
        autograd kernels do.  This is the program the profiler traces and
        the memory curve the MRL is built from (Fig 3)."""
        return AppliedPolicy(None, set(), set(OFFLOAD_SITES), set(),
                             "baseline-save-sites")

    def raw(self) -> AppliedPolicy:
        """Save-everything (no remat wrapper): upper bound on activation
        memory; reported in benches for contrast, never used as the paper
        baseline."""
        return AppliedPolicy(None, set(), set(OFFLOAD_SITES), set(),
                             "raw-save-everything", raw=True)
