"""Candidate List construction and scoring (paper §5.3, Eq. 2).

Candidates are tagged residual instances whose lifetime overlaps outstanding
MREs and whose size is large enough to use host-link bandwidth efficiently.
``Score = N̂_MRE + C · Ŝ`` with both terms normalized over the current CL.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Set

from repro.common.config import ChameleonConfig
from repro.core.mrl import MRL
from repro.core.profiler import ProfileData, TensorInstance

MIN_SWAP_BYTES = 1 << 16   # below this, PCIe setup cost dominates (§5.3)


@dataclass
class Candidate:
    tensor: TensorInstance
    n_mre: int
    score: float


def build_candidate_list(prof: ProfileData, mrl: MRL, cfg: ChameleonConfig,
                         exclude: Set[int] = frozenset(),
                         min_bytes: int = MIN_SWAP_BYTES) -> List[Candidate]:
    raw = []
    for t in prof.candidates:
        if t.uid in exclude or t.nbytes < min_bytes:
            continue
        n_mre = mrl.covered_count(t.birth, t.death)
        if n_mre == 0:   # lifetime doesn't overlap the peak region (§5.3)
            continue
        raw.append((t, n_mre))
    if not raw:
        return []
    max_mre = max(n for _, n in raw) or 1
    max_size = max(t.nbytes for t, _ in raw) or 1
    out = [Candidate(t, n, n / max_mre + cfg.score_coef_c * t.nbytes / max_size)
           for t, n in raw]
    out.sort(key=lambda c: (-c.score, c.tensor.uid))
    return out
