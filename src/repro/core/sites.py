"""Activation-site tagging.

Every offloadable activation in the model zoo is tagged with
``jax.ad_checkpoint.checkpoint_name``.  These names are the JAX analogue of
the paper's cross-iteration tensor identity: the policy generator selects
*sites*, the executor turns the selected sites into a
``save_and_offload_only_these_names`` remat policy, and the fuzzy matcher
(§6.1) re-associates policy entries with sites after the traced program
changes.

Under ``lax.scan`` over layers a site denotes the *stacked* per-layer
activation (one buffer per scan step); in unrolled mode sites carry an
``l{i}/`` prefix for per-layer granularity.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Tuple

from jax.ad_checkpoint import checkpoint_name

# The canonical site vocabulary.  Order matters: it is also the one-hot bit
# assignment used by the integer fuzzy matcher (Appendix A adaptation).
OFFLOAD_SITES: Tuple[str, ...] = (
    "embed_out",      # token embedding output
    "ln_in",          # pre-norm input (residual stream snapshot)
    "qkv_proj",       # fused qkv projection output
    "attn_ctx",       # attention context (pre out-proj)
    "attn_out",       # attention block output
    "cross_kv",       # encoder / image KV (enc-dec + VLM)
    "cross_ctx",      # cross-attention context
    "ffn_pre",        # gate/up projection output
    "ffn_act",        # post-activation
    "ffn_out",        # down projection output
    "resid_mid",      # residual after attention
    "resid_post",     # residual after mlp (layer output / scan carry)
    "router_logits",  # MoE router scores
    "moe_dispatch",   # gathered expert inputs
    "moe_act",        # expert hidden activations
    "moe_out",        # combined expert outputs
    "ssm_in",         # mamba in-projection output
    "ssm_conv",       # post-conv activation
    "ssm_gate",       # gate branch
    "ssm_state",      # SSD chunk states
    "ssm_out",        # mamba block output
    "final_norm",
)
SITE_INDEX = {s: i for i, s in enumerate(OFFLOAD_SITES)}


class _Ctx(threading.local):
    def __init__(self):
        self.prefix = ""


_CTX = _Ctx()


@contextlib.contextmanager
def site_prefix(prefix: str):
    """Per-layer prefixing for unrolled (fine-grained) mode."""
    prev = _CTX.prefix
    _CTX.prefix = prefix
    try:
        yield
    finally:
        _CTX.prefix = prev


def tag(x, site: str):
    assert site in SITE_INDEX, f"unknown site {site!r}"
    return checkpoint_name(x, _CTX.prefix + site)


def base_site(name: str) -> str:
    """Strip any l{i}/ prefix back to the canonical site."""
    return name.rsplit("/", 1)[-1]
