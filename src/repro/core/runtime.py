"""ChameleonRuntime — ties profiler, stage machine, policy generator and
executor into the per-iteration loop (paper Fig. 2).

Protocol (driven by ``repro.runtime.trainer.Trainer``):

    rt = ChameleonRuntime(cham_cfg, step_builder)
    rt.prepare(example_args)                  # WarmUp fit (Algo 3, proactive)
    for it in range(steps):
        fn = rt.step_fn()                     # current applied policy
        t0 = time(); out = fn(*args); block(); dt = time() - t0
        rt.record_dispatch("train", fn, args) # Lightweight-mode op stream
        ... (any extra dispatches: eval, optimizer-skip, ... recorded too)
        rt.end_iteration(dt)                  # Algo 1 stage machine

During GenPolicy the runtime generates one policy variant per step (varying
the logical-layer grouping knob) and, after n steps, keeps the variant with
the best measured iteration time — the paper's §7.1 "generates five policies
and selects the one with the best runtime performance".

The adaptation *pipeline* (classification, cached-policy re-association,
variant construction, store write-back) lives in ``repro.adapt``; this
module keeps the iteration-loop state machine and the install points.
With ``cfg.adapt.mode`` set to ``async`` or ``speculative`` the settled
WarmUp enqueues an :class:`~repro.adapt.AdaptSnapshot` to the background
:class:`~repro.adapt.AdaptationService` instead of running GenPolicy
iterations inline; the worker's result installs at the next iteration
boundary (after the engine feedback of the policy that just ran), so
drift never stalls an iteration.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
# PolicyVariant / VARIANT_KNOBS moved to repro.adapt.pipeline; re-exported
# here because callers import them from the runtime module
from repro.adapt import (VARIANT_KNOBS, AdaptResult, AdaptSnapshot,
                         AdaptationPipeline, AdaptationService, PolicyVariant)
from repro.common.config import ChameleonConfig
from repro.core import tokenizer
from repro.core.executor import AppliedPolicy, Executor
from repro.core.memtrace import build_timeline
from repro.core.oom import warmup_offload_sites
from repro.core.policy import (ChameleonOOMError, SwapPolicy,
                               projected_peak)
from repro.faults.health import MEM_CLASS
from repro.faults.ladder import (RUNG_CONSERVATIVE, RUNG_FULL, RUNG_NAMES,
                                 RUNG_NO_SWAP, RUNG_TRIMMED,
                                 DegradationLadder, trim_swap)
from repro.core.profiler import ProfileData, profile_jaxpr
from repro.core.stages import Stage, StageMachine
from repro.policystore import DriftClassifier, PolicyStore, Tier

__all__ = ["ChameleonRuntime", "PolicyVariant", "VARIANT_KNOBS"]


class ChameleonRuntime:
    def __init__(self, cfg: ChameleonConfig,
                 step_builder: Callable[[Optional[Any]], Callable],
                 budget: Optional[int] = None, hostmem=None):
        self.cfg = cfg
        self.budget = budget if budget is not None else cfg.hbm_budget_bytes
        self.step_builder = step_builder
        self.executor = Executor(cfg)
        if hostmem is None and cfg.enabled and cfg.hostmem.enabled:
            from repro.hostmem import HostMemTier
            hostmem = HostMemTier.from_chameleon(cfg)
        self.hostmem = hostmem
        self._step_cache: Dict[str, Callable] = {}
        self._trace_cache: Dict[Tuple, tokenizer.TokenStream] = {}
        self._jaxpr_cache: Dict[Tuple, Any] = {}
        # baseline profile per arg-shape key — pure memoization of
        # profile_jaxpr over the cached baseline trace, so a WarmUp
        # re-entry onto a recurring shape bucket skips both the re-trace
        # and the (pure-Python) profile traversal on the training thread
        self._baseprof_cache: Dict[Tuple, ProfileData] = {}
        # detailed profiles of streams adapted before, keyed by iteration
        # fingerprint: a recurring stream's snapshot carries its profile so
        # the worker skips the (GIL-heavy) profile_jaxpr traversal — only a
        # stream's *first* adaptation pays it.  The profile keeps the
        # t_iter it was measured at; a recurrence prices with that.
        self._profile_lru: "collections.OrderedDict[str, ProfileData]" = \
            collections.OrderedDict()
        self._profile_lru_cap = 8
        self.applied: AppliedPolicy = self.executor.baseline()
        self.profile: Optional[ProfileData] = None
        self.baseline_profile: Optional[ProfileData] = None
        self._iter_streams: List[tokenizer.TokenStream] = []
        # incremental iteration signature: histogram/length deltas are
        # applied only for dispatch slots whose content hash changed
        self._sig_acc = tokenizer.SignatureAccumulator()
        self._example_args: Optional[tuple] = None
        self._pending_variant: Optional[PolicyVariant] = None
        self._mirror_src: Optional[np.ndarray] = None
        self.step_idx = 0
        self.history: List[dict] = []
        self.profiling_overhead_s = 0.0      # steady-state Lightweight mode
        self.adaptation_overhead_s = 0.0     # episodic (GenPolicy/store/fit)
        # ---- policystore: persistent fingerprint-keyed adaptation cache
        self.store: Optional[PolicyStore] = None
        self.drift: Optional[DriftClassifier] = None
        if cfg.enabled and cfg.policystore.enabled:
            self.store = PolicyStore(cfg.policystore)
            self.drift = DriftClassifier(cfg.policystore)
        # ---- adaptation pipeline + placement (repro.adapt): the §5 cycle
        # itself is pipeline code shared by every mode; the service owns
        # variant bookkeeping plus the async worker/mailbox machinery
        adapt_mode = cfg.adapt.mode if cfg.enabled else "inline"
        self.pipeline = AdaptationPipeline(cfg, self.executor,
                                           store=self.store, drift=self.drift,
                                           hostmem=self.hostmem)
        self.service = AdaptationService(
            self.pipeline, adapt_mode, max_parked=cfg.adapt.max_parked,
            max_snapshots=cfg.adapt.max_snapshots, history=cfg.adapt.history,
            pace_s=cfg.adapt.pace_s, pace_cap_s=cfg.adapt.pace_cap_s)
        self.machine = StageMachine(cfg, async_mode=adapt_mode != "inline")
        # ---- degradation ladder (repro.faults): link health drives the
        # applied policy down full → trimmed → conservative → no_swap and
        # probe-driven recovery climbs it back up
        self.ladder: Optional[DegradationLadder] = None
        self._full_applied: Optional[AppliedPolicy] = None
        self._probe_src: Optional[np.ndarray] = None
        if cfg.enabled and self.hostmem is not None and cfg.resilience.enabled:
            self.ladder = DegradationLadder(
                hold_iterations=cfg.resilience.ladder_hold_iterations,
                probe_interval=cfg.resilience.probe_interval)
        self._gen_knobs: Tuple[float, ...] = VARIANT_KNOBS
        self._last_sig: Optional[tokenizer.Signature] = None
        # dispatch-shape drift: same primitives, different memory profile
        # (seq-len bucket cycling) — invisible to the token stream, so the
        # runtime tracks the train dispatch's arg shapes itself
        self._train_shape: Optional[Tuple] = None
        self._prev_train_shape: Optional[Tuple] = None
        self._last_decision = None           # DriftDecision of this adaptation
        # per-iteration swap/compute overlap (repro.obs): fraction of
        # engine transfer time hidden under compute spans this iteration
        self._iter_t0 = time.perf_counter()
        self.overlap_history: collections.deque = collections.deque(
            maxlen=512)
        obs.tracer().set_iteration(self.step_idx)

    # ------------------------------------------- adaptation state (service)
    # the GenPolicy variant list, selection winner, and adaptation-latency
    # records moved into AdaptationService with the pipeline extraction;
    # these properties keep the runtime's public surface unchanged
    @property
    def variants(self) -> List[PolicyVariant]:
        return self.service.variants

    @variants.setter
    def variants(self, v) -> None:
        self.service.variants = list(v)

    @property
    def best(self) -> Optional[PolicyVariant]:
        return self.service.best

    @best.setter
    def best(self, v) -> None:
        self.service.best = v

    @property
    def adaptations(self) -> List[dict]:
        return self.service.adaptations

    # ------------------------------------------------------------ helpers
    def _args_key(self, args) -> Tuple:
        import jax
        leaves = jax.tree_util.tree_leaves(args)
        return tuple((getattr(x, "shape", None), str(getattr(x, "dtype", "")))
                     for x in leaves)

    def _baseline_jaxpr(self, args):
        """Trace the no-swap baseline program (save-sites policy — the
        PyTorch-autograd-equivalent memory behavior, see Executor.baseline)."""
        key = ("baseline",) + self._args_key(args)
        if key not in self._jaxpr_cache:
            import jax
            fn = self.step_builder(self.executor.baseline().to_jax())
            self._jaxpr_cache[key] = jax.make_jaxpr(
                fn.__wrapped__ if hasattr(fn, "__wrapped__") else fn)(*args)
        return self._jaxpr_cache[key]

    def _get_step(self, applied: AppliedPolicy) -> Callable:
        fn = self._step_cache.get(applied.fingerprint)
        if fn is None:
            fn = self.step_builder(applied.to_jax())
            self._step_cache[applied.fingerprint] = fn
        return fn

    # -------------------------------------------------------------- setup
    def prepare(self, example_args: tuple) -> AppliedPolicy:
        """WarmUp entry: proactive Algo-3 fit so the first iterations never
        OOM while profiling data accumulates.  With a policy store attached
        the observed program is fingerprinted first: a reuse-tier hit
        applies the cached policy directly (no WarmUp wait, no GenPolicy),
        a warm-start hit seeds the upcoming variant search."""
        self._example_args = example_args
        if not self.cfg.enabled:
            return self.applied
        self.service.begin(self.step_idx)
        with obs.tracer().span(obs.LANE_ADAPT, "prepare", arg=self.step_idx):
            key = ("baseline",) + self._args_key(example_args)
            cj = self._baseline_jaxpr(example_args)
            prof = self._baseprof_cache.get(key)
            if prof is None:
                prof = profile_jaxpr(cj, t_iter=1.0)  # timing unknown
                self._baseprof_cache[key] = prof      # pre-run; memory-only
            self.baseline_profile = prof              # warm-up fit
            tl = build_timeline(prof)
            if self.store is not None and self._try_policystore(prof, tl):
                return self.applied            # reuse tier: cached policy
            if tl.peak > self.budget:
                try:
                    sites = warmup_offload_sites(prof, self.cfg, self.budget)
                    self.applied = AppliedPolicy(
                        None, sites,
                        self.executor.site_universe(prof) - sites, set(),
                        "warmup:" + ",".join(sorted(sites)))
                    kind = "warmup"
                except ChameleonOOMError:
                    self.applied = self.executor.conservative(prof)
                    kind = "conservative"
            else:
                self.applied = self.executor.baseline()
                kind = "baseline"
            self._audit_apply(kind)
        return self.applied

    def _audit_apply(self, kind: str, knob: Optional[float] = None) -> None:
        """Audit-log the policy taking effect (repro.obs drift trail)."""
        if self.ladder is not None:
            # a fresh adaptation supersedes any ladder degradation: it is
            # the new rung-0 policy, and if the link is still bad the
            # mirror traffic re-degrades health and the ladder re-descends
            self._full_applied = self.applied
            self.ladder.reset(self.step_idx, "new-policy")
        obs.audit().event(
            "policy.apply", policy_kind=kind, step=self.step_idx,
            policy=self.applied.fingerprint[:48], knob=knob,
            n_offload=len(self.applied.offload),
            release_plan=len(self.applied.release_plan))

    # ------------------------------------------- policystore (repro.policystore)
    def _try_policystore(self, prof: ProfileData, tl) -> bool:
        """Classify the observed program against the store (pipeline code)
        and *install* the outcome (runtime's job).  Returns True when a
        reuse-tier hit applied a cached policy (callers skip the WarmUp
        fit); warm-start/regen configure the variant search and return
        False."""
        fp, decision = self.pipeline.classify(
            prof, self.budget,
            bwmodel=self.hostmem.bwmodel if self.hostmem else None)
        if decision.tier is Tier.REUSE:
            # identity must be a hash test, not a float threshold: blended
            # similarity is capped below 1.0 for unequal hashes, but hash
            # equality is the authoritative check either way
            rec = decision.record
            exact = rec is not None and fp.exact in (
                rec.prepare_fingerprint.exact, rec.fingerprint.exact)
            hit = self.pipeline.apply_cached(rec, prof, tl, self.budget,
                                             exact_hit=exact)
            if hit is not None:
                self._last_decision = decision
                self.applied = hit.applied
                if hit.profile is not None:
                    # the schedule remapped: engine feedback follows it
                    self.profile = hit.profile
                    if self.hostmem is not None:
                        self.executor.bind_release_points(
                            self.applied, self.hostmem.engine)
                        self.hostmem.engine.begin_iteration()
                self.store.touch(rec)
                self.machine.force_stable(self.step_idx, "policystore-reuse")
                self.machine.n_genpolicy = None
                self._gen_knobs = VARIANT_KNOBS
                self._audit_apply("reuse", knob=rec.knob if rec else None)
                self._finish_adaptation("reuse")
                return True
            decision = self.drift.demote(decision, "match-miss")
        self._last_decision = decision
        self._gen_knobs = self.pipeline.warm_knobs(decision)
        self.machine.n_genpolicy = (len(self._gen_knobs) - 1
                                    if self._gen_knobs != VARIANT_KNOBS
                                    else None)
        return False

    def _store_result(self) -> None:
        """Write the adaptation winner back to the store, keyed by the
        profiled train-step stream (cold-start exact hit) and carrying the
        full iteration signature (mid-run drift similarity)."""
        if self.store is None or self.best is None or self.profile is None:
            return
        iter_fp = None
        if self._last_sig is not None and len(self._last_sig):
            # virtual-length-aware: capped scan materializations must not
            # collapse different layer counts into one iteration key
            iter_fp = self.pipeline.iteration_fingerprint(self._last_sig)
        rec = self.pipeline.build_record(
            self.best, self.profile, self.budget, iter_fp=iter_fp,
            bwmodel=self.hostmem.bwmodel if self.hostmem else None)
        self.store.put(rec)
        obs.audit().event(
            "policy.store_put", key=rec.key[:12],
            policy_kind=rec.policy_kind, knob=self.best.knob,
            measured_t=round(self.best.measured_t or 0.0, 6),
            step=self.step_idx)

    def _finish_adaptation(self, tier: str) -> None:
        """Close the adaptation-latency window opened by ``prepare``."""
        self.service.finish(tier, self.step_idx)

    # ------------------------------------------------------ per-iteration
    def step_fn(self) -> Callable:
        return self._get_step(self.applied)

    def record_dispatch(self, name: str, fn: Callable, args: tuple) -> None:
        """Lightweight mode: token stream of this dispatch (trace cached by
        arg shapes, so steady-state cost is a dict lookup + append)."""
        t0 = time.perf_counter()
        key = (name, self.applied.fingerprint) + self._args_key(args)
        toks = self._trace_cache.get(key)
        if toks is None:
            import jax
            try:
                traced = fn.trace(*args)          # jitted fn
                cj = traced.jaxpr
            except AttributeError:
                cj = jax.make_jaxpr(fn)(*args)
            toks = tokenizer.tokenize_jaxpr_stream(cj)
            self._trace_cache[key] = toks
        self._iter_streams.append(toks)
        if name == "train":
            self._last_train_args = args
            self._train_shape = key[2:]           # arg shapes/dtypes only
        self.profiling_overhead_s += time.perf_counter() - t0

    def end_iteration(self, t_iter: float) -> Stage:
        t0 = time.perf_counter()
        # the policy that *this* iteration executed — _genpolicy_step /
        # _select_best may replace self.applied for the next one below
        ran = self.applied
        sig = self._sig_acc.update(self._iter_streams)
        self._iter_streams = []
        self._last_sig = sig
        prev_stage = self.machine.stage
        stage = self.machine.observe(sig, self.step_idx)
        # shape drift (same op stream, different shapes -> different memory
        # profile): Algo 1 cannot see it, so re-enter WarmUp ourselves; the
        # policystore keys buckets separately (per-site byte aggregates) so
        # a recurring bucket reuses its own cached policy
        shape_drift = (self.cfg.enabled
                       and self._prev_train_shape is not None
                       and self._train_shape is not None
                       and self._train_shape != self._prev_train_shape)
        if shape_drift and stage is not Stage.WARMUP:
            stage = self.machine.to_warmup(self.step_idx, "shape-change")
        self._prev_train_shape = self._train_shape
        self.step_idx += 1

        # a variant ran this iteration: record its measured time
        if self._pending_variant is not None:
            self._pending_variant.measured_t = t_iter
            self._pending_variant = None

        # episodic adaptation work (Detailed profiling, variant selection,
        # policystore write/lookup, re-prepare) is accounted separately
        # from the steady-state Lightweight-mode bookkeeping: the paper's
        # Table-1 overhead claim is per-iteration, adaptation is what
        # benchmarks/adapt_bench.py measures
        t_adapt = time.perf_counter()
        if stage is Stage.GENPOLICY:
            self._genpolicy_step(t_iter)
        elif stage is Stage.STABLE and prev_stage is Stage.GENPOLICY:
            self._select_best()
        elif stage is Stage.ADAPTING and prev_stage is not Stage.ADAPTING:
            # async placement: the sequence settled — hand the background
            # worker an immutable snapshot (or install a parked
            # speculative result on the spot) and keep iterating
            self._async_kickoff(t_iter)
        elif stage is Stage.WARMUP and (prev_stage is not Stage.WARMUP
                                        or shape_drift):
            # sequence (or dispatch shape) changed: back to the
            # conservative fit (Fig 2 loop) — shape drift re-prepares even
            # when observe() left the machine in/through WarmUp this step
            self.service.reset_search()
            if self.machine.async_mode:
                # supersede anything in flight for the old stream
                self.service.invalidate("shape-drift" if shape_drift
                                        else "seq-change")
            if self._example_args is not None:
                args = getattr(self, "_last_train_args", self._example_args)
                if not self.machine.async_mode:
                    # inline (reference mode): re-trace + re-profile from
                    # scratch, as the paper's loop does.  Async keeps the
                    # shape-keyed caches so a recurring bucket's re-entry
                    # costs a dict hit, not a trace — genuinely new
                    # shapes miss the key and still pay once.
                    self._jaxpr_cache.clear()
                    self._baseprof_cache.clear()
                self.prepare(args)
        adapt_dt = time.perf_counter() - t_adapt
        self.adaptation_overhead_s += adapt_dt
        # §5.4.2 execution feedback for the policy that just ran: mirror
        # its swap schedule through the engine (real policy_swap-class
        # copies, released by advance_op at each promised op), then sweep
        # any remaining planned swap-outs — the iteration's op stream has
        # fully executed, so every promised release point has passed —
        # and reset the op cursor for the next iteration.
        if self.hostmem is not None and ran.release_plan:
            self._mirror_policy_swaps(ran)
            eng = self.hostmem.engine
            eng.advance_op(max(ran.release_plan.values()))
            eng.begin_iteration()
        # async swap-in point: only *after* the executed policy's engine
        # feedback drained may a worker result replace self.applied — the
        # iteration boundary the swap-in protocol promises
        if self.machine.stage is Stage.ADAPTING:
            t_install = time.perf_counter()
            res = self.service.poll()
            if res is not None:
                self._install_result(res, "adapt-installed")
            elif self.service.watchdog(self.cfg.resilience.adapt_timeout_s):
                # hung or lost worker: supersede its epoch (a late result
                # can never install) and un-wedge the stage machine — the
                # current policy keeps serving, which is safe by
                # construction (it fit before the drift)
                self.service.invalidate("worker-timeout")
                self.machine.complete_adapting(self.step_idx,
                                               "adapt-timeout")
                self._finish_adaptation("timeout")
            self.adaptation_overhead_s += time.perf_counter() - t_install
        # degradation ladder (repro.faults): react to link health after
        # this iteration's engine feedback; GenPolicy iterations are
        # skipped — the variant search overwrites self.applied anyway and
        # _select_best's install resets the ladder
        if self.ladder is not None and stage is not Stage.GENPOLICY:
            t_ladder = time.perf_counter()
            self._ladder_step()
            self.adaptation_overhead_s += time.perf_counter() - t_ladder
        self.history.append({"step": self.step_idx, "stage": stage.value,
                             "policy": self.applied.fingerprint,
                             "t_iter": t_iter})
        self._close_obs_window(ran)
        self.profiling_overhead_s += (time.perf_counter() - t0) - adapt_dt
        return stage

    def _close_obs_window(self, ran: Optional[AppliedPolicy] = None) -> None:
        """Per-iteration overlap efficiency: how much of this window's
        engine transfer time was hidden under compute spans (after the
        mirror swaps above, so the applied policy's traffic counts).
        Then close the memory ledger's window for the policy that ran:
        realized-peak replay, the predicted-vs-realized scoreboard, byte
        conservation, and budget-headroom feedback into the health FSM."""
        t1 = time.perf_counter()
        eff, transfer_s, hidden_s = obs.window_efficiency(
            obs.tracer(), self._iter_t0, t1)
        if transfer_s > 0.0:
            self.overlap_history.append({
                "step": self.step_idx, "t": t1,
                "efficiency": eff, "transfer_s": transfer_s,
                "hidden_s": hidden_s})
            obs.metrics().gauge("overlap_efficiency", eff, t=t1)
        obs.metrics().counter("iterations")
        rec = obs.ledger().close_iteration(
            self.step_idx,
            profile=self.profile or self.baseline_profile,
            swap=ran.swap if ran is not None else None,
            budget=self.budget,
            pool_stats=(self.hostmem.pool.stats()
                        if self.hostmem is not None else None),
            t=t1)
        self._memledger_feedback(rec)
        self._iter_t0 = t1
        obs.tracer().set_iteration(self.step_idx)

    def _memledger_feedback(self, rec: dict) -> None:
        """Ledger → health FSM: sustained margin erosion (realized peak
        above plan with the budget headroom nearly gone) degrades the
        ``memory`` pseudo-class, so the ladder backs the policy off
        *before* an OOM.  On a clean run realized == projected and the
        class decays back to healthy like any link."""
        if self.hostmem is None or self.ladder is None:
            return
        health = self.hostmem.engine.health
        if MEM_CLASS not in health.links:
            return
        headroom, error = rec.get("headroom_frac"), rec.get("peak_error")
        if headroom is None or error is None:
            # nothing scored (warmup / conservative rung: no swap plan to
            # compare against) — counts as a comfortable iteration
            health.note_success(MEM_CLASS)
            return
        severe = headroom < 0.0
        mild = (error > 0.0
                and headroom < self.cfg.resilience.headroom_degrade_frac)
        if severe or mild:
            health.note_pressure(MEM_CLASS, severe=severe)
            obs.audit().event("memory.pressure", step=rec["step"],
                              severe=severe, headroom=round(headroom, 4),
                              error=round(error, 4))
        else:
            health.note_success(MEM_CLASS)

    # --------------------------------------- §5.4.2 applied-swap traffic
    def _mirror_policy_swaps(self, applied: AppliedPolicy) -> None:
        """Route the executed policy's swap schedule through the host tier
        as real policy_swap-class copies: each entry's D2H is retired by
        ``advance_op`` at its simulator-promised release op (dropping the
        source reference there, not at first reuse), then swapped back in
        at its planned swap-in point, recycling the slabs.  This is the
        engine-visible form of the swap traffic XLA executes inside the
        compiled step; it keeps per-class counters and the bandwidth
        curve fed by the *applied* policy, capped per iteration by
        ``HostMemConfig.mirror_swap_bytes``."""
        swap = applied.swap
        cap = self.cfg.hostmem.mirror_swap_bytes
        if swap is None or not cap or not swap.entries:
            return
        eng = self.hostmem.engine
        budget = cap
        picked = []
        for e in sorted(swap.entries, key=lambda e: e.birth):
            if e.nbytes <= 0 or e.nbytes > budget:
                continue
            budget -= e.nbytes
            picked.append(e)
        if not picked:
            return
        # the schedule is in flight all at once — widen the window so
        # copies retire at their promised ops, not by overflow
        eng.set_class_depth("policy_swap", len(picked) + 2)
        biggest = max(e.nbytes for e in picked)
        if self._mirror_src is None or self._mirror_src.nbytes < biggest:
            self._mirror_src = np.zeros(biggest, np.uint8)
        outs = [(e, eng.submit_swap_out(self._mirror_src[:e.nbytes],
                                        SwapPolicy.entry_tag(e)))
                for e in picked]
        for e, _ in sorted(outs, key=lambda t: t[0].swap_out_done_op):
            eng.advance_op(e.swap_out_done_op)      # promised release point
        for e, ev in sorted(outs, key=lambda t: t[0].swap_in_op):
            eng.wait(eng.submit_swap_in(ev, SwapPolicy.entry_tag(e)))

    # ------------------------------------ degradation ladder (repro.faults)
    def _ladder_step(self) -> None:
        """Consult link health and move the applied policy along the
        ladder (full → trimmed → conservative → no_swap and back)."""
        lad = self.ladder
        eng = self.hostmem.engine
        if lad.should_probe(self.step_idx):
            self._health_probe(eng)
        move = lad.decide(eng.health.worst(), self.step_idx)
        if move is not None:
            self._apply_rung(move)

    def _health_probe(self, eng) -> None:
        """Small round-trip copies through the engine: at a reduced rung
        the applied policy may generate no link traffic at all, so these
        probes are what feeds the health machine's recovery streak (and,
        on a still-bad link, its error score)."""
        rs = self.cfg.resilience
        if self._probe_src is None:
            self._probe_src = np.zeros(max(rs.probe_bytes, 1), np.uint8)
        ok = 0
        for _ in range(max(rs.probe_burst, 1)):
            try:
                ev = eng.wait(eng.submit_swap_out(self._probe_src,
                                                  "health_probe"))
                if ev.failed:
                    continue             # failure already fed health
                eng.wait(eng.submit_swap_in(ev, "health_probe"))
                ok += 1
            except Exception:  # noqa: BLE001 — probes must never raise
                pass
        obs.audit().event("ladder.probe", step=self.step_idx,
                          rung=self.ladder.name, ok=ok,
                          burst=max(rs.probe_burst, 1),
                          health=self.hostmem.engine.health.worst())

    def _apply_rung(self, rung: int) -> None:
        """Rebuild ``self.applied`` for the rung the ladder moved to.
        Rungs that cannot be built from available state fall through to
        the next more conservative one."""
        prof = self.profile or self.baseline_profile
        applied: Optional[AppliedPolicy] = None
        if rung == RUNG_FULL:
            applied = self._full_applied or self.applied
        elif rung == RUNG_TRIMMED:
            full = self._full_applied or self.applied
            if prof is not None and full is not None and full.swap is not None:
                kept = trim_swap(prof, full.swap, self.budget,
                                 self.cfg.resilience.trim_drop_fraction)
                if kept is not None:
                    swap = SwapPolicy(
                        kept, projected_peak(prof, kept),
                        full.swap.baseline_peak, full.swap.budget,
                        full.swap.stall_time, full.swap.t_iter,
                        full.swap.n_ops,
                        contention_s=full.swap.contention_s,
                        occupancy=getattr(full.swap, "occupancy", 0.0))
                    applied = self.executor.lower(swap, prof)
        if applied is None and rung in (RUNG_TRIMMED, RUNG_CONSERVATIVE):
            # conservative WarmUp rung: the Algo-3 passive fit — no
            # per-tensor schedule, no release plan, guaranteed to fit
            if prof is not None:
                try:
                    sites = warmup_offload_sites(prof, self.cfg, self.budget)
                    applied = AppliedPolicy(
                        None, sites,
                        self.executor.site_universe(prof) - sites, set(),
                        "ladder-warmup:" + ",".join(sorted(sites)))
                except ChameleonOOMError:
                    applied = self.executor.conservative(prof)
            else:
                applied = self.executor.conservative(None)
        if applied is None:              # RUNG_NO_SWAP (or nothing else)
            applied = self.executor.baseline()
        self.applied = applied
        self.executor.bind_release_points(applied, self.hostmem.engine)
        self.hostmem.engine.begin_iteration()
        obs.audit().event(
            "ladder.apply", step=self.step_idx, rung=RUNG_NAMES[rung],
            policy=applied.fingerprint[:48],
            swap_entries=(len(applied.swap.entries) if applied.swap else 0),
            release_plan=len(applied.release_plan))

    # ----------------------------------------------------- GenPolicy path
    def _genpolicy_step(self, t_iter: float) -> None:
        args = getattr(self, "_last_train_args", self._example_args)
        if args is None:
            return
        knob_next = self._gen_knobs[len(self.variants) % len(self._gen_knobs)]
        with obs.tracer().span(obs.LANE_ADAPT, "genpolicy_step",
                               arg=knob_next):
            self._genpolicy_step_body(args, t_iter)

    def _genpolicy_step_body(self, args, t_iter: float) -> None:
        cj = self._baseline_jaxpr(args)
        prof = profile_jaxpr(cj, t_iter=t_iter)   # Detailed mode
        self.profile = prof
        knob = self._gen_knobs[len(self.variants) % len(self._gen_knobs)]
        hm = self.hostmem
        # bwmodel prices transfer sizes and the engine prices the live
        # per-class link backlog for every variant; free-times are handed
        # to the engine only for the variant that wins (_select_best)
        var = self.pipeline.variant(prof, knob, self.budget,
                                    bwmodel=hm.bwmodel if hm else None,
                                    engine=hm.engine if hm else None)
        self.variants.append(var)
        self._pending_variant = var
        self.applied = var.applied                 # next iteration runs it

    def _select_best(self) -> None:
        with obs.tracer().span(obs.LANE_ADAPT, "select_best",
                               arg=len(self.variants)):
            timed = [v for v in self.variants if v.measured_t is not None]
            if timed:
                self._select_best_timed(timed)
                self._audit_apply("genpolicy", knob=self.best.knob)
            tier = (self._last_decision.tier.value
                    if self._last_decision is not None else Tier.REGEN.value)
            self._finish_adaptation(tier)
            self._last_decision = None
            self._gen_knobs = VARIANT_KNOBS    # next adaptation starts cold
            self.machine.n_genpolicy = None
            if timed:
                self._store_result()

    def _select_best_timed(self, timed: List[PolicyVariant]) -> None:
        self.best = min(timed, key=lambda v: v.measured_t)
        self.applied = self.best.applied
        if self.hostmem is not None and self.best.swap is not None:
            # §5.4.2 hand-off: only the applied policy's release points
            # reach the engine; end_iteration drives engine.advance_op
            # over them so swapped buffers are freed at the promised op
            # instead of at first reuse.  (Rebuilt here rather than
            # trusted from Executor.lower: variants may carry an
            # applied policy constructed elsewhere.)
            self.applied.release_plan = {
                SwapPolicy.entry_tag(e): e.swap_out_done_op
                for e in self.best.swap.entries
                if e.swap_out_done_op >= 0}
            self.executor.bind_release_points(self.applied,
                                              self.hostmem.engine)
            self.hostmem.engine.begin_iteration()

    # ------------------------------------------ async placement (repro.adapt)
    def _snapshot(self, args, t_iter: float) -> AdaptSnapshot:
        """Freeze this adaptation's inputs.  Tracing stays on the training
        thread (and is cached for recurring streams); the worker only pays
        the profile traversal — never a concurrent jax trace."""
        cj = self._baseline_jaxpr(args)
        hm = self.hostmem
        iter_fp = None
        if self._last_sig is not None and len(self._last_sig):
            iter_fp = self.pipeline.iteration_fingerprint(self._last_sig)
        cached_prof = (self._profile_lru.get(iter_fp.exact)
                       if iter_fp is not None else None)
        return AdaptSnapshot(
            jaxpr=cj, t_iter=t_iter, budget=self.budget,
            bwmodel=hm.bwmodel.snapshot() if hm else None,
            contention_s=hm.engine.queued_delay() if hm else 0.0,
            backlog=hm.engine.backlog_snapshot() if hm else {},
            gen_knobs=(),                  # worker classifies + seeds itself
            iter_exact=iter_fp.exact if iter_fp is not None else None,
            iter_fp=iter_fp, step=self.step_idx, profile=cached_prof)

    def _async_kickoff(self, t_iter: float) -> None:
        """ADAPTING entry: install a parked speculative result if the
        observed stream has one (zero inline GenPolicy steps, nothing in
        flight), otherwise enqueue the snapshot for the worker."""
        args = getattr(self, "_last_train_args", self._example_args)
        if args is None:
            return
        snap = self._snapshot(args, t_iter)
        self.service.begin(self.step_idx)
        hit = self.service.take_speculative(snap.iter_exact)
        if hit is not None:
            self._install_result(hit, "speculative-hit")
            return
        self.service.submit(snap)

    def _install_result(self, res: AdaptResult, why: str) -> None:
        """Swap-in: adopt a completed (worker or parked speculative)
        adaptation at the iteration boundary.  Mirrors the inline
        ``_select_best_timed`` install — applied policy, engine release
        points, stage transition, accounting."""
        self.applied = res.applied
        if res.profile is not None:
            self.profile = res.profile
            if res.iter_exact:           # recurrences skip worker profiling
                self._profile_lru[res.iter_exact] = res.profile
                self._profile_lru.move_to_end(res.iter_exact)
                while len(self._profile_lru) > self._profile_lru_cap:
                    self._profile_lru.popitem(last=False)
        self.best = PolicyVariant(res.applied, res.swap,
                                  res.knob if res.knob is not None else 1.0,
                                  measured_t=None)
        if self.hostmem is not None and res.swap is not None:
            self.applied.release_plan = {
                SwapPolicy.entry_tag(e): e.swap_out_done_op
                for e in res.swap.entries if e.swap_out_done_op >= 0}
            self.executor.bind_release_points(self.applied,
                                              self.hostmem.engine)
            self.hostmem.engine.begin_iteration()
        self.machine.complete_adapting(self.step_idx, why)
        self.machine.n_genpolicy = None
        self._gen_knobs = VARIANT_KNOBS
        self._audit_apply(res.kind, knob=res.knob)
        self.service.note_adapted(res.iter_exact)
        self.service.finish(res.tier, self.step_idx)
        self._last_decision = None

    def close(self) -> None:
        """Stop the background worker (no-op for inline placement)."""
        self.service.close()

    # ----------------------------------------------------------- reports
    def stats(self) -> dict:
        return {
            "stage": self.machine.stage.value,
            "transitions": list(self.machine.transitions),
            "n_variants": len(self.variants),
            "best_knob": self.best.knob if self.best else None,
            "applied": self.applied.fingerprint,
            "release_plan": len(self.applied.release_plan),
            "contention_s": (self.best.swap.contention_s
                             if self.best and self.best.swap else 0.0),
            "profiling_overhead_s": self.profiling_overhead_s,
            "adaptation_overhead_s": self.adaptation_overhead_s,
            "ladder": self.ladder.stats() if self.ladder else None,
            "signature": self._sig_acc.stats(),
            "hostmem": self.hostmem.stats() if self.hostmem else None,
            "policystore": self.policystore_stats(),
            "adapt": self.service.stats(),
            "obs": self.obs_stats(),
        }

    def obs_stats(self) -> dict:
        """Tracing/overlap summary (repro.obs).  ``overlap`` aggregates the
        per-iteration swap/compute overlap-efficiency history; iterations
        with no engine traffic are excluded (``measured`` counts the ones
        that had transfers, ``iterations`` every closed window)."""
        effs = [h["efficiency"] for h in self.overlap_history
                if h["efficiency"] is not None]
        return {
            "overlap": {
                "last": effs[-1] if effs else None,
                "mean": float(np.mean(effs)) if effs else None,
                "measured": len(effs),
                "iterations": self.step_idx,
                "transfer_s": float(sum(h["transfer_s"]
                                        for h in self.overlap_history)),
                "hidden_s": float(sum(h["hidden_s"]
                                      for h in self.overlap_history)),
            },
            "tracer": obs.tracer().stats(),
            "audit": obs.audit().counts(),
            "memory": obs.ledger().stats(),
        }

    def policystore_stats(self) -> Optional[dict]:
        """Per-tier hit counters, store state, and adaptation latencies."""
        if self.store is None:
            return None
        gp = sum(1 for h in self.history if h["stage"] == Stage.GENPOLICY.value)
        return {
            "store": self.store.stats(),
            "tiers": self.drift.stats(),
            "adaptations": list(self.adaptations),
            "genpolicy_steps_total": gp,
        }
