"""ChameleonRuntime — ties profiler, stage machine, policy generator and
executor into the per-iteration loop (paper Fig. 2).

Protocol (driven by ``repro.runtime.trainer.Trainer``):

    rt = ChameleonRuntime(cham_cfg, step_builder)
    rt.prepare(example_args)                  # WarmUp fit (Algo 3, proactive)
    for it in range(steps):
        fn = rt.step_fn()                     # current applied policy
        t0 = time(); out = fn(*args); block(); dt = time() - t0
        rt.record_dispatch("train", fn, args) # Lightweight-mode op stream
        ... (any extra dispatches: eval, optimizer-skip, ... recorded too)
        rt.end_iteration(dt)                  # Algo 1 stage machine

During GenPolicy the runtime generates one policy variant per step (varying
the logical-layer grouping knob) and, after n steps, keeps the variant with
the best measured iteration time — the paper's §7.1 "generates five policies
and selects the one with the best runtime performance".
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.common.config import ChameleonConfig
from repro.core import tokenizer
from repro.core.executor import AppliedPolicy, Executor
from repro.core.memtrace import build_timeline
from repro.core.oom import warmup_offload_sites
from repro.core.policy import ChameleonOOMError, SwapPolicy, generate_policy
from repro.core.profiler import ProfileData, profile_jaxpr
from repro.core.stages import Stage, StageMachine

# grouping knobs tried across the n GenPolicy steps (variant selection)
VARIANT_KNOBS = (1.0, 2.0, 0.5, 4.0, 0.25)


@dataclass
class PolicyVariant:
    applied: AppliedPolicy
    swap: Optional[SwapPolicy]
    knob: float
    measured_t: Optional[float] = None


class ChameleonRuntime:
    def __init__(self, cfg: ChameleonConfig,
                 step_builder: Callable[[Optional[Any]], Callable],
                 budget: Optional[int] = None, hostmem=None):
        self.cfg = cfg
        self.budget = budget if budget is not None else cfg.hbm_budget_bytes
        self.step_builder = step_builder
        self.executor = Executor(cfg)
        self.machine = StageMachine(cfg)
        if hostmem is None and cfg.enabled and cfg.hostmem.enabled:
            from repro.hostmem import HostMemTier
            hostmem = HostMemTier.from_chameleon(cfg)
        self.hostmem = hostmem
        self._step_cache: Dict[str, Callable] = {}
        self._trace_cache: Dict[Tuple, np.ndarray] = {}
        self._jaxpr_cache: Dict[Tuple, Any] = {}
        self.applied: AppliedPolicy = self.executor.baseline()
        self.profile: Optional[ProfileData] = None
        self.baseline_profile: Optional[ProfileData] = None
        self._iter_streams: List[np.ndarray] = []
        self._example_args: Optional[tuple] = None
        self.variants: List[PolicyVariant] = []
        self._pending_variant: Optional[PolicyVariant] = None
        self._mirror_src: Optional[np.ndarray] = None
        self.best: Optional[PolicyVariant] = None
        self.step_idx = 0
        self.history: List[dict] = []
        self.profiling_overhead_s = 0.0

    # ------------------------------------------------------------ helpers
    def _args_key(self, args) -> Tuple:
        import jax
        leaves = jax.tree_util.tree_leaves(args)
        return tuple((getattr(x, "shape", None), str(getattr(x, "dtype", "")))
                     for x in leaves)

    def _baseline_jaxpr(self, args):
        """Trace the no-swap baseline program (save-sites policy — the
        PyTorch-autograd-equivalent memory behavior, see Executor.baseline)."""
        key = ("baseline",) + self._args_key(args)
        if key not in self._jaxpr_cache:
            import jax
            fn = self.step_builder(self.executor.baseline().to_jax())
            self._jaxpr_cache[key] = jax.make_jaxpr(
                fn.__wrapped__ if hasattr(fn, "__wrapped__") else fn)(*args)
        return self._jaxpr_cache[key]

    def _get_step(self, applied: AppliedPolicy) -> Callable:
        fn = self._step_cache.get(applied.fingerprint)
        if fn is None:
            fn = self.step_builder(applied.to_jax())
            self._step_cache[applied.fingerprint] = fn
        return fn

    # -------------------------------------------------------------- setup
    def prepare(self, example_args: tuple) -> AppliedPolicy:
        """WarmUp entry: proactive Algo-3 fit so the first iterations never
        OOM while profiling data accumulates."""
        self._example_args = example_args
        if not self.cfg.enabled:
            return self.applied
        cj = self._baseline_jaxpr(example_args)
        prof = profile_jaxpr(cj, t_iter=1.0)   # timing unknown pre-run; the
        self.baseline_profile = prof           # warm-up fit is memory-only
        tl = build_timeline(prof)
        if tl.peak > self.budget:
            try:
                sites = warmup_offload_sites(prof, self.cfg, self.budget)
                self.applied = AppliedPolicy(None, sites,
                                             self.executor.site_universe(prof)
                                             - sites, set(),
                                             "warmup:" + ",".join(sorted(sites)))
            except ChameleonOOMError:
                self.applied = self.executor.conservative(prof)
        else:
            self.applied = self.executor.baseline()
        return self.applied

    # ------------------------------------------------------ per-iteration
    def step_fn(self) -> Callable:
        return self._get_step(self.applied)

    def record_dispatch(self, name: str, fn: Callable, args: tuple) -> None:
        """Lightweight mode: token stream of this dispatch (trace cached by
        arg shapes, so steady-state cost is a dict lookup + append)."""
        t0 = time.perf_counter()
        key = (name, self.applied.fingerprint) + self._args_key(args)
        toks = self._trace_cache.get(key)
        if toks is None:
            import jax
            try:
                traced = fn.trace(*args)          # jitted fn
                cj = traced.jaxpr
            except AttributeError:
                cj = jax.make_jaxpr(fn)(*args)
            toks = tokenizer.tokenize_jaxpr(cj)
            self._trace_cache[key] = toks
        self._iter_streams.append(toks)
        if name == "train":
            self._last_train_args = args
        self.profiling_overhead_s += time.perf_counter() - t0

    def end_iteration(self, t_iter: float) -> Stage:
        t0 = time.perf_counter()
        # the policy that *this* iteration executed — _genpolicy_step /
        # _select_best may replace self.applied for the next one below
        ran = self.applied
        sig = tokenizer.sequence_signature(self._iter_streams)
        self._iter_streams = []
        prev_stage = self.machine.stage
        stage = self.machine.observe(sig, self.step_idx)
        self.step_idx += 1

        # a variant ran this iteration: record its measured time
        if self._pending_variant is not None:
            self._pending_variant.measured_t = t_iter
            self._pending_variant = None

        if stage is Stage.GENPOLICY:
            self._genpolicy_step(t_iter)
        elif stage is Stage.STABLE and prev_stage is Stage.GENPOLICY:
            self._select_best()
        elif stage is Stage.WARMUP and prev_stage is not Stage.WARMUP:
            # sequence changed: back to the conservative fit (Fig 2 loop)
            self.variants, self.best = [], None
            if self._example_args is not None:
                args = getattr(self, "_last_train_args", self._example_args)
                self._jaxpr_cache.clear()
                self.prepare(args)
        # §5.4.2 execution feedback for the policy that just ran: mirror
        # its swap schedule through the engine (real policy_swap-class
        # copies, released by advance_op at each promised op), then sweep
        # any remaining planned swap-outs — the iteration's op stream has
        # fully executed, so every promised release point has passed —
        # and reset the op cursor for the next iteration.
        if self.hostmem is not None and ran.release_plan:
            self._mirror_policy_swaps(ran)
            eng = self.hostmem.engine
            eng.advance_op(max(ran.release_plan.values()))
            eng.begin_iteration()
        self.history.append({"step": self.step_idx, "stage": stage.value,
                             "policy": self.applied.fingerprint,
                             "t_iter": t_iter})
        self.profiling_overhead_s += time.perf_counter() - t0
        return stage

    # --------------------------------------- §5.4.2 applied-swap traffic
    def _mirror_policy_swaps(self, applied: AppliedPolicy) -> None:
        """Route the executed policy's swap schedule through the host tier
        as real policy_swap-class copies: each entry's D2H is retired by
        ``advance_op`` at its simulator-promised release op (dropping the
        source reference there, not at first reuse), then swapped back in
        at its planned swap-in point, recycling the slabs.  This is the
        engine-visible form of the swap traffic XLA executes inside the
        compiled step; it keeps per-class counters and the bandwidth
        curve fed by the *applied* policy, capped per iteration by
        ``HostMemConfig.mirror_swap_bytes``."""
        swap = applied.swap
        cap = self.cfg.hostmem.mirror_swap_bytes
        if swap is None or not cap or not swap.entries:
            return
        eng = self.hostmem.engine
        budget = cap
        picked = []
        for e in sorted(swap.entries, key=lambda e: e.birth):
            if e.nbytes <= 0 or e.nbytes > budget:
                continue
            budget -= e.nbytes
            picked.append(e)
        if not picked:
            return
        # the schedule is in flight all at once — widen the window so
        # copies retire at their promised ops, not by overflow
        eng.set_class_depth("policy_swap", len(picked) + 2)
        biggest = max(e.nbytes for e in picked)
        if self._mirror_src is None or self._mirror_src.nbytes < biggest:
            self._mirror_src = np.zeros(biggest, np.uint8)
        outs = [(e, eng.submit_swap_out(self._mirror_src[:e.nbytes],
                                        SwapPolicy.entry_tag(e)))
                for e in picked]
        for e, _ in sorted(outs, key=lambda t: t[0].swap_out_done_op):
            eng.advance_op(e.swap_out_done_op)      # promised release point
        for e, ev in sorted(outs, key=lambda t: t[0].swap_in_op):
            eng.wait(eng.submit_swap_in(ev, SwapPolicy.entry_tag(e)))

    # ----------------------------------------------------- GenPolicy path
    def _genpolicy_step(self, t_iter: float) -> None:
        args = getattr(self, "_last_train_args", self._example_args)
        if args is None:
            return
        cj = self._baseline_jaxpr(args)
        prof = profile_jaxpr(cj, t_iter=t_iter)   # Detailed mode
        self.profile = prof
        import dataclasses
        knob = VARIANT_KNOBS[len(self.variants) % len(VARIANT_KNOBS)]
        groups = max(1, int((prof.scan_layers or 32) * knob))
        cfg_v = dataclasses.replace(self.cfg, groups_per_phase=groups)
        tl = build_timeline(prof)
        hm = self.hostmem
        try:
            if tl.peak > self.budget:
                # bwmodel prices transfer sizes and the engine prices the
                # live per-class link backlog for every variant; free-times
                # are handed to the engine only for the variant that wins
                # (_select_best)
                swap = generate_policy(
                    prof, cfg_v, self.budget, timeline=tl,
                    bwmodel=hm.bwmodel if hm else None,
                    engine=hm.engine if hm else None,
                    register_free_times=False)
                applied = self.executor.lower(swap, prof)
            else:
                swap, applied = None, self.executor.baseline()
        except ChameleonOOMError:
            swap, applied = None, self.executor.conservative(prof)
        var = PolicyVariant(applied, swap, knob)
        self.variants.append(var)
        self._pending_variant = var
        self.applied = applied                     # next iteration runs it

    def _select_best(self) -> None:
        timed = [v for v in self.variants if v.measured_t is not None]
        if timed:
            self.best = min(timed, key=lambda v: v.measured_t)
            self.applied = self.best.applied
            if self.hostmem is not None and self.best.swap is not None:
                # §5.4.2 hand-off: only the applied policy's release points
                # reach the engine; end_iteration drives engine.advance_op
                # over them so swapped buffers are freed at the promised op
                # instead of at first reuse.  (Rebuilt here rather than
                # trusted from Executor.lower: variants may carry an
                # applied policy constructed elsewhere.)
                self.applied.release_plan = {
                    SwapPolicy.entry_tag(e): e.swap_out_done_op
                    for e in self.best.swap.entries
                    if e.swap_out_done_op >= 0}
                self.executor.bind_release_points(self.applied,
                                                  self.hostmem.engine)
                self.hostmem.engine.begin_iteration()

    # ----------------------------------------------------------- reports
    def stats(self) -> dict:
        return {
            "stage": self.machine.stage.value,
            "transitions": list(self.machine.transitions),
            "n_variants": len(self.variants),
            "best_knob": self.best.knob if self.best else None,
            "applied": self.applied.fingerprint,
            "release_plan": len(self.applied.release_plan),
            "contention_s": (self.best.swap.contention_s
                             if self.best and self.best.swap else 0.0),
            "profiling_overhead_s": self.profiling_overhead_s,
            "hostmem": self.hostmem.stats() if self.hostmem else None,
        }
