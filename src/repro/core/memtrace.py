"""No-swap memory-timeline reconstruction (paper Fig. 3).

From tensor liveness we rebuild the device-memory usage curve the program
*would* have without any swap — the input to MRL construction.  Static
memory (params/optimizer state) is a constant base handled by ZeRO; the
curve here is the dynamic (activation) component, exactly the split the
paper makes versus DeepSpeed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.core.profiler import ProfileData, TensorInstance


@dataclass
class MemoryTimeline:
    usage: np.ndarray          # bytes in use *before* executing op i (len n_ops+1)
    static_bytes: int
    peak: int
    peak_op: int

    def total(self, i: int) -> int:
        return int(self.usage[i]) + self.static_bytes


def build_timeline(prof: ProfileData, include_static: bool = True) -> MemoryTimeline:
    n = prof.n_ops
    delta = np.zeros(n + 2, np.int64)
    for t in prof.tensors:
        b = min(max(t.birth, 0), n)
        d = min(max(t.death, b), n + 1)
        delta[b] += t.nbytes
        delta[d] -= t.nbytes
    usage = np.cumsum(delta)[: n + 1]
    peak_op = int(np.argmax(usage))
    peak = int(usage[peak_op])
    static = prof.static_bytes if include_static else 0
    return MemoryTimeline(usage, static, peak + static, peak_op)


def over_budget_ops(tl: MemoryTimeline, budget: int) -> Tuple[np.ndarray, np.ndarray]:
    """(op indices, required reduction bytes) where usage exceeds budget."""
    total = tl.usage.astype(np.int64) + tl.static_bytes
    idx = np.nonzero(total > budget)[0]
    return idx, (total[idx] - budget)
