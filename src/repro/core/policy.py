"""End-to-end policy generation (paper Algo. 2) and the SwapPolicy object
the Executor applies.

Algo 2: while the MRL is non-empty, rebuild the CL (scores depend on the
remaining MREs), run the simulator over it, and extend the policy.  If the
CL comes back empty with MREs outstanding, training cannot fit even with
swap — raise (the caller's WarmUp OOM loop may still downshift batch or
enable remat, see core.oom).  Finally §5.4.2 computes swap-out completion
times for early memory release.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import numpy as np

from repro.common.config import ChameleonConfig
from repro.core.candidates import build_candidate_list
from repro.core.memtrace import MemoryTimeline, build_timeline
from repro.core.mrl import MRL
from repro.core.profiler import ProfileData
from repro.core.simulator import PolicyEntry, Simulator


class ChameleonOOMError(RuntimeError):
    """No candidate set can bring the program under the memory budget."""


@dataclass
class SwapPolicy:
    entries: List[PolicyEntry]
    projected_peak: int            # bytes after applying the policy
    baseline_peak: int
    budget: int
    stall_time: float
    t_iter: float
    n_ops: int
    fingerprint: str = ""
    contention_s: float = 0.0      # link backlog priced at generation time
    occupancy: float = 0.0         # sustained other-class link occupancy

    def __post_init__(self):
        sites = sorted({(e.site, e.layer) for e in self.entries})
        self.fingerprint = f"swap[{len(self.entries)}]" + ",".join(
            f"{s}:{l}" for s, l in sites[:64])

    # ---- site-level view (scan-mode application granularity) ----------
    def site_fractions(self, prof: ProfileData) -> Dict[str, float]:
        per_site_total: Dict[str, int] = {}
        for t in prof.candidates:
            if t.site:
                per_site_total[t.site] = per_site_total.get(t.site, 0) + 1
        picked: Dict[str, int] = {}
        for e in self.entries:
            if e.site:
                picked[e.site] = picked.get(e.site, 0) + 1
        return {s: picked.get(s, 0) / n for s, n in per_site_total.items() if n}

    def offload_sites(self, prof: ProfileData, threshold: float = 0.5) -> Set[str]:
        """Sites to offload when applying at scan granularity."""
        return {s for s, f in self.site_fractions(prof).items()
                if f >= threshold}

    @property
    def swapped_bytes(self) -> int:
        return sum(e.nbytes for e in self.entries)

    # ---- §5.4.2 free-time hand-off to the host-memory tier -------------
    @staticmethod
    def entry_tag(e: PolicyEntry) -> str:
        return f"{e.site or 'tensor'}:{e.layer}:{e.uid}"

    def register_free_times(self, engine) -> int:
        """Hand the simulator-planned release points to a
        ``repro.hostmem.engine.TransferEngine`` so swap-out completion
        events carry them (the custom-recordStream analogue)."""
        for e in self.entries:
            engine.plan_release(self.entry_tag(e), e.swap_out_done_op)
        return len(self.entries)

    def summary(self) -> str:
        gib = 1 / 2 ** 30
        return (f"SwapPolicy: {len(self.entries)} tensors, "
                f"{self.swapped_bytes * gib:.2f} GiB swapped, "
                f"peak {self.baseline_peak * gib:.2f} -> "
                f"{self.projected_peak * gib:.2f} GiB "
                f"(budget {self.budget * gib:.2f}), "
                f"stall {self.stall_time * 1e3:.1f} ms")


def projected_peak(prof: ProfileData, entries: List[PolicyEntry]) -> int:
    """Dynamic-memory peak with the swapped tensors absent between
    swap-out completion and swap-in pre-trigger (timeline replay).  Used
    both for a freshly generated policy and to re-verify a cached policy
    remapped onto a new program (repro.policystore reuse tier)."""
    n = prof.n_ops
    delta = np.zeros(n + 2, np.int64)
    by_uid = {e.uid: e for e in entries}
    for t in prof.tensors:
        b = min(max(t.birth, 0), n)
        d = min(max(t.death, b), n + 1)
        e = by_uid.get(t.uid)
        if e is not None:
            out = min(max(e.swap_out_done_op, b), d)
            back = min(max(e.swap_in_op, out), d)
            delta[b] += t.nbytes
            delta[out] -= t.nbytes
            delta[back] += t.nbytes
            delta[d] -= t.nbytes
        else:
            delta[b] += t.nbytes
            delta[d] -= t.nbytes
    usage = np.cumsum(delta)[: n + 1]
    return int(usage.max(initial=0)) + prof.static_bytes


def generate_policy(prof: ProfileData, cfg: ChameleonConfig,
                    budget: Optional[int] = None,
                    timeline: Optional[MemoryTimeline] = None,
                    bwmodel=None, engine=None,
                    register_free_times: bool = True) -> SwapPolicy:
    budget = budget if budget is not None else cfg.hbm_budget_bytes
    tl = timeline or build_timeline(prof)
    mrl = MRL.from_timeline(tl, budget)
    # the engine prices per-class link contention (queued checkpoint /
    # kv-spill drains shrink the early overlap windows) — an idle or
    # absent engine reproduces the paper's idle-link assumption exactly
    sim = Simulator(prof, tl.peak_op, cfg, bwmodel=bwmodel, engine=engine)
    entries: List[PolicyEntry] = []
    chosen: Set[int] = set()

    while not mrl.is_empty():                       # Algo 2 line 2
        cl = build_candidate_list(prof, mrl, cfg, exclude=chosen)
        if not cl:                                  # Algo 2 line 8
            raise ChameleonOOMError(
                f"MRL not clearable: {mrl.max_required()/2**30:.2f} GiB "
                f"over budget with no remaining candidates")
        new = sim.simulate(cl, mrl)
        if not new:
            raise ChameleonOOMError("simulator could not place any candidate")
        for e in new:
            chosen.add(e.uid)
        entries.extend(new)

    sim.set_free_time(entries)                      # Algo 2 line 11 (§5.4.2)

    projected = projected_peak(prof, entries)

    pol = SwapPolicy(entries, projected, tl.peak, budget,
                     sim.stall_time, prof.t_iter, prof.n_ops,
                     contention_s=sim.contention_s,
                     occupancy=sim.occupancy)
    if engine is not None and register_free_times:  # hostmem free-time hand-off
        pol.register_free_times(engine)
    return pol
