"""Stage-adjusting module (paper Algo. 1).

WarmUp --(m stable steps)--> GenPolicy --(n steps)--> Stable; any significant
operator-sequence change (length diff >= 5% OR cosine < 95%) resets to
WarmUp.  During GenPolicy the profiler runs in Detailed mode and a fresh
policy is generated each step; the best-performing of the n policies becomes
the long-term policy (§7.1).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.common.config import ChameleonConfig
from repro.core.tokenizer import similarity


class Stage(enum.Enum):
    WARMUP = "WarmUp"
    GENPOLICY = "GenPolicy"
    STABLE = "Stable"


@dataclass
class StageMachine:
    cfg: ChameleonConfig
    stage: Stage = Stage.WARMUP
    stable_step: int = 0
    prev_seq: Optional[np.ndarray] = None
    transitions: list = field(default_factory=list)

    def observe(self, op_seq: np.ndarray, step: int = -1) -> Stage:
        """Algo 1: feed one iteration's operator sequence."""
        if self.prev_seq is None:
            self.prev_seq = op_seq
            self._log(step, "init", Stage.WARMUP)
            return self.stage

        len_diff, cos = similarity(op_seq, self.prev_seq)
        stable = (len_diff < self.cfg.len_change_threshold
                  and cos > self.cfg.cos_sim_threshold)
        prev_stage = self.stage
        if stable:
            self.stable_step += 1
            if prev_stage is Stage.WARMUP and self.stable_step > self.cfg.m_warmup_stable:
                self.stage, self.stable_step = Stage.GENPOLICY, 0
            elif (prev_stage is Stage.GENPOLICY
                  and self.stable_step > self.cfg.n_genpolicy_steps):
                self.stage = Stage.STABLE
        else:
            self.stage, self.stable_step = Stage.WARMUP, 0
        if self.stage is not prev_stage:
            self._log(step, "stable" if stable else "seq-change", self.stage)
        self.prev_seq = op_seq
        return self.stage

    @property
    def mode(self) -> str:
        """Profiler mode implied by the stage (§4)."""
        return "detailed" if self.stage is Stage.GENPOLICY else "lightweight"

    def _log(self, step, why, to):
        self.transitions.append((step, why, to.value))
