"""Stage-adjusting module (paper Algo. 1).

WarmUp --(m stable steps)--> GenPolicy --(n steps)--> Stable; any significant
operator-sequence change (length diff >= 5% OR cosine < 95%) resets to
WarmUp.  During GenPolicy the profiler runs in Detailed mode and a fresh
policy is generated each step; the best-performing of the n policies becomes
the long-term policy (§7.1).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro import obs
from repro.common.config import ChameleonConfig
from repro.core.tokenizer import Signature, sig_similarity


class Stage(enum.Enum):
    WARMUP = "WarmUp"
    GENPOLICY = "GenPolicy"
    STABLE = "Stable"
    # async placement (repro.adapt): the sequence has settled and the
    # variant search is running on the background worker — profiling
    # stays Lightweight and iterations keep serving the old policy
    ADAPTING = "Adapting"


@dataclass
class StageMachine:
    cfg: ChameleonConfig
    stage: Stage = Stage.WARMUP
    stable_step: int = 0
    prev_seq: Optional[Signature] = None
    transitions: list = field(default_factory=list)
    # per-adaptation override of Algo 1's `n` (None -> cfg value): a
    # policystore warm start shrinks the GenPolicy variant search to the
    # seeded knobs instead of the full five
    n_genpolicy: Optional[int] = None
    # async placement (repro.adapt): a settled WarmUp enters ADAPTING
    # (worker searches in the background) instead of GENPOLICY (inline
    # measured search); complete_adapting() moves on to STABLE when the
    # runtime installs the worker's result at an iteration boundary
    async_mode: bool = False

    def observe(self, op_seq, step: int = -1) -> Stage:
        """Algo 1: feed one iteration's operator sequence — either a raw
        token array or an (incrementally maintained) ``Signature``.  With
        signatures the length-diff + cosine test runs in histogram space:
        O(changed dispatches) steady state, never O(n_ops)."""
        if not isinstance(op_seq, Signature):
            op_seq = Signature.from_tokens(np.asarray(op_seq))
        if self.prev_seq is None:
            self.prev_seq = op_seq
            self._log(step, "init", self.stage)
            return self.stage

        n_gen = (self.n_genpolicy if self.n_genpolicy is not None
                 else self.cfg.n_genpolicy_steps)
        len_diff, cos = sig_similarity(op_seq, self.prev_seq)
        stable = (len_diff < self.cfg.len_change_threshold
                  and cos > self.cfg.cos_sim_threshold)
        prev_stage = self.stage
        if stable:
            self.stable_step += 1
            if prev_stage is Stage.WARMUP and self.stable_step > self.cfg.m_warmup_stable:
                # async: hold in ADAPTING (Lightweight profiling, old
                # policy serving) until the worker's result installs
                self.stage = (Stage.ADAPTING if self.async_mode
                              else Stage.GENPOLICY)
                self.stable_step = 0
            elif (prev_stage is Stage.GENPOLICY
                  and self.stable_step > n_gen):
                self.stage = Stage.STABLE
        else:
            self.stage, self.stable_step = Stage.WARMUP, 0
        if self.stage is not prev_stage:
            self._log(step, "stable" if stable else "seq-change", self.stage)
        self.prev_seq = op_seq
        return self.stage

    def to_warmup(self, step: int = -1, why: str = "shape-change") -> Stage:
        """Out-of-band reset: the runtime saw drift the token stream
        cannot express (e.g. a dispatch-shape change — same primitives,
        different memory profile) and restarts adaptation."""
        prev = self.stage
        self.stage, self.stable_step = Stage.WARMUP, 0
        if prev is not Stage.WARMUP:
            self._log(step, why, self.stage)
        return self.stage

    def force_stable(self, step: int = -1, why: str = "forced") -> Stage:
        """Jump straight to Stable: the policystore's reuse tier applied a
        cached policy, so neither the WarmUp wait nor GenPolicy is needed
        for this adaptation."""
        prev = self.stage
        self.stage, self.stable_step = Stage.STABLE, 0
        if prev is not Stage.STABLE:
            self._log(step, why, self.stage)
        return self.stage

    def complete_adapting(self, step: int = -1,
                          why: str = "adapt-installed") -> Stage:
        """Async adaptation finished: the runtime installed the worker's
        (or a parked speculative) result at an iteration boundary."""
        prev = self.stage
        self.stage, self.stable_step = Stage.STABLE, 0
        if prev is not Stage.STABLE:
            self._log(step, why, self.stage)
        return self.stage

    @property
    def mode(self) -> str:
        """Profiler mode implied by the stage (§4).  ADAPTING stays
        Lightweight — Detailed replays run on the worker, off-thread."""
        return "detailed" if self.stage is Stage.GENPOLICY else "lightweight"

    def _log(self, step, why, to):
        self.transitions.append((step, why, to.value))
        # audit + trace: every stage move is an inspectable event and a
        # marker on the adapt lane (name set is bounded: one per stage)
        obs.audit().event("stage.transition", step=step, why=why,
                          to=to.value)
        obs.tracer().instant(obs.LANE_ADAPT, f"stage:{to.value}",
                             arg=(step, why))
