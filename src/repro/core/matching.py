"""Multi-feature fuzzy matching (paper §6.1 + Appendix A).

Across retraces there are no stable tensor identities; policy entries are
re-associated with the new program's site instances using integer-only
feature comparison (the paper's trick: one-hot operator tags + bit-packed
call stacks instead of string compares).

Features per instance, packed into a single int64:
  bits  0..31  site one-hot   (site vocabulary maps to 32 bits, like the
                               paper's "32 most frequent operators")
  bits 32..39  dtype code
  bits 40..55  shape hash     (16-bit product/dim mix)
  bits 56..63  position bucket (birth op / n_ops quantized to 256)

Exact match requires identical site bit + dtype + shape hash; position may
drift by up to ``pos_tolerance`` buckets (minor sequence changes shift op
indices slightly — the tolerance is what lets Chameleon ride out small
changes without regenerating the policy).

Hot path: :func:`match_instances` is array-native.  All candidate features
are packed into int64 numpy arrays **once per profile** (lazily, cached on
the profile object), new candidates are sorted/grouped by their exact-mask
key, and the position-tolerance assignment resolves per bucket with array
ops — no per-pair ``pack_features`` calls.  The original per-instance
Python loop survives as :func:`match_instances_reference`; property tests
(tests/test_monitor_hotpath.py) prove the two produce identical results.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.profiler import ProfileData, TensorInstance
from repro.core.sites import SITE_INDEX


def _site_bit(site: Optional[str]) -> int:
    if site is None:
        return 0
    return 1 << (SITE_INDEX.get(site, hash(site) & 31) % 32)


def _shape_hash(shape: Tuple[int, ...]) -> int:
    h = 0
    for d in shape:
        h = (h * 131 + d) & 0xFFFF
    return h


def pack_features(t: TensorInstance, n_ops: int) -> int:
    pos = min(int(t.birth * 256 / max(n_ops, 1)), 255)
    return (_site_bit(t.site)
            | (t.dtype_code & 0xFF) << 32
            | _shape_hash(t.shape) << 40
            | pos << 56)


_EXACT_MASK = (1 << 56) - 1          # site | dtype | shape
_POS_SHIFT = 56
_NO_MATCH = np.int64(1) << 40       # larger than any reachable distance


@dataclass
class CandidateFeatures:
    """Candidate features of one profile as flat int64 arrays (one row per
    candidate, in ``prof.candidates`` order)."""
    uids: np.ndarray                 # int64
    key: np.ndarray                  # int64, exact-mask features (bits 0..55)
    pos: np.ndarray                  # int64, position bucket 0..255
    layer: np.ndarray                # int64
    birth: np.ndarray                # int64

    @property
    def n(self) -> int:
        return int(self.uids.size)


def candidate_feature_arrays(prof) -> CandidateFeatures:
    """Feature arrays for ``prof.candidates``, computed once and cached on
    the profile object (works for :class:`ProfileData` and the store's
    profile stubs alike).  The base key per unique (site, dtype, shape) is
    memoized, so repeated shapes across layers — the common case — cost one
    dict hit each; position buckets come from one vectorized expression.
    The cache assumes candidates are not mutated afterwards."""
    cached = getattr(prof, "_cand_feat_cache", None)
    if cached is not None:
        return cached
    cands = prof.candidates
    n = len(cands)
    n_ops = max(int(prof.n_ops), 1)
    uids = np.fromiter((t.uid for t in cands), np.int64, n)
    births = np.fromiter((t.birth for t in cands), np.int64, n)
    layers = np.fromiter((t.layer for t in cands), np.int64, n)
    base = np.empty(n, np.int64)
    memo: Dict[Tuple, int] = {}
    for i, t in enumerate(cands):
        mk = (t.site, t.dtype_code, t.shape)
        b = memo.get(mk)
        if b is None:
            b = (_site_bit(t.site)
                 | (t.dtype_code & 0xFF) << 32
                 | _shape_hash(t.shape) << 40)
            memo[mk] = b
        base[i] = b
    pos = np.minimum(births * 256 // n_ops, 255)
    feats = CandidateFeatures(uids, base, pos, layers, births)
    try:
        prof._cand_feat_cache = feats
    except AttributeError:
        pass                          # slotted stub: just skip caching
    return feats


@dataclass
class MatchResult:
    mapping: Dict[int, int]          # old uid -> new uid
    unmatched: List[int]             # old uids with no counterpart
    moved: int                       # matched but position drifted


def match_instances(old: ProfileData, new: ProfileData,
                    pos_tolerance: int = 16) -> MatchResult:
    """Associate old candidate instances with new ones (integer compares
    only; layer index breaks ties among identical features).

    Array-native: new candidates are lex-sorted by (key, layer, birth) so
    each old candidate resolves against one contiguous bucket with a single
    vectorized distance/argmin, exactly reproducing the reference greedy
    assignment (first minimum in (layer, birth) order wins)."""
    of = candidate_feature_arrays(old)
    nf = candidate_feature_arrays(new)
    if of.n == 0:
        return MatchResult({}, [], 0)
    if nf.n == 0:
        return MatchResult({}, [int(u) for u in of.uids], 0)

    order = np.lexsort((nf.birth, nf.layer, nf.key))
    skey = nf.key[order]
    spos = nf.pos[order]
    slayer = nf.layer[order]
    suid = nf.uids[order]

    # group old candidates by key too (stable: preserves candidate order
    # within a bucket, which is what the greedy tie-break depends on; the
    # buckets themselves are independent, so bucket order is free)
    oorder = np.argsort(of.key, kind="stable")
    okey = of.key[oorder]
    runs = np.flatnonzero(np.diff(okey)) + 1
    ostarts = np.concatenate([[0], runs, [of.n]])

    lo = np.searchsorted(skey, okey[ostarts[:-1]], side="left")
    hi = np.searchsorted(skey, okey[ostarts[:-1]], side="right")

    mapping: Dict[int, int] = {}
    unmatched: List[Tuple[int, int]] = []       # (orig old index, uid)
    moved = 0
    for bi in range(ostarts.size - 1):
        o_idx = oorder[ostarts[bi]:ostarts[bi + 1]]
        l, h = int(lo[bi]), int(hi[bi])
        if l == h:
            unmatched.extend((int(i), int(of.uids[i])) for i in o_idx)
            continue
        # (o, b) distance matrix for the whole bucket, one vectorized op
        d = (np.abs(spos[l:h][None, :] - of.pos[o_idx][:, None])
             + (slayer[l:h][None, :] != of.layer[o_idx][:, None]))
        for r, i in enumerate(o_idx):
            j = int(np.argmin(d[r]))
            dj = int(d[r, j])
            if dj > pos_tolerance:
                unmatched.append((int(i), int(of.uids[i])))
                continue
            d[:, j] = _NO_MATCH                 # column consumed
            mapping[int(of.uids[i])] = int(suid[l + j])
            if dj:
                moved += 1
    unmatched.sort()                            # reference order: old order
    return MatchResult(mapping, [u for _, u in unmatched], moved)


def match_instances_reference(old: ProfileData, new: ProfileData,
                              pos_tolerance: int = 16) -> MatchResult:
    """Original per-instance Python implementation, kept as the parity
    oracle for the vectorized :func:`match_instances`."""
    new_feats: Dict[int, List[TensorInstance]] = {}
    for t in new.candidates:
        key = pack_features(t, new.n_ops) & _EXACT_MASK
        new_feats.setdefault(key, []).append(t)
    for lst in new_feats.values():
        lst.sort(key=lambda t: (t.layer, t.birth))

    mapping: Dict[int, int] = {}
    unmatched: List[int] = []
    moved = 0
    used: set = set()
    for t in old.candidates:
        f = pack_features(t, old.n_ops)
        key = f & _EXACT_MASK
        pos = f >> _POS_SHIFT
        best = None
        best_d = None
        for c in new_feats.get(key, ()):  # integer comparisons only
            if c.uid in used:
                continue
            cpos = pack_features(c, new.n_ops) >> _POS_SHIFT
            d = abs(int(cpos) - int(pos)) + (0 if c.layer == t.layer else 1)
            if d <= pos_tolerance and (best_d is None or d < best_d):
                best, best_d = c, d
        if best is None:
            unmatched.append(t.uid)
        else:
            used.add(best.uid)
            mapping[t.uid] = best.uid
            if best_d:
                moved += 1
    return MatchResult(mapping, unmatched, moved)


def remap_policy(policy, old: ProfileData, new: ProfileData,
                 pos_tolerance: int = 16):
    """Carry a SwapPolicy across a *minor* sequence change by re-pointing
    its entries at the matched new instances.  Returns (entries, hit_rate);
    the caller regenerates the policy when hit_rate is low (the stage
    machine will already be back in WarmUp for major changes)."""
    res = match_instances(old, new, pos_tolerance)
    by_uid = {t.uid: t for t in new.candidates}
    remapped = []
    for e in policy.entries:
        nid = res.mapping.get(e.uid)
        if nid is None:
            continue
        t = by_uid[nid]
        ne = type(e)(t.uid, t.site, t.layer, t.nbytes, t.birth, t.death,
                     e.swap_in_op, e.swap_out_done_op, e.stalled, e.score)
        remapped.append(ne)
    hit = len(remapped) / max(len(policy.entries), 1)
    return remapped, hit
