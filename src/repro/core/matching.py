"""Multi-feature fuzzy matching (paper §6.1 + Appendix A).

Across retraces there are no stable tensor identities; policy entries are
re-associated with the new program's site instances using integer-only
feature comparison (the paper's trick: one-hot operator tags + bit-packed
call stacks instead of string compares).

Features per instance, packed into a single int64:
  bits  0..31  site one-hot   (site vocabulary maps to 32 bits, like the
                               paper's "32 most frequent operators")
  bits 32..39  dtype code
  bits 40..55  shape hash     (16-bit product/dim mix)
  bits 56..63  position bucket (birth op / n_ops quantized to 256)

Exact match requires identical site bit + dtype + shape hash; position may
drift by up to ``pos_tolerance`` buckets (minor sequence changes shift op
indices slightly — the tolerance is what lets Chameleon ride out small
changes without regenerating the policy).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiler import ProfileData, TensorInstance
from repro.core.sites import SITE_INDEX


def _site_bit(site: Optional[str]) -> int:
    if site is None:
        return 0
    return 1 << (SITE_INDEX.get(site, hash(site) & 31) % 32)


def _shape_hash(shape: Tuple[int, ...]) -> int:
    h = 0
    for d in shape:
        h = (h * 131 + d) & 0xFFFF
    return h


def pack_features(t: TensorInstance, n_ops: int) -> int:
    pos = min(int(t.birth * 256 / max(n_ops, 1)), 255)
    return (_site_bit(t.site)
            | (t.dtype_code & 0xFF) << 32
            | _shape_hash(t.shape) << 40
            | pos << 56)


_EXACT_MASK = (1 << 56) - 1          # site | dtype | shape
_POS_SHIFT = 56


@dataclass
class MatchResult:
    mapping: Dict[int, int]          # old uid -> new uid
    unmatched: List[int]             # old uids with no counterpart
    moved: int                       # matched but position drifted


def match_instances(old: ProfileData, new: ProfileData,
                    pos_tolerance: int = 16) -> MatchResult:
    """Associate old candidate instances with new ones (integer compares
    only; layer index breaks ties among identical features)."""
    new_feats: Dict[int, List[TensorInstance]] = {}
    for t in new.candidates:
        key = pack_features(t, new.n_ops) & _EXACT_MASK
        new_feats.setdefault(key, []).append(t)
    for lst in new_feats.values():
        lst.sort(key=lambda t: (t.layer, t.birth))

    mapping: Dict[int, int] = {}
    unmatched: List[int] = []
    moved = 0
    used: set = set()
    for t in old.candidates:
        f = pack_features(t, old.n_ops)
        key = f & _EXACT_MASK
        pos = f >> _POS_SHIFT
        best = None
        best_d = None
        for c in new_feats.get(key, ()):  # integer comparisons only
            if c.uid in used:
                continue
            cpos = pack_features(c, new.n_ops) >> _POS_SHIFT
            d = abs(int(cpos) - int(pos)) + (0 if c.layer == t.layer else 1)
            if d <= pos_tolerance and (best_d is None or d < best_d):
                best, best_d = c, d
        if best is None:
            unmatched.append(t.uid)
        else:
            used.add(best.uid)
            mapping[t.uid] = best.uid
            if best_d:
                moved += 1
    return MatchResult(mapping, unmatched, moved)


def remap_policy(policy, old: ProfileData, new: ProfileData,
                 pos_tolerance: int = 16):
    """Carry a SwapPolicy across a *minor* sequence change by re-pointing
    its entries at the matched new instances.  Returns (entries, hit_rate);
    the caller regenerates the policy when hit_rate is low (the stage
    machine will already be back in WarmUp for major changes)."""
    res = match_instances(old, new, pos_tolerance)
    by_uid = {t.uid: t for t in new.candidates}
    remapped = []
    for e in policy.entries:
        nid = res.mapping.get(e.uid)
        if nid is None:
            continue
        t = by_uid[nid]
        ne = type(e)(t.uid, t.site, t.layer, t.nbytes, t.birth, t.death,
                     e.swap_in_op, e.swap_out_done_op, e.stalled, e.score)
        remapped.append(ne)
    hit = len(remapped) / max(len(policy.entries), 1)
    return remapped, hit
