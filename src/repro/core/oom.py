"""WarmUp-stage OOM handling (paper §6.3 + Appendix B, Algo 3).

PyTorch Chameleon handles OOM *reactively* mid-iteration (free in-flight swap
blocks -> stream-event sync -> GMLake defragment -> passive swap -> retry).
XLA's static buffer assignment removes fragmentation and lets us run the same
loop *proactively at trace time*: project the peak from the reconstructed
timeline, and while it exceeds the budget, passively swap the candidate whose
size is closest to the outstanding deficit (Algo 3 line 9's closest-size
rule), then re-project.  The result is the conservative WarmUp policy under
which the first iterations are guaranteed to fit — profiling data stays
intact, training never crashes (the paper's goal).
"""
from __future__ import annotations

from typing import List, Optional, Set, Tuple

import numpy as np

from repro.common.config import ChameleonConfig
from repro.core.memtrace import build_timeline
from repro.core.policy import ChameleonOOMError
from repro.core.profiler import ProfileData, TensorInstance


def _projected_peak(prof: ProfileData, absent: Set[int]) -> int:
    n = prof.n_ops
    delta = np.zeros(n + 2, np.int64)
    for t in prof.tensors:
        if t.uid in absent:
            continue  # passively swapped: off-device for its idle span
        b = min(max(t.birth, 0), n)
        d = min(max(t.death, b), n + 1)
        delta[b] += t.nbytes
        delta[d] -= t.nbytes
    return int(np.cumsum(delta)[: n + 1].max(initial=0)) + prof.static_bytes


def passive_swap_fit(prof: ProfileData, cfg: ChameleonConfig,
                     budget: Optional[int] = None
                     ) -> Tuple[Set[int], int, List[TensorInstance]]:
    """Algo 3 loop at trace granularity.

    Returns (uids passively swapped, projected peak, swap order)."""
    budget = budget if budget is not None else cfg.hbm_budget_bytes
    candidates = sorted(prof.candidates, key=lambda t: -t.nbytes)
    absent: Set[int] = set()
    order: List[TensorInstance] = []
    peak = _projected_peak(prof, absent)
    while peak > budget:
        deficit = peak - budget
        pool = [t for t in candidates if t.uid not in absent]
        if not pool:
            raise ChameleonOOMError(
                f"passive swap exhausted: still {deficit/2**30:.2f} GiB over")
        # closest-size-to-required-block rule (Algo 3 PassiveSwap)
        pick = min(pool, key=lambda t: (abs(t.nbytes - deficit), t.uid))
        absent.add(pick.uid)
        order.append(pick)
        peak = _projected_peak(prof, absent)
    return absent, peak, order


def warmup_offload_sites(prof: ProfileData, cfg: ChameleonConfig,
                         budget: Optional[int] = None) -> Set[str]:
    """Site-level view of the passive-swap selection (scan-mode apply)."""
    absent, _, order = passive_swap_fit(prof, cfg, budget)
    return {t.site for t in order if t.site}
