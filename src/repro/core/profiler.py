"""Lightweight online profiler — Detailed mode (§4).

Walks the traced step's jaxpr (scans virtually unrolled so op indices match
the physical device op stream) and produces:

  * the operator stream (for logical-layer grouping, Eq 1),
  * tensor instances with liveness (birth/death op indices) — including the
    per-slice sawtooth liveness of scan residuals, which is what makes the
    reconstructed no-swap memory curve look like the paper's Fig 3,
  * the candidate site instances (``checkpoint_name``-tagged residuals),
  * one measured iteration time ``T_iter`` (a single wall-clock number — the
    paper's key constraint: **no per-operator timings are ever collected**).

Static memory (params, optimizer state = jit invars) is excluded from the
dynamic timeline: the paper builds on DeepSpeed/ZeRO for static memory and
swaps *dynamic* memory; we mirror that split (ZeRO sharding lives in
``repro.optim``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.sites import base_site
from repro.core.tokenizer import GLOBAL_VOCAB, OpVocab, _sub_jaxprs, _unwrap

MIN_TRACK_BYTES = 1 << 10

_DTYPE_CODES: Dict[str, int] = {}


def dtype_code(dt) -> int:
    s = str(dt)
    if s not in _DTYPE_CODES:
        _DTYPE_CODES[s] = len(_DTYPE_CODES) + 1
    return _DTYPE_CODES[s]


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


@dataclass
class TensorInstance:
    uid: int
    nbytes: int
    birth: int                 # expanded op index where allocated
    death: int                 # expanded op index of last use
    site: Optional[str] = None  # canonical site name (tagged residuals)
    layer: int = -1             # scan slice index (-1 = whole tensor)
    dtype_code: int = 0
    shape: Tuple[int, ...] = ()
    producer_token: int = 0

    @property
    def is_candidate(self) -> bool:
        return self.site is not None


@dataclass
class ProfileData:
    op_tokens: np.ndarray               # expanded op stream
    tensors: List[TensorInstance]
    t_iter: float                       # measured iteration wall time (s)
    static_bytes: int                   # params/opt-state resident bytes
    n_ops: int = 0
    scan_layers: int = 0                # main stack length (0 = unrolled)

    def __post_init__(self):
        self.n_ops = int(len(self.op_tokens))

    def __setattr__(self, name, value):
        # replacing the tensor list (e.g. dryrun's shallow-copied per-chip
        # rescale) must drop the derived candidate/feature caches
        if name == "tensors":
            self.__dict__.pop("_candidates", None)
            self.__dict__.pop("_cand_feat_cache", None)
        object.__setattr__(self, name, value)

    @property
    def candidates(self) -> List[TensorInstance]:
        cached = self.__dict__.get("_candidates")
        if cached is None:
            cached = [t for t in self.tensors if t.is_candidate]
            self.__dict__["_candidates"] = cached
        return cached

    def feature_arrays(self):
        """Packed int64 candidate-feature arrays (see ``core.matching``),
        computed lazily and cached — the §6.1 matching hot path reads these
        instead of re-packing features per comparison."""
        from repro.core.matching import candidate_feature_arrays
        return candidate_feature_arrays(self)


# --------------------------------------------------------------------------
def _count_ops(jaxpr, cache) -> int:
    j = _unwrap(jaxpr)
    key = id(j)
    if key in cache:
        return cache[key]
    total = 0
    for eqn in j.eqns:
        if eqn.primitive.name == "scan":
            total += eqn.params.get("length", 1) * _count_ops(
                eqn.params["jaxpr"], cache)
            continue
        subs = _sub_jaxprs(eqn)
        if subs:
            total += sum(_count_ops(s, cache) for s in subs)
        else:
            total += 1
    cache[key] = total
    return total


def _emit_tokens(jaxpr, vocab, out, cache):
    j = _unwrap(jaxpr)
    for eqn in j.eqns:
        if eqn.primitive.name == "scan":
            L = eqn.params.get("length", 1)
            body = eqn.params["jaxpr"]
            one = []
            _emit_tokens(body, vocab, one, cache)
            out.extend(one * L)
            continue
        subs = _sub_jaxprs(eqn)
        if subs:
            for s in subs:
                _emit_tokens(s, vocab, out, cache)
            continue
        out.append(vocab.id(eqn.primitive.name))


def _find_site_outputs(scan_eqn) -> Dict[int, Tuple[str, Tuple[int, ...], int]]:
    """Map stacked-output position -> (site, slice shape, dtype code) for
    ``name``-tagged residuals of a scan (searching nested scans one level)."""
    body = _unwrap(scan_eqn.params["jaxpr"])
    num_carry = scan_eqn.params.get("num_carry", 0)
    ys_vars = list(body.outvars[num_carry:])
    named: Dict[int, Tuple[str, Tuple[int, ...], int]] = {}

    # direct var-identity match first, then unique aval match
    names = []
    def collect(j):
        for eqn in j.eqns:
            if eqn.primitive.name == "name":
                names.append((eqn.params["name"], eqn.outvars[0]))
            elif eqn.primitive.name == "scan":
                collect(_unwrap(eqn.params["jaxpr"]))
            else:
                for s in _sub_jaxprs(eqn):
                    collect(_unwrap(s))
    collect(body)

    taken = set()
    # pass 1: identity matches
    pending = []
    for nm, var in names:
        site = base_site(nm)
        hit = False
        for pos, yv in enumerate(ys_vars):
            if pos not in taken and yv is var:
                named[pos] = (site, tuple(var.aval.shape),
                              dtype_code(var.aval.dtype))
                taken.add(pos)
                hit = True
                break
        if not hit:
            pending.append((site, var))
    # pass 2: in-order greedy aval match (names and ys both follow body
    # equation order, so sequential assignment resolves same-shape ties —
    # e.g. gate/up both tagged ffn_pre, or the resid_* family)
    cursor = 0
    for site, var in pending:
        vshape, vdt = tuple(var.aval.shape), var.aval.dtype
        for pos in list(range(cursor, len(ys_vars))) + list(range(0, cursor)):
            if pos in taken:
                continue
            yv = ys_vars[pos]
            yshape = tuple(yv.aval.shape)
            if yv.aval.dtype == vdt and (
                    yshape == vshape
                    or (len(yshape) > len(vshape)
                        and yshape[-len(vshape):] == vshape)):
                named[pos] = (site, yshape[1:], dtype_code(yv.aval.dtype))
                taken.add(pos)
                cursor = pos + 1
                break
    return named


def profile_jaxpr(closed_jaxpr, t_iter: float,
                  vocab: OpVocab = GLOBAL_VOCAB,
                  min_track_bytes: int = MIN_TRACK_BYTES) -> ProfileData:
    """Detailed-mode walk of the (baseline, policy-free) train-step jaxpr."""
    j = _unwrap(closed_jaxpr)
    cache: Dict[int, int] = {}

    # ---- pass A: expanded op stream + per-top-level-eqn spans
    tokens: List[int] = []
    spans = []  # (eqn, start, end, iter_spans|None)
    cursor = 0
    for eqn in j.eqns:
        start = cursor
        if eqn.primitive.name == "scan":
            L = eqn.params.get("length", 1)
            per = _count_ops(eqn.params["jaxpr"], cache)
            one: List[int] = []
            _emit_tokens(eqn.params["jaxpr"], vocab, one, cache)
            tokens.extend(one * L)
            cursor += per * L
            iter_spans = [(start + i * per, start + (i + 1) * per)
                          for i in range(L)]
            spans.append((eqn, start, cursor, iter_spans))
        else:
            subs = _sub_jaxprs(eqn)
            if subs:
                sub_out: List[int] = []
                for s in subs:
                    _emit_tokens(s, vocab, sub_out, cache)
                if not sub_out:
                    sub_out = [vocab.id(eqn.primitive.name)]
                tokens.extend(sub_out)
                cursor += len(sub_out)
            else:
                tokens.append(vocab.id(eqn.primitive.name))
                cursor += 1
            spans.append((eqn, start, cursor, None))
    n_ops = cursor

    # ---- pass B: top-level liveness
    producer: Dict[object, int] = {}           # var -> spans index
    consumers: Dict[object, List[int]] = {}
    for si, (eqn, *_rest) in enumerate(spans):
        for v in eqn.invars:
            if hasattr(v, "aval") and not hasattr(v, "val"):  # skip Literals
                consumers.setdefault(v, []).append(si)
        for v in eqn.outvars:
            producer[v] = si

    static_bytes = sum(_nbytes(v.aval) for v in j.invars)

    tensors: List[TensorInstance] = []
    uid = 0
    scan_layers = 0
    for v, psi in producer.items():
        nb = _nbytes(v.aval)
        if nb < min_track_bytes:
            continue
        eqn, pstart, pend, piters = spans[psi]
        cons = consumers.get(v, [])
        if not cons:  # jaxpr output: lives to the end
            death = n_ops
            last_ci = None
        else:
            last_ci = max(cons)
            death = spans[last_ci][1]  # start of last consuming eqn

        # scan residual with per-slice sawtooth liveness?
        sliced = False
        if piters is not None and len(v.aval.shape) >= 1:
            L = len(piters)
            if v.aval.shape[0] == L and L > 1:
                site_map = _find_site_outputs(eqn)
                num_carry = eqn.params.get("num_carry", 0)
                try:
                    pos = list(eqn.outvars).index(v) - num_carry
                except ValueError:
                    pos = -1
                site = None
                if pos >= 0 and pos in site_map:
                    site = site_map[pos][0]
                # death side: reverse scan consumes slice i at iter L-1-i
                cons_iters = None
                if last_ci is not None:
                    ceqn, cstart, cend, citers = spans[last_ci]
                    if citers is not None and len(citers) == L:
                        cons_iters = citers
                        rev = bool(ceqn.params.get("reverse", False))
                per_slice = nb // L
                if per_slice >= min_track_bytes:
                    scan_layers = max(scan_layers, L)
                    for i in range(L):
                        if cons_iters is not None:
                            d = cons_iters[L - 1 - i][0] if rev else cons_iters[i][0]
                        else:
                            d = death
                        tensors.append(TensorInstance(
                            uid, per_slice, piters[i][1], d, site=site,
                            layer=i,
                            dtype_code=dtype_code(v.aval.dtype),
                            shape=tuple(v.aval.shape[1:]),
                            producer_token=vocab.id("scan")))
                        uid += 1
                    sliced = True
        if not sliced:
            site = None
            if eqn.primitive.name == "name":
                site = base_site(eqn.params["name"])
            tensors.append(TensorInstance(
                uid, nb, pend, death, site=site,
                dtype_code=dtype_code(v.aval.dtype),
                shape=tuple(v.aval.shape),
                producer_token=vocab.id(eqn.primitive.name)))
            uid += 1

    return ProfileData(np.asarray(tokens, np.int32), tensors, t_iter,
                       static_bytes, scan_layers=scan_layers)
