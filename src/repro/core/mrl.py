"""Memory Reduction List (paper §5.2).

One entry per operator inside an over-budget region:
``op index -> bytes that must be absent from device memory at that op``.
Kept as parallel numpy arrays; the simulator decrements ranges as swaps are
scheduled (§5.4.1) and the policy loop (Algo 2) runs until the list clears.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.memtrace import MemoryTimeline, over_budget_ops


@dataclass
class MRL:
    ops: np.ndarray        # sorted op indices with an MRE
    required: np.ndarray   # remaining required reduction per op (bytes)

    @classmethod
    def from_timeline(cls, tl: MemoryTimeline, budget: int) -> "MRL":
        ops, req = over_budget_ops(tl, budget)
        return cls(ops, req.astype(np.int64))

    def is_empty(self) -> bool:
        return bool(np.all(self.required <= 0))

    @property
    def remaining_ops(self) -> np.ndarray:
        return self.ops[self.required > 0]

    # ops is sorted, so the [birth, death) window is one searchsorted
    # slice instead of two O(n) boolean masks — covered_count/decrement
    # run per candidate inside Algo 2's inner loop, making this the last
    # per-candidate O(n_mre) cost in Simulator.simulate
    def _window(self, birth: int, death: int) -> slice:
        lo = int(np.searchsorted(self.ops, birth, side="left"))
        hi = int(np.searchsorted(self.ops, death, side="left"))
        return slice(lo, max(hi, lo))

    def covered_count(self, birth: int, death: int) -> int:
        """Number of outstanding MREs inside [birth, death)."""
        w = self._window(birth, death)
        return int(np.count_nonzero(self.required[w] > 0))

    def decrement(self, birth: int, death: int, nbytes: int) -> None:
        """Tensor of `nbytes` leaves the device for ops in [birth, death)."""
        self.required[self._window(birth, death)] -= nbytes

    def max_required(self) -> int:
        return int(self.required.max(initial=0))
