"""Memory Reduction List (paper §5.2).

One entry per operator inside an over-budget region:
``op index -> bytes that must be absent from device memory at that op``.
Kept as parallel numpy arrays; the simulator decrements ranges as swaps are
scheduled (§5.4.1) and the policy loop (Algo 2) runs until the list clears.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.memtrace import MemoryTimeline, over_budget_ops


@dataclass
class MRL:
    ops: np.ndarray        # sorted op indices with an MRE
    required: np.ndarray   # remaining required reduction per op (bytes)

    @classmethod
    def from_timeline(cls, tl: MemoryTimeline, budget: int) -> "MRL":
        ops, req = over_budget_ops(tl, budget)
        return cls(ops, req.astype(np.int64))

    def is_empty(self) -> bool:
        return bool(np.all(self.required <= 0))

    @property
    def remaining_ops(self) -> np.ndarray:
        return self.ops[self.required > 0]

    def covered_count(self, birth: int, death: int) -> int:
        """Number of outstanding MREs inside [birth, death)."""
        m = (self.ops >= birth) & (self.ops < death) & (self.required > 0)
        return int(np.count_nonzero(m))

    def decrement(self, birth: int, death: int, nbytes: int) -> None:
        """Tensor of `nbytes` leaves the device for ops in [birth, death)."""
        m = (self.ops >= birth) & (self.ops < death)
        self.required[m] -= nbytes

    def max_required(self) -> int:
        return int(self.required.max(initial=0))
