"""Operator-sequence tokenization (§4, Lightweight mode).

The paper assigns an integer to each operator name and represents the
iteration's operator sequence as an integer tensor; change detection then
reduces to a length check plus a cosine similarity — no strings at runtime.

Here an "operator" is a jaxpr equation (with scans virtually unrolled so the
token stream matches the physical device op stream), and the per-iteration
sequence is the concatenation of every jitted function the training loop
dispatched that iteration (fwd/bwd, optimizer, optional eval, ...) — the JAX
analogue of the eager dispatch stream.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import numpy as np

# primitives whose sub-jaxpr we expand inline
_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


class OpVocab:
    """Operator-name -> integer token (grown on demand)."""

    def __init__(self):
        self._ids: Dict[str, int] = {}

    def id(self, name: str) -> int:
        tok = self._ids.get(name)
        if tok is None:
            tok = len(self._ids) + 1  # 0 reserved
            self._ids[name] = tok
        return tok

    def __len__(self):
        return len(self._ids)


GLOBAL_VOCAB = OpVocab()


def _sub_jaxprs(eqn):
    out = []
    for k in _SUBJAXPR_PARAMS:
        if k in eqn.params:
            v = eqn.params[k]
            if v is not None:
                out.append(v)
    if "branches" in eqn.params:          # cond: take first branch (documented)
        out.append(eqn.params["branches"][0])
    if "cond_jaxpr" in eqn.params:        # while
        out.append(eqn.params["body_jaxpr"])
    return out


def _unwrap(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def tokenize_jaxpr(jaxpr, vocab: OpVocab = GLOBAL_VOCAB,
                   max_ops: int = 2_000_000) -> np.ndarray:
    """Flatten a (closed) jaxpr into an int32 token stream, unrolling scans."""
    toks: List[int] = []

    def walk(j, mult: int):
        j = _unwrap(j)
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name == "scan":
                length = eqn.params.get("length", 1)
                body = eqn.params["jaxpr"]
                walk(body, mult * length)
                continue
            subs = _sub_jaxprs(eqn)
            if subs:
                for s in subs:
                    walk(s, mult)
                continue
            tok = vocab.id(name)
            toks.extend([tok] * mult if mult <= 64 else [tok] * 64)
            if len(toks) > max_ops:
                raise RuntimeError("op stream too long")

    walk(jaxpr, 1)
    return np.asarray(toks, np.int32)


def sequence_signature(token_streams: Iterable[np.ndarray]) -> np.ndarray:
    """Concatenate the per-dispatch token streams of one iteration."""
    streams = [s for s in token_streams if s.size]
    if not streams:
        return np.zeros((0,), np.int32)
    return np.concatenate(streams)


def similarity(a: np.ndarray, b: np.ndarray) -> Tuple[float, float]:
    """(relative length difference, cosine similarity).

    Cosine is computed on the operator-count histogram, which is the
    length-robust form of the paper's tensor cosine (identical when
    lengths match and ops only reorder/extend)."""
    la, lb = len(a), len(b)
    if la == 0 and lb == 0:
        return 0.0, 1.0
    if la == 0 or lb == 0:
        return 1.0, 0.0
    len_diff = abs(la - lb) / max(la, lb)
    n = int(max(a.max(initial=0), b.max(initial=0))) + 1
    ha = np.bincount(a, minlength=n).astype(np.float64)
    hb = np.bincount(b, minlength=n).astype(np.float64)
    denom = np.linalg.norm(ha) * np.linalg.norm(hb)
    cos = float(ha @ hb / denom) if denom else 0.0
    return len_diff, cos
