"""Operator-sequence tokenization (§4, Lightweight mode).

The paper assigns an integer to each operator name and represents the
iteration's operator sequence as an integer tensor; change detection then
reduces to a length check plus a cosine similarity — no strings at runtime.

Here an "operator" is a jaxpr equation (with scans virtually unrolled so the
token stream matches the physical device op stream), and the per-iteration
sequence is the concatenation of every jitted function the training loop
dispatched that iteration (fwd/bwd, optimizer, optional eval, ...) — the JAX
analogue of the eager dispatch stream.

Steady-state cost model (the Table-1 "always on" constraint): the
per-iteration signature is **not** rebuilt from scratch.  Each dispatch's
stream is tokenized once into a :class:`TokenStream` carrying its operator
histogram and a content hash; a :class:`SignatureAccumulator` keeps the
iteration histogram + length *incrementally*, touching only the dispatch
slots whose content hash changed.  An unchanged iteration therefore costs a
handful of hash compares — O(changed dispatches), not O(n_ops).

Scan bodies repeat the same tokens ``length`` times; materializing more
than :data:`REPEAT_CAP` copies per equation buys no information, so the
materialized stream is capped while ``virtual_len`` and the histogram keep
the true run-length-aware multiplicities.  Length-diff detection (a
deep-scan layer-count change, say 80 -> 96 layers) stays exact even though
both variants materialize identically.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

# primitives whose sub-jaxpr we expand inline
_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")

# max materialized copies of a scan-replicated token per equation; virtual
# length and histograms always use the true multiplicity
REPEAT_CAP = 64

# degenerate-token-id guard: histogram buffers never grow past this many
# bins — ids above (corrupt streams, foreign vocabularies) collapse into
# the last bin instead of sizing a multi-GiB bincount buffer
MAX_DENSE_TOKEN = 1 << 20


class OpVocab:
    """Operator-name -> integer token (grown on demand)."""

    def __init__(self):
        self._ids: Dict[str, int] = {}

    def id(self, name: str) -> int:
        tok = self._ids.get(name)
        if tok is None:
            tok = len(self._ids) + 1  # 0 reserved
            self._ids[name] = tok
        return tok

    def __len__(self):
        return len(self._ids)


GLOBAL_VOCAB = OpVocab()


def _sub_jaxprs(eqn):
    out = []
    for k in _SUBJAXPR_PARAMS:
        if k in eqn.params:
            v = eqn.params[k]
            if v is not None:
                out.append(v)
    if "branches" in eqn.params:          # cond: take first branch (documented)
        out.append(eqn.params["branches"][0])
    if "cond_jaxpr" in eqn.params:        # while
        out.append(eqn.params["body_jaxpr"])
    return out


def _unwrap(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def _clip_tokens(tokens: np.ndarray) -> np.ndarray:
    """Collapse degenerate huge ids into the last dense bin."""
    if tokens.size and int(tokens.max(initial=0)) > MAX_DENSE_TOKEN:
        return np.minimum(tokens, MAX_DENSE_TOKEN)
    return tokens


def token_histogram(tokens: np.ndarray,
                    minlength: int = 0) -> np.ndarray:
    """Bounded-size int64 operator-count histogram of a token array."""
    if tokens.size == 0:
        return np.zeros(max(minlength, 1), np.int64)
    return np.bincount(_clip_tokens(tokens),
                       minlength=minlength).astype(np.int64)


class TokenStream:
    """One dispatch's tokenized op stream plus its monitoring metadata.

    ``tokens`` is the materialized stream (scan repeats capped at
    :data:`REPEAT_CAP` per equation); ``virtual_len`` and ``hist`` carry
    the *true* run-length-aware op count and per-operator multiplicities,
    which is what similarity/length-diff detection must see.
    ``content_hash`` identifies the true stream (two streams whose capped
    materializations collide but whose virtual multiplicities differ hash
    differently).
    """

    __slots__ = ("tokens", "virtual_len", "hist", "content_hash")

    def __init__(self, tokens: np.ndarray, virtual_len: Optional[int] = None,
                 hist: Optional[np.ndarray] = None):
        self.tokens = np.asarray(tokens, np.int32)
        self.virtual_len = (int(self.tokens.size) if virtual_len is None
                            else int(virtual_len))
        self.hist = (token_histogram(self.tokens) if hist is None
                     else np.asarray(hist, np.int64))
        h = hashlib.blake2b(digest_size=16)
        h.update(self.tokens.tobytes())
        h.update(self.virtual_len.to_bytes(8, "little"))
        h.update(np.ascontiguousarray(self.hist).tobytes())
        self.content_hash = h.digest()

    def __len__(self):
        return self.virtual_len


def tokenize_jaxpr_stream(jaxpr, vocab: OpVocab = GLOBAL_VOCAB,
                          max_ops: int = 2_000_000) -> TokenStream:
    """Flatten a (closed) jaxpr into a :class:`TokenStream`, unrolling
    scans virtually: the materialized array caps per-equation repeats at
    :data:`REPEAT_CAP`, the histogram and virtual length do not."""
    toks: List[int] = []
    counts: Dict[int, int] = {}
    virtual = 0

    def walk(j, mult: int):
        nonlocal virtual
        j = _unwrap(j)
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name == "scan":
                length = eqn.params.get("length", 1)
                body = eqn.params["jaxpr"]
                walk(body, mult * length)
                continue
            subs = _sub_jaxprs(eqn)
            if subs:
                for s in subs:
                    walk(s, mult)
                continue
            tok = vocab.id(name)
            toks.extend([tok] * min(mult, REPEAT_CAP))
            counts[tok] = counts.get(tok, 0) + mult
            virtual += mult
            if len(toks) > max_ops:
                raise RuntimeError("op stream too long")

    walk(jaxpr, 1)
    tokens = np.asarray(toks, np.int32)
    size = (max(min(t, MAX_DENSE_TOKEN) for t in counts) + 1) if counts else 1
    hist = np.zeros(size, np.int64)
    for tok, c in counts.items():
        hist[min(tok, MAX_DENSE_TOKEN)] += c
    return TokenStream(tokens, virtual_len=virtual, hist=hist)


def tokenize_jaxpr(jaxpr, vocab: OpVocab = GLOBAL_VOCAB,
                   max_ops: int = 2_000_000) -> np.ndarray:
    """Materialized int32 token stream (back-compat array form)."""
    return tokenize_jaxpr_stream(jaxpr, vocab, max_ops).tokens


# --------------------------------------------------------------- signatures
class Signature:
    """One iteration's op-sequence signature in histogram space.

    Carries the (virtual) length and operator-count histogram that Algo 1's
    length-diff + cosine test needs, plus an optional identity ``key`` (the
    tuple of per-dispatch content hashes) that lets an unchanged iteration
    short-circuit to (0, 1) without touching any array.  ``materialize()``
    concatenates the underlying token arrays lazily — only episodic
    consumers (fingerprinting at store time) pay for it.
    """

    __slots__ = ("length", "hist", "key", "_streams", "_tokens", "_norm")

    def __init__(self, length: int, hist: np.ndarray,
                 key: Optional[tuple] = None,
                 streams: Optional[List[TokenStream]] = None):
        self.length = int(length)
        self.hist = hist
        self.key = key
        self._streams = streams
        self._tokens: Optional[np.ndarray] = None
        self._norm: Optional[float] = None

    @classmethod
    def from_tokens(cls, tokens: np.ndarray) -> "Signature":
        tokens = np.asarray(tokens)
        sig = cls(tokens.size, token_histogram(tokens))
        sig._tokens = tokens.astype(np.int32, copy=False)
        return sig

    @property
    def norm(self) -> float:
        if self._norm is None:
            self._norm = float(np.linalg.norm(self.hist.astype(np.float64)))
        return self._norm

    def materialize(self) -> np.ndarray:
        """Concatenated (capped) token stream of the iteration."""
        if self._tokens is None:
            arrs = [s.tokens for s in (self._streams or []) if s.tokens.size]
            self._tokens = (np.concatenate(arrs) if arrs
                            else np.zeros((0,), np.int32))
        return self._tokens

    def __len__(self):
        return self.length


class SignatureAccumulator:
    """Maintains the iteration signature incrementally.

    ``update`` diffs the new dispatch-stream list against the previous one
    by content hash and applies histogram/length deltas only for the slots
    that changed — the steady-state iteration (everything cached upstream)
    does a handful of 16-byte compares and no array work.  The counters
    make the O(changed dispatches) claim testable: ``update_tokens`` grows
    only by the virtual length of streams actually re-accumulated.
    """

    def __init__(self):
        self._prev: List[TokenStream] = []
        self._hist = np.zeros(1, np.int64)
        self._length = 0
        self.iterations = 0
        self.changed_slots = 0
        self.update_tokens = 0

    # ---- delta application
    def _grow(self, n: int) -> None:
        if n > self._hist.size:
            self._hist = np.concatenate(
                [self._hist, np.zeros(n - self._hist.size, np.int64)])

    def _apply(self, stream: TokenStream, sign: int) -> None:
        self._grow(stream.hist.size)
        self._hist[: stream.hist.size] += sign * stream.hist
        self._length += sign * stream.virtual_len
        self.update_tokens += stream.virtual_len

    def update(self, streams: List[TokenStream]) -> Signature:
        self.iterations += 1
        prev = self._prev
        for i in range(max(len(prev), len(streams))):
            old = prev[i] if i < len(prev) else None
            new = streams[i] if i < len(streams) else None
            if (old is not None and new is not None
                    and old.content_hash == new.content_hash):
                continue
            self.changed_slots += 1
            if old is not None:
                self._apply(old, -1)
            if new is not None:
                self._apply(new, +1)
        self._prev = list(streams)
        return Signature(self._length, self._hist.copy(),
                         key=tuple(s.content_hash for s in streams),
                         streams=list(streams))

    def stats(self) -> dict:
        return {"iterations": self.iterations,
                "changed_slots": self.changed_slots,
                "update_tokens": self.update_tokens}


def sequence_signature(token_streams: Iterable) -> np.ndarray:
    """Concatenate per-dispatch token streams (arrays or TokenStreams) of
    one iteration into the materialized array form."""
    arrs = [s.tokens if isinstance(s, TokenStream) else s
            for s in token_streams]
    arrs = [a for a in arrs if a.size]
    if not arrs:
        return np.zeros((0,), np.int32)
    return np.concatenate(arrs)


# --------------------------------------------------------------- similarity
def sig_similarity(a: Signature, b: Signature) -> Tuple[float, float]:
    """(relative length difference, histogram cosine) between two
    iteration signatures.  Identical content keys short-circuit without
    touching any array — the steady-state path."""
    if a.key is not None and a.key == b.key:
        return 0.0, 1.0
    la, lb = a.length, b.length
    if la == 0 and lb == 0:
        return 0.0, 1.0
    if la == 0 or lb == 0:
        return 1.0, 0.0
    len_diff = abs(la - lb) / max(la, lb)
    m = min(a.hist.size, b.hist.size)
    denom = a.norm * b.norm
    cos = float(a.hist[:m] @ b.hist[:m] / denom) if denom else 0.0
    return len_diff, cos


def similarity(a: np.ndarray, b: np.ndarray) -> Tuple[float, float]:
    """(relative length difference, cosine similarity).

    Cosine is computed on the operator-count histogram, which is the
    length-robust form of the paper's tensor cosine (identical when
    lengths match and ops only reorder/extend).  Histogram buffers are
    bounded: token ids above :data:`MAX_DENSE_TOKEN` collapse into one bin
    instead of sizing the bincount by the largest id seen."""
    la, lb = len(a), len(b)
    if la == 0 and lb == 0:
        return 0.0, 1.0
    if la == 0 or lb == 0:
        return 1.0, 0.0
    len_diff = abs(la - lb) / max(la, lb)
    ha, hb = token_histogram(a), token_histogram(b)
    m = min(ha.size, hb.size)
    denom = np.linalg.norm(ha.astype(np.float64)) * \
        np.linalg.norm(hb.astype(np.float64))
    cos = float(ha[:m] @ hb[:m] / denom) if denom else 0.0
    return len_diff, cos
