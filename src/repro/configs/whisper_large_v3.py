"""whisper-large-v3 [audio]: enc-dec, conv frontend stubbed.
[arXiv:2212.04356; unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    num_layers=32, encoder_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, d_ff=5120, vocab_size=51866,
    qkv_bias=True, norm="layernorm", act="gelu", glu=False,
    pos_embedding="learned", max_position=1 << 16, encoder_seq=1500,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, encoder_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                          vocab_size=256, encoder_seq=32, max_position=512,
                          dtype="float32", param_dtype="float32")
