"""stablelm-2-1.6b [dense]: MHA, layernorm.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=5632, vocab_size=100352, qkv_bias=False,
    norm="layernorm", act="silu", glu=True, rope_theta=1e4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, d_ff=128,
                          vocab_size=256, dtype="float32",
                          param_dtype="float32")
