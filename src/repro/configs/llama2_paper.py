"""The paper's own evaluation model: Llama2 (32L, d=4096, ffn=11008) —
used by the faithful-reproduction benchmarks (Tables 1-4, Figs 4-8)."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-paper", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=32000, qkv_bias=False,
    norm="rmsnorm", act="silu", glu=True, rope_theta=1e4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=4, d_model=128, num_heads=4,
                          num_kv_heads=4, head_dim=32, d_ff=344,
                          vocab_size=512, dtype="float32",
                          param_dtype="float32")
