"""Assigned-architecture configs (public-literature exact configs) plus the
paper's own Llama2 scaling target.  ``get_config(name)`` returns the full
config; ``get_reduced(name)`` a smoke-test-sized config of the same family.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.common.config import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS: List[str] = [
    "whisper_large_v3",
    "qwen2_7b",
    "qwen1_5_0_5b",
    "stablelm_1_6b",
    "llama3_2_1b",
    "qwen3_moe_30b_a3b",
    "granite_moe_1b_a400m",
    "llama3_2_vision_90b",
    "mamba2_780m",
    "zamba2_1_2b",
]
# canonical external ids (with dashes/dots) -> module name
ALIASES = {
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-7b": "qwen2_7b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "stablelm-1.6b": "stablelm_1_6b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "mamba2-780m": "mamba2_780m",
    "zamba2-1.2b": "zamba2_1_2b",
    "llama2-paper": "llama2_paper",
}
ALL_IDS = ARCH_IDS + ["llama2_paper"]


def _module(name: str):
    name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _module(name).reduced()


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """Shape cells this arch runs; long_500k needs sub-quadratic decode."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # full-attention arch: noted skip (DESIGN.md §5)
        out.append(s)
    return out


def cell_matrix() -> Dict[str, List[str]]:
    """arch -> list of runnable shape names (the 40-cell table w/ skips)."""
    return {a: [s.name for s in applicable_shapes(get_config(a))]
            for a in ARCH_IDS}
