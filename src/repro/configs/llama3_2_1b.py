"""llama3.2-1b [dense]: small llama3, GQA kv=8, tied embeddings.
[hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, qkv_bias=False,
    norm="rmsnorm", act="silu", glu=True, rope_theta=5e5,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256, dtype="float32",
                          param_dtype="float32")
