"""mamba2-780m [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    norm="rmsnorm", tie_embeddings=True, pos_embedding="none",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=256,
                          ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
                          dtype="float32", param_dtype="float32")
