"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    hybrid_attn_every=6, norm="rmsnorm", act="gelu", glu=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=5, d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, d_ff=128,
                          vocab_size=256, ssm_state=16, ssm_head_dim=16,
                          ssm_chunk=32, hybrid_attn_every=2,
                          dtype="float32", param_dtype="float32")
