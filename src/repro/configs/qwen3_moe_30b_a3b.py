"""qwen3-moe-30b-a3b [moe]: 128 experts top-8, GQA kv=4, head_dim 128.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128, d_ff=768, vocab_size=151936, qkv_bias=False,
    norm="rmsnorm", act="silu", glu=True, rope_theta=1e6,
    num_experts=128, experts_per_token=8, moe_d_ff=768,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=64,
                          vocab_size=256, num_experts=8,
                          experts_per_token=2, moe_d_ff=64,
                          dtype="float32", param_dtype="float32")
