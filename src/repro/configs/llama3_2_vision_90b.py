"""llama-3.2-vision-90b [vlm]: cross-attn image layers every 5th layer;
patch-embedding frontend stubbed. [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, qkv_bias=False,
    norm="rmsnorm", act="silu", glu=True, rope_theta=5e5,
    cross_attn_every=5, image_tokens=6404,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=6, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256, cross_attn_every=3,
                          image_tokens=16, dtype="float32",
                          param_dtype="float32")
