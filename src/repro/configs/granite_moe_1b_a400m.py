"""granite-moe-1b-a400m [moe]: 32 experts top-8, GQA kv=8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155, qkv_bias=False,
    norm="rmsnorm", act="silu", glu=True, rope_theta=1e4,
    num_experts=32, experts_per_token=8, moe_d_ff=512,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=64,
                          vocab_size=256, num_experts=8,
                          experts_per_token=2, moe_d_ff=64,
                          dtype="float32", param_dtype="float32")
