"""qwen2-7b [dense]: GQA kv=4, QKV bias. [arXiv:2407.10671; hf]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, qkv_bias=True,
    norm="rmsnorm", act="silu", glu=True, rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=160,
                          vocab_size=256, dtype="float32",
                          param_dtype="float32")
