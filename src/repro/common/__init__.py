from repro.common.config import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    SHAPES_BY_NAME,
    MeshConfig,
    SINGLE_POD_MESH,
    MULTI_POD_MESH,
    ChameleonConfig,
    TrainConfig,
)
