"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def tree_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_zeros_like(tree, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_paths(tree):
    """List of ('/'.join(path), leaf) pairs with dict-key path names."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
               for x, y in zip(la, lb))
