"""Configuration dataclasses for Chameleon-JAX.

A single ``ModelConfig`` covers every assigned architecture family
(dense / moe / encdec / vlm / ssm / hybrid); ``ShapeConfig`` describes the
assigned input-shape cells; ``MeshConfig``/``TrainConfig``/``ServeConfig``
describe the runtime.  Everything is a frozen dataclass so configs are
hashable and usable as jit static arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | encdec | vlm | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: int = 0          # 0 -> = num_heads (MHA)
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu | gelu
    glu: bool = True               # gated MLP (silu(x@Wg) * (x@Wu)) @ Wd
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"    # rope | learned | none
    max_position: int = 1 << 20
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2): shared attention block every k ssm layers ---
    hybrid_attn_every: int = 0

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500        # precomputed frame embeddings (stub frontend)

    # --- VLM (llama-3.2-vision): cross-attention image layers ---
    cross_attn_every: int = 0      # every k-th layer is a cross-attn layer
    image_tokens: int = 0          # precomputed patch embeddings (stub frontend)

    # --- numerics / implementation ---
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "bfloat16"
    attn_impl: str = "chunked"     # dense | chunked | pallas
    attn_chunk: int = 1024
    scan_layers: bool = True       # scan over stacked layer params
    logits_softcap: float = 0.0

    def __post_init__(self):
        if self.num_kv_heads == 0:
            object.__setattr__(self, "num_kv_heads", self.num_heads)
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived sizes -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k cell? (SSM / hybrid decode)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count, exact against the model zoo's init
        (validated by tests/test_models_smoke.py)."""
        d, v = self.d_model, self.vocab_size
        norm = 2 * d if self.norm == "layernorm" else d
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.pos_embedding == "learned":
            emb += self.max_position * d
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        mlp_mult = 3 if self.glu else 2
        dense_mlp = mlp_mult * d * self.d_ff
        dense_block = attn + dense_mlp + 2 * norm
        cross_block = dense_block + attn + norm + 1  # xattn + lnx + xgate

        def ssm_block():
            di, ds, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            ch = di + 2 * ds
            return (norm                              # ln
                    + d * (2 * di + 2 * ds + nh)      # in_proj
                    + self.ssm_conv_width * ch + ch   # conv w + b
                    + 3 * nh                          # A_log, dt_bias, D
                    + di                              # norm_scale
                    + di * d)                         # out_proj

        if self.family == "dense":
            return emb + norm + self.num_layers * dense_block
        if self.family == "vlm":
            n_cross = (self.num_layers // self.cross_attn_every
                       if self.cross_attn_every else 0)
            n_self = self.num_layers - n_cross
            return (emb + norm + n_self * dense_block
                    + n_cross * cross_block)
        if self.family == "moe":
            moe_mlp = (self.num_experts * mlp_mult * d * self.moe_d_ff
                       + d * self.num_experts)
            return emb + norm + self.num_layers * (attn + moe_mlp + 2 * norm)
        if self.family == "ssm":
            return emb + norm + self.num_layers * ssm_block()
        if self.family == "hybrid":
            return (emb + norm + self.num_layers * ssm_block()
                    + dense_block)
        if self.family == "encdec":
            enc = self.encoder_layers * dense_block + self.encoder_seq * d
            dec = self.num_layers * cross_block
            return emb + 2 * norm + enc + dec
        return emb + self.num_layers * dense_block

    def active_param_count(self) -> int:
        """Activated params per token (MoE uses top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        mlp_mult = 3 if self.glu else 2
        total = self.param_count()
        all_experts = self.num_experts * mlp_mult * d * self.moe_d_ff
        active = self.experts_per_token * mlp_mult * d * self.moe_d_ff
        return total - self.num_layers * (all_experts - active)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


# The four assigned LM shapes (identical across all ten archs).
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# host-link calibration sweep: 64 KiB .. 64 MiB (single source of truth —
# HostMemConfig default, bwmodel default, and the benchmark all use this)
HOSTMEM_CALIBRATION_SIZES: Tuple[int, ...] = tuple(
    1 << p for p in range(16, 27, 2))


@dataclass(frozen=True)
class HostMemConfig:
    """Host-memory tier (repro.hostmem): pinned pool + transfer engine +
    measured bandwidth model.  Disabled -> the simulator prices transfers
    with the constant ``host_link_gbps`` exactly as the paper does."""
    enabled: bool = True
    pool_bytes: int = 0                          # 0 -> uncapped host pool
    min_class_bytes: int = 1 << 12               # smallest slab size class
    engine_depth: int = 2                        # in-flight copies (double buffer)
    # KV-spill payload compression across the host link: "none" keeps the
    # bit-exact raw path; "int8" routes float decode-state rows through the
    # quant_offload kernels (row-wise symmetric int8 + f32 scales), 2-4x
    # fewer staged bytes at <=0.4% per-row error; "auto" prices raw vs
    # int8 per row from the tuned kernel rates + measured link curve
    # (repro.kernels.autotune) and picks the cheaper one
    spill_compression: str = "none"              # none | int8 | auto
    spill_compress_min_bytes: int = 1 << 12      # rows below stay raw
    # per-traffic-class depth overrides, e.g. (("checkpoint", 16),) lets a
    # whole checkpoint drain queue without forcing early retires
    class_depths: Tuple[Tuple[str, int], ...] = ()
    # per-iteration byte cap on mirroring the applied policy's swap
    # schedule through the engine (real policy_swap-class copies retired
    # at each entry's promised release op); 0 disables the mirror
    mirror_swap_bytes: int = 64 << 20
    calibrate: bool = False                      # measure the link at startup
    calibration_sizes: Tuple[int, ...] = HOSTMEM_CALIBRATION_SIZES
    calibration_iters: int = 3


@dataclass(frozen=True)
class AutotuneConfig:
    """Roofline-driven kernel autotuning for the swap path
    (repro.kernels.autotune).  When enabled, startup measures each
    configured Pallas kernel's block-config variants, keeps the one with
    the highest achieved fraction of the memory-bandwidth roofline, and
    persists winners in a schema-versioned cache keyed by
    ``(kernel, shape-bucket, dtype, device_kind)`` — a warm cache means
    restart reuses tuned configs with zero re-measurement.  The measured
    link efficiency also derates the simulator's Eq-3 constant."""
    enabled: bool = False
    cache_dir: str = ""                          # "" -> in-memory only
    iters: int = 3                               # timing reps per variant
    device_kind: str = "tpu_v5e"                 # autotune.device registry key
    # kernels to tune at startup; flash_attention / ssd_scan can be added
    # where their tuning cost is worth it
    kernels: Tuple[str, ...] = ("quantize", "dequantize")


@dataclass(frozen=True)
class PolicyStoreConfig:
    """Persistent policy cache (repro.policystore): fingerprint-keyed
    store of generated SwapPolicies with a three-tier drift response
    (reuse / warm-start / regen).  ``dir=""`` keeps the store in-memory
    only; a directory makes policies survive process restarts."""
    enabled: bool = True
    dir: str = ""                                # "" -> memory-only store
    max_records: int = 64                        # LRU capacity (memory + disk)
    # calibrated-similarity tier thresholds (see policystore.drift)
    reuse_threshold: float = 0.90
    warm_threshold: float = 0.55
    # length-ratio gates: layer-count/model changes rescale the stream but
    # keep its shingle set, so tiers also require a length match
    reuse_len_ratio: float = 0.95
    warm_len_ratio: float = 0.60
    # REUSE only applies if fuzzy matching re-associates at least this
    # fraction of the cached entries onto the new program
    min_reuse_hit_rate: float = 0.60
    # REUSE is capped at WARM_START when the live bandwidth curve drifted
    # beyond this factor from the record's snapshot at any measured size
    # (only enforced once the live model is calibrated; loose enough that
    # online-EMA jitter does not trip it)
    bw_drift_limit: float = 4.0
    # fingerprint sketch parameters
    minhash_perms: int = 64
    shingle: int = 4
    # LSH band-bucket index over MinHash signatures: ``nearest`` probes
    # bucket collisions first (sublinear past ~1k records) and falls back
    # to a vectorized upper-bound-pruned scan only when the probe finds no
    # reuse-grade match.  rows per band = minhash_perms // lsh_bands.
    lsh_bands: int = 16


@dataclass(frozen=True)
class AdaptConfig:
    """Adaptation-pipeline placement (repro.adapt).

    ``mode`` decides where the §5 adaptation cycle (Detailed profiling →
    GenPolicy variant search → policy application) runs:

      * ``inline`` — the reference mode: adaptation runs on the training
        thread exactly as the paper describes (one measured variant per
        GenPolicy iteration); every async result can be asserted
        equivalent to what this mode produces for the same snapshot;
      * ``async`` — drift enqueues an :class:`~repro.adapt.AdaptJob`
        carrying an immutable snapshot; a background worker runs the
        variant search against it and publishes the winner to a
        single-slot mailbox, installed at the next iteration boundary
        while the old policy keeps serving;
      * ``speculative`` — ``async`` plus pre-generation: when the
        service predicts a recurring fingerprint (train→eval interleaves
        are periodic) it pre-builds that policy in idle background time
        so the phase switch costs 0 inline GenPolicy steps even on a
        cold mailbox.
    """
    mode: str = "inline"                 # inline | async | speculative
    # bounded service memory: parked speculative results and retained
    # snapshots (keyed by iteration fingerprint) are LRU-capped
    max_parked: int = 8
    max_snapshots: int = 16
    # fingerprint-transition history window the recurrence predictor sees
    history: int = 64
    # GIL-cooperative worker pacing: the background worker sleeps between
    # variant simulations (at least ``pace_s``, at least one snapshot
    # t_iter, capped at ``pace_cap_s``) so an overlapped training step
    # contends with at most one variant's worth of host-side work instead
    # of the whole bank.  Costs background latency only — the job still
    # lands within the drift window.  0 disables pacing.
    pace_s: float = 0.02
    pace_cap_s: float = 0.25


@dataclass(frozen=True)
class ResilienceConfig:
    """Swap-path fault recovery (repro.faults): engine retry/timeout
    parameters, link-health thresholds, and the degradation ladder.

    The engine retries a failed transfer ``max_retries`` times with
    exponential backoff; a copy slower than
    ``max(timeout_floor_s, timeout_factor * predicted)`` counts as a
    timeout.  Errors/timeouts/retries feed a per-traffic-class health
    score; crossing ``degrade_score``/``fail_score`` drives the
    degradation ladder in ``core/runtime.py`` (full → trimmed →
    conservative → no_swap), which climbs back up after
    ``recover_successes`` clean transfers (probe bursts generate them
    when the reduced rung is otherwise silent)."""
    enabled: bool = True
    # ---- engine retry / timeout ----
    max_retries: int = 3
    retry_backoff_s: float = 0.002               # first retry delay
    backoff_cap_s: float = 0.1                   # exponential backoff cap
    timeout_floor_s: float = 0.05                # below this is never "slow"
    timeout_factor: float = 8.0                  # x bwmodel-predicted time
    # ---- health state machine ----
    degrade_score: float = 2.0
    fail_score: float = 6.0
    recover_successes: int = 8
    residual_limit: float = 8.0                  # measured/predicted ratio
    health_decay: float = 0.7                    # score decay per clean copy
    # first copies pay jax dispatch init + slab allocation and the
    # bandwidth curve is still cold — no slow/timeout penalties until
    # this many transfers have completed
    health_warmup_transfers: int = 16
    # ---- degradation ladder ----
    ladder_hold_iterations: int = 2              # min iterations between moves
    probe_interval: int = 8                      # iterations between probes
    probe_burst: int = 4                         # round-trips per probe
    probe_bytes: int = 1 << 20
    trim_drop_fraction: float = 0.5              # max schedule cut at trimmed
    # ---- memory-ledger headroom feedback (repro.obs.memledger) ----
    # when the realized peak overshoots the executed policy's projection
    # AND the remaining budget headroom falls under this fraction, the
    # ledger notes mild pressure on the "memory" health class (severe
    # when the realized peak exceeds the budget outright) — so the
    # ladder degrades on shrinking margin before an OOM
    headroom_degrade_frac: float = 0.05
    # ---- adaptation-worker watchdog (hung worker un-wedges ADAPTING) ----
    adapt_timeout_s: float = 30.0                # 0 disables


@dataclass(frozen=True)
class ChameleonConfig:
    """Paper hyperparameters (§4, §5, §7.1)."""
    enabled: bool = True
    hbm_budget_bytes: int = 16 * 1024 ** 3      # v5e HBM per chip
    host_link_gbps: float = 32.0                 # Eq 3 bandwidth B (GB/s)
    m_warmup_stable: int = 2                     # Algo 1 `m`
    n_genpolicy_steps: int = 5                   # Algo 1 `n`
    len_change_threshold: float = 0.05           # 5% length diff
    cos_sim_threshold: float = 0.95              # 95% cosine similarity
    score_coef_c: float = 1.0                    # Eq 2 `C`
    groups_per_phase: int = 0                    # 0 -> num_layers (Fig 4 insight)
    offload_mode: str = "exact"                  # exact | compressed (int8, beyond-paper)
    allow_remat_fallback: bool = True            # beyond-paper: 3-way save/offload/remat
    peak_flops: float = 197e12                   # v5e bf16
    hbm_gbps: float = 819.0
    hostmem: HostMemConfig = HostMemConfig()     # host-memory tier (repro.hostmem)
    autotune: AutotuneConfig = AutotuneConfig()  # kernel autotuner (repro.kernels.autotune)
    policystore: PolicyStoreConfig = PolicyStoreConfig()  # repro.policystore
    adapt: AdaptConfig = AdaptConfig()           # adaptation placement (repro.adapt)
    resilience: ResilienceConfig = ResilienceConfig()  # fault recovery (repro.faults)


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    loss_scale: float = 2.0 ** 15                # dynamic loss scaling (op-seq change source)
    loss_scale_dynamic: bool = True
    eval_every: int = 0                          # on-the-fly validation (op-seq change source)
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    zero_stage: int = 2                          # 0,1,2,3
    grad_compression: str = "none"               # none | int8_ef (cross-pod)
    seed: int = 0
