"""Health-driven degradation ladder for the swap path (repro.faults.ladder).

When link health (``repro.faults.health``) reports trouble, the runtime
steps the applied policy down a fixed ladder of progressively more
conservative rungs instead of crashing or wedging:

    0 full          — the adaptation winner, unchanged
    1 trimmed       — same policy minus its lowest-value swaps (by
                      simulator score), re-verified against the budget
                      with ``projected_peak`` — less link traffic, same
                      fit guarantee
    2 conservative  — the WarmUp passive-swap fit (Algo 3 via
                      ``warmup_offload_sites``): no per-tensor schedule,
                      no planned release points, guaranteed-fit
    3 no_swap       — the save-sites baseline: the host link is not
                      trusted with anything

Descent is one rung per decision while health reads ``failed`` (with a
small hold between moves so retries can settle), to at least ``trimmed``
while ``degraded``.  Recovery is probe-driven: at a reduced rung the
runtime periodically issues small round-trip copies through the engine
(the only traffic a conservative rung generates), and once the health
machine has decayed back to ``healthy`` the ladder climbs one rung —
the climb itself is the real probe, since a still-bad link immediately
re-degrades and the ladder steps back down.

This module owns rung state + transition policy and the swap-trimming
helper; *applying* a rung (rebuilding the jitted step) is the runtime's
job (``ChameleonRuntime._apply_rung``).
"""
from __future__ import annotations

from typing import List, Optional

from repro import obs
from repro.faults.health import DEGRADED, FAILED, HEALTHY

RUNG_NAMES = ("full", "trimmed", "conservative", "no_swap")
RUNG_FULL, RUNG_TRIMMED, RUNG_CONSERVATIVE, RUNG_NO_SWAP = range(4)


class DegradationLadder:
    def __init__(self, *, hold_iterations: int = 2, probe_interval: int = 8):
        self.rung = RUNG_FULL
        self.hold_iterations = int(hold_iterations)
        self.probe_interval = int(probe_interval)
        self._last_move = -(1 << 30)
        self.last_probe = -(1 << 30)
        self.transitions: List[dict] = []
        self.n_descents = 0
        self.n_ascents = 0

    # ------------------------------------------------------------ policy
    def decide(self, worst: str, step: int) -> Optional[int]:
        """Map the worst per-class health state to a rung move.  Returns
        the new rung, or None when the ladder holds position."""
        if worst == FAILED:
            if (self.rung < RUNG_NO_SWAP
                    and step - self._last_move >= self.hold_iterations):
                return self._move(self.rung + 1, step, "health-failed")
            return None
        if worst == DEGRADED:
            if self.rung < RUNG_TRIMMED:
                return self._move(RUNG_TRIMMED, step, "health-degraded")
            return None
        # healthy: climb one rung once the health machine has recovered
        # (its recover_successes streak already debounces this)
        if (self.rung > RUNG_FULL
                and step - self._last_move >= self.hold_iterations):
            return self._move(self.rung - 1, step, "recovery-probe")
        return None

    def reset(self, step: int, why: str = "new-policy") -> None:
        """Snap back to the full rung (a fresh adaptation installed: it
        becomes the new rung-0 policy and earns a clean start)."""
        if self.rung != RUNG_FULL:
            self._move(RUNG_FULL, step, why)

    def should_probe(self, step: int) -> bool:
        """At a reduced rung the applied policy may generate no link
        traffic at all, so health would stay frozen; the runtime issues a
        probe burst whenever this fires."""
        if self.rung == RUNG_FULL:
            return False
        if step - self.last_probe < self.probe_interval:
            return False
        self.last_probe = step
        return True

    def _move(self, rung: int, step: int, why: str) -> int:
        old, self.rung = self.rung, rung
        self._last_move = step
        if rung > old:
            self.n_descents += 1
        else:
            self.n_ascents += 1
        self.transitions.append({"step": step, "frm": RUNG_NAMES[old],
                                 "to": RUNG_NAMES[rung], "why": why})
        obs.audit().event("ladder.transition", step=step,
                          frm=RUNG_NAMES[old], to=RUNG_NAMES[rung], why=why)
        obs.metrics().gauge("ladder_rung", rung)
        return rung

    # ------------------------------------------------------------- stats
    @property
    def name(self) -> str:
        return RUNG_NAMES[self.rung]

    def stats(self) -> dict:
        return {"rung": self.rung, "name": self.name,
                "descents": self.n_descents, "ascents": self.n_ascents,
                "transitions": list(self.transitions[-16:])}


def trim_swap(prof, swap, budget: int, max_drop_fraction: float = 0.5):
    """Drop as many of the lowest-score entries as the budget allows
    (capped at ``max_drop_fraction`` of the schedule) and return the
    kept entries, or None when nothing can be dropped.

    Dropping an entry removes its off-device window, so the projected
    peak is monotonically non-decreasing in the number dropped — binary
    search finds the largest feasible drop count in O(log n) timeline
    replays."""
    from repro.core.policy import projected_peak
    if swap is None or not swap.entries:
        return None
    entries = sorted(swap.entries, key=lambda e: (e.score, e.uid))
    cap = int(len(entries) * max_drop_fraction)
    if cap <= 0:
        return None
    lo, hi = 0, cap                    # drop counts known-good / candidate
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if projected_peak(prof, entries[mid:]) <= budget:
            lo = mid
        else:
            hi = mid - 1
    if lo == 0:
        return None
    return entries[lo:]
