"""repro.faults — deterministic fault injection + the recovery machinery
it exercises.

Three pieces (see docs/robustness.md):

  * :mod:`repro.faults.plan` — seeded :class:`FaultPlan` schedules with
    process-global arming; hook points (:func:`inject`) are threaded
    through the transfer engine, pinned pool, policy store, adaptation
    worker and checkpoint writer, and are zero-cost no-ops when no plan
    is armed;
  * :mod:`repro.faults.health` — per-traffic-class link health state
    machine (healthy → degraded → failed) fed by the engine's retry /
    timeout / bandwidth-residual signals;
  * :mod:`repro.faults.ladder` — the degradation ladder the runtime
    steps the applied policy down when health degrades (full → trimmed →
    conservative → no_swap) and climbs back up via recovery probes.
"""
from repro.faults.health import (DEGRADED, FAILED, HEALTHY, HealthMonitor,
                                 LinkHealth)
from repro.faults.ladder import (RUNG_CONSERVATIVE, RUNG_FULL, RUNG_NAMES,
                                 RUNG_NO_SWAP, RUNG_TRIMMED,
                                 DegradationLadder, trim_swap)
from repro.faults.plan import (SITES, Fault, FaultPlan, FaultSpec, active,
                               arm, armed, disarm, inject, injected, tick)

__all__ = [
    "SITES", "Fault", "FaultPlan", "FaultSpec",
    "arm", "armed", "active", "disarm", "inject", "injected", "tick",
    "HEALTHY", "DEGRADED", "FAILED", "HealthMonitor", "LinkHealth",
    "DegradationLadder", "trim_swap", "RUNG_NAMES",
    "RUNG_FULL", "RUNG_TRIMMED", "RUNG_CONSERVATIVE", "RUNG_NO_SWAP",
]
