"""Deterministic seeded fault injection (repro.faults).

A :class:`FaultPlan` is a schedule of :class:`FaultSpec` rules keyed by
**site** (a string naming a hook point threaded through the swap path —
see :data:`SITES`) and **iteration**.  Whether a given hook invocation
fires is decided by a keyed blake2b hash over ``(seed, site, iteration,
occurrence-index)`` — the schedule is a pure function of the seed, so a
chaos scenario replays identically across processes and machines, and a
failing nightly run can be reproduced locally from its seed alone.

Arming is process-global (:func:`arm` / :func:`disarm`), mirroring how
``repro.obs`` exposes its tracer: production hook points call
:func:`inject` unconditionally, and with no plan armed that is one
module-attribute load and a ``None`` check — measured in
``benchmarks/monitor_bench.py`` to be below noise on the transfer hot
path.  Hooks therefore stay compiled in; there is no "fault build".

Every fired fault is recorded on the plan (bounded) and emitted as a
``fault.injected`` audit event, so a chaos run's evidence trail shows
exactly which fault produced which retry/degradation downstream.
"""
from __future__ import annotations

import hashlib
import json
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro import obs

# Hook points threaded through the swap path.  A spec's ``site`` must be
# one of these (checked at construction so a typo'd scenario fails fast).
SITES: Tuple[str, ...] = (
    "engine.transfer_error",    # D2H/H2D copy raises mid-transfer
    "engine.transfer_stall",    # copy delayed by ``seconds`` (link stall)
    "engine.transfer_drop",     # copy silently does nothing (lost DMA)
    "pool.alloc",               # pinned allocation fails outright
    "pool.pressure",            # host memory pressure: fresh slabs denied
    "store.load",               # policy record unreadable at load
    "store.put",                # record/index write fails mid-put
    "adapt.worker",             # adaptation worker raises
    "adapt.hang",               # adaptation worker hangs for ``seconds``
    "ckpt.write",               # checkpoint shard write fails
)


@dataclass(frozen=True)
class FaultSpec:
    """One injection rule: fire at ``site`` with probability ``prob`` per
    hook invocation, inside the iteration window [start, stop), at most
    ``max_fires`` times.  ``seconds`` parameterizes stall/hang faults."""
    site: str
    prob: float = 1.0
    start: int = 0
    stop: Optional[int] = None
    max_fires: Optional[int] = None
    seconds: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"expected one of {SITES}")

    def to_json(self) -> dict:
        return {"site": self.site, "prob": self.prob, "start": self.start,
                "stop": self.stop, "max_fires": self.max_fires,
                "seconds": self.seconds}

    @classmethod
    def from_json(cls, d: dict) -> "FaultSpec":
        return cls(site=d["site"], prob=float(d.get("prob", 1.0)),
                   start=int(d.get("start", 0)),
                   stop=(None if d.get("stop") is None else int(d["stop"])),
                   max_fires=(None if d.get("max_fires") is None
                              else int(d["max_fires"])),
                   seconds=float(d.get("seconds", 0.0)))


@dataclass
class Fault:
    """What a fired hook returns to its call site."""
    site: str
    iteration: int
    seconds: float = 0.0
    key: str = ""


def _u01(seed: int, site: str, iteration: int, occ: int, key: str) -> float:
    """Deterministic uniform draw in [0, 1) for one hook invocation."""
    h = hashlib.blake2b(
        f"{seed}:{site}:{iteration}:{occ}:{key}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "little") / 2.0 ** 64


class FaultPlan:
    """Seeded schedule of fault specs, armed process-wide via :func:`arm`.

    Thread-safe: hook points fire from the training thread, the adaptation
    worker, and the checkpoint writer concurrently."""

    LOG_CAP = 4096

    def __init__(self, specs: List[FaultSpec], seed: int = 0):
        self.seed = int(seed)
        self.specs = list(specs)
        self.iteration = 0
        self._occ: Dict[Tuple[str, int], int] = {}   # (site, iter) -> calls
        self.fired: Dict[str, int] = {}              # site -> fires
        self._spec_fires: Dict[int, int] = {}        # spec idx -> fires
        self.log: List[dict] = []
        self._lock = threading.Lock()
        self._by_site: Dict[str, List[Tuple[int, FaultSpec]]] = {}
        for i, s in enumerate(self.specs):
            self._by_site.setdefault(s.site, []).append((i, s))

    # ----------------------------------------------------------- schedule
    def set_iteration(self, it: int) -> None:
        self.iteration = int(it)

    def fire(self, site: str, key: str = "") -> Optional[Fault]:
        specs = self._by_site.get(site)
        if not specs:
            return None
        with self._lock:
            it = self.iteration
            occ = self._occ.get((site, it), 0)
            self._occ[(site, it)] = occ + 1
            for idx, s in specs:
                if it < s.start or (s.stop is not None and it >= s.stop):
                    continue
                if (s.max_fires is not None
                        and self._spec_fires.get(idx, 0) >= s.max_fires):
                    continue
                if _u01(self.seed, site, it, occ, key) >= s.prob:
                    continue
                self._spec_fires[idx] = self._spec_fires.get(idx, 0) + 1
                self.fired[site] = self.fired.get(site, 0) + 1
                f = Fault(site, it, seconds=s.seconds, key=key)
                if len(self.log) < self.LOG_CAP:
                    self.log.append({"site": site, "iteration": it,
                                     "occ": occ, "key": key,
                                     "seconds": s.seconds})
                break
            else:
                return None
        obs.audit().event("fault.injected", site=site, iteration=it,
                          occ=occ, key=key[:64], seconds=s.seconds)
        obs.metrics().counter("faults_injected")
        return f

    def total_fired(self) -> int:
        with self._lock:
            return sum(self.fired.values())

    def stats(self) -> dict:
        with self._lock:
            return {"seed": self.seed, "specs": len(self.specs),
                    "iteration": self.iteration,
                    "fired": dict(self.fired),
                    "total_fired": sum(self.fired.values())}

    # ------------------------------------------------------ serialization
    def to_json(self) -> dict:
        return {"seed": self.seed,
                "specs": [s.to_json() for s in self.specs]}

    @classmethod
    def from_json(cls, d: dict) -> "FaultPlan":
        return cls([FaultSpec.from_json(s) for s in d.get("specs", [])],
                   seed=int(d.get("seed", 0)))

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))

    # ------------------------------------------------------ conveniences
    @classmethod
    def everywhere(cls, seed: int = 0, prob: float = 0.05,
                   seconds: float = 0.01, start: int = 0,
                   stop: Optional[int] = None,
                   max_fires_per_site: Optional[int] = None) -> "FaultPlan":
        """One spec per site — the chaos driver's all-sites scenario."""
        return cls([FaultSpec(site, prob=prob, seconds=seconds, start=start,
                              stop=stop, max_fires=max_fires_per_site)
                    for site in SITES], seed=seed)


# -------------------------------------------------------- process arming
_ACTIVE: Optional[FaultPlan] = None


def arm(plan: FaultPlan) -> FaultPlan:
    """Make ``plan`` the process-wide fault schedule."""
    global _ACTIVE
    _ACTIVE = plan
    obs.audit().event("fault.armed", seed=plan.seed, specs=len(plan.specs))
    return plan


def disarm() -> Optional[FaultPlan]:
    """Remove the armed plan (hooks go back to zero-cost no-ops)."""
    global _ACTIVE
    old, _ACTIVE = _ACTIVE, None
    if old is not None:
        obs.audit().event("fault.disarmed", total_fired=old.total_fired())
    return old


def active() -> Optional[FaultPlan]:
    return _ACTIVE


def armed() -> bool:
    return _ACTIVE is not None


def inject(site: str, key: str = "") -> Optional[Fault]:
    """The production hook point.  With no plan armed this is one global
    read and a ``None`` check — cheap enough to leave in hot paths."""
    p = _ACTIVE
    if p is None:
        return None
    return p.fire(site, key)


def tick(iteration: int) -> None:
    """Advance the armed plan's iteration cursor (driven by the trainer);
    no-op when disarmed."""
    p = _ACTIVE
    if p is not None:
        p.set_iteration(iteration)


class injected:
    """Context manager for tests: arm a plan, disarm on exit."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def __enter__(self) -> FaultPlan:
        return arm(self.plan)

    def __exit__(self, *exc) -> None:
        disarm()
