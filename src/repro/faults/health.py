"""Per-traffic-class link health state machine (repro.faults.health).

Each traffic class of the transfer engine gets a three-state machine

    healthy  →  degraded  →  failed
       ↑____________|___________|      (recovery via clean successes)

fed by the engine's recovery machinery: every retry, terminal transfer
failure, and timeout (measured copy time far above the bandwidth-model
prediction — a large *residual*) adds to an error score; every clean
transfer decays it.  Thresholds on the score drive the transitions, and
transitions are the *input* to the degradation ladder in
``core/runtime.py`` — the ladder never looks at raw faults, only at
health states, so any anomaly source (injected or organic) degrades the
swap policy through one narrow interface.

Scores rather than raw counters: a single transient timeout on an
otherwise healthy link decays away within ``recover_successes`` clean
transfers, while a burst pushes the class to ``degraded``/``failed``
quickly.  All transitions emit ``health.transition`` audit events and a
``link_health.<class>`` gauge.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Iterable, Optional

from repro import obs

HEALTHY = "healthy"
DEGRADED = "degraded"
FAILED = "failed"
_LEVEL = {HEALTHY: 0, DEGRADED: 1, FAILED: 2}

#: pseudo traffic class carrying HBM budget-headroom pressure from the
#: obs memory ledger — same FSM, same ladder interface as link faults,
#: so the runtime degrades on shrinking margin *before* an OOM
MEM_CLASS = "memory"


@dataclass
class LinkHealth:
    """Score + counters for one traffic class."""
    cls: str
    state: str = HEALTHY
    score: float = 0.0
    clean_streak: int = 0
    n_errors: int = 0
    n_retries: int = 0
    n_timeouts: int = 0
    n_slow: int = 0
    n_pressure: int = 0
    n_transitions: int = 0

    def as_dict(self) -> dict:
        return {"state": self.state, "score": round(self.score, 3),
                "clean_streak": self.clean_streak,
                "n_errors": self.n_errors, "n_retries": self.n_retries,
                "n_timeouts": self.n_timeouts, "n_slow": self.n_slow,
                "n_pressure": self.n_pressure,
                "n_transitions": self.n_transitions}


class HealthMonitor:
    """Tracks :class:`LinkHealth` per traffic class.

    Weights: a terminal error counts 1.0, a timeout 1.0, a retry 0.5 and
    a slow-but-successful transfer (residual above ``residual_limit``)
    0.25.  A clean success multiplies the score by ``decay`` and, after
    ``recover_successes`` consecutive cleans with the score back under
    the healthy threshold, re-promotes the class.
    """

    def __init__(self, classes: Iterable[str], *,
                 degrade_score: float = 2.0, fail_score: float = 6.0,
                 recover_successes: int = 8, residual_limit: float = 8.0,
                 decay: float = 0.7):
        self.degrade_score = float(degrade_score)
        self.fail_score = float(fail_score)
        self.recover_successes = int(recover_successes)
        self.residual_limit = float(residual_limit)
        self.decay = float(decay)
        self._lock = threading.Lock()
        self.links: Dict[str, LinkHealth] = {
            c: LinkHealth(c) for c in classes}

    # ------------------------------------------------------------ inputs
    def note_success(self, cls: str, residual: Optional[float] = None) -> None:
        """A transfer completed cleanly; ``residual`` = measured/predicted
        copy time from the bandwidth model (None when uncalibrated)."""
        with self._lock:
            lk = self.links[cls]
            if residual is not None and residual > self.residual_limit:
                lk.n_slow += 1
                lk.score += 0.25
                lk.clean_streak = 0
                self._reconsider(lk)
                return
            lk.score *= self.decay
            lk.clean_streak += 1
            self._reconsider(lk)

    def note_retry(self, cls: str) -> None:
        self._bump(cls, 0.5, "n_retries")

    def note_timeout(self, cls: str) -> None:
        self._bump(cls, 1.0, "n_timeouts")

    def note_error(self, cls: str) -> None:
        self._bump(cls, 1.0, "n_errors")

    def note_pressure(self, cls: str, severe: bool = False) -> None:
        """Memory-margin pressure from the obs ledger: *severe* (realized
        peak past the budget) scores like a terminal error; *mild*
        (realized peak above plan, headroom nearly gone) accumulates, so
        sustained margin erosion degrades the class while a one-off blip
        decays away."""
        self._bump(cls, 1.0 if severe else 0.35, "n_pressure")

    def _bump(self, cls: str, weight: float, counter: str) -> None:
        with self._lock:
            lk = self.links[cls]
            setattr(lk, counter, getattr(lk, counter) + 1)
            lk.score += weight
            lk.clean_streak = 0
            self._reconsider(lk)

    # ------------------------------------------------------- transitions
    def _reconsider(self, lk: LinkHealth) -> None:
        if lk.score >= self.fail_score:
            target = FAILED
        elif lk.score >= self.degrade_score:
            target = DEGRADED
        elif (lk.state != HEALTHY
              and lk.clean_streak >= self.recover_successes
              and lk.score < self.degrade_score * 0.5):
            target = HEALTHY
        elif lk.state == FAILED and lk.score < self.degrade_score:
            # decayed out of the failed band but not yet earned healthy
            target = DEGRADED
        else:
            return
        if target == lk.state:
            return
        old, lk.state = lk.state, target
        lk.n_transitions += 1
        obs.audit().event("health.transition", cls=lk.cls, frm=old,
                          to=target, score=round(lk.score, 3),
                          errors=lk.n_errors, timeouts=lk.n_timeouts,
                          retries=lk.n_retries)
        obs.metrics().gauge(f"link_health.{lk.cls}", _LEVEL[target])

    # ----------------------------------------------------------- queries
    def state(self, cls: str) -> str:
        return self.links[cls].state

    def worst(self) -> str:
        """Most-degraded state across classes — the ladder's input."""
        with self._lock:
            return max((lk.state for lk in self.links.values()),
                       key=_LEVEL.__getitem__, default=HEALTHY)

    def stats(self) -> dict:
        with self._lock:
            return {c: lk.as_dict() for c, lk in self.links.items()}
