"""Priced raw-vs-int8 spill compression (``spill_compression="auto"``).

The static ``"int8"`` mode compresses every big-enough float row; this
advisor instead *prices* the two options with tuned numbers and picks
the cheaper one per row:

  raw   = transfer_time(row_bytes)
  int8  = quantize_time + transfer_time(payload + scales) + dequant_time

Transfer times come from the live
:class:`~repro.hostmem.bwmodel.BandwidthModel` (measured curve, or the
efficiency-scaled constant).  Kernel times come from the autotune
cache's achieved bytes/s for the ``quantize``/``dequantize`` kernels —
the roofline measurements taken by the
:class:`~repro.kernels.autotune.tuner.Autotuner`.  With no tuned entry
the kernel cost is treated as free, which reduces to the static int8
rule (compression wins whenever the link saving is positive) — so an
untuned ``auto`` is never worse than ``"int8"`` was.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro import obs
from repro.kernels.autotune.cache import AutotuneCache

COMPRESS_RAW = "raw"
COMPRESS_INT8 = "int8"


class CompressionAdvisor:
    def __init__(self, bwmodel=None, cache: Optional[AutotuneCache] = None,
                 fallback_gbps: float = 32.0):
        self.bwmodel = bwmodel
        self.cache = cache
        self.fallback_gbps = fallback_gbps
        self.n_int8 = 0
        self.n_raw = 0

    # ------------------------------------------------------------ pricing
    def _transfer_s(self, nbytes: int) -> float:
        if self.bwmodel is not None:
            return self.bwmodel.transfer_time(nbytes)
        return nbytes / (self.fallback_gbps * 1e9)

    def _achieved_bps(self, kernel: str) -> Optional[float]:
        """Tuned achieved bytes/s for ``kernel`` (any bucket — block
        geometry, not exact size, is what was tuned)."""
        if self.cache is None:
            return None
        best = None
        for key, e in self.cache.entries.items():
            if key.startswith(kernel + "|") and e.get("achieved_bps"):
                bps = float(e["achieved_bps"])
                best = bps if best is None else max(best, bps)
        return best

    def _kernel_s(self, kernel: str, kernel_bytes: int) -> float:
        bps = self._achieved_bps(kernel)
        return kernel_bytes / bps if bps else 0.0

    def decide(self, row_nbytes: int, itemsize: int, rows: int,
               cls: str = "kv_spill", tag: str = "") -> Tuple[str, dict]:
        """Pick ``"raw"`` or ``"int8"`` for one row; the decision and
        both priced costs go to the audit log."""
        elems = row_nbytes // max(itemsize, 1)
        payload = elems + rows * 4               # int8 bytes + f32 scales
        raw_s = self._transfer_s(row_nbytes)
        # kernel byte accounting mirrors space.py: quantize reads the row
        # and writes payload+scales; dequantize does the mirror image
        q_s = self._kernel_s("quantize", row_nbytes + payload)
        dq_s = self._kernel_s("dequantize", payload + row_nbytes)
        int8_s = q_s + dq_s + self._transfer_s(payload)
        choice = COMPRESS_INT8 if int8_s < raw_s else COMPRESS_RAW
        if choice == COMPRESS_INT8:
            self.n_int8 += 1
        else:
            self.n_raw += 1
        detail = {"raw_s": raw_s, "int8_s": int8_s,
                  "quant_s": q_s + dq_s, "row_nbytes": row_nbytes,
                  "payload_nbytes": payload}
        obs.audit().event("kvspill.compression_choice", cls=cls,
                          tag=tag[:48], choice=choice,
                          raw_us=round(raw_s * 1e6, 3),
                          int8_us=round(int8_s * 1e6, 3),
                          row_nbytes=row_nbytes)
        return choice, detail

    def stats(self) -> dict:
        return {"n_int8": self.n_int8, "n_raw": self.n_raw}
