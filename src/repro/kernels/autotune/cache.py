"""Persistent autotune result cache.

One schema-versioned JSON file (``autotune.json`` inside the cache dir)
holds every tuned entry keyed by ``kernel|shape-bucket|dtype|device_kind``
plus the :class:`~repro.hostmem.bwmodel.BandwidthModel` snapshot the
measurements were taken next to — the same restart story as the
policystore: a cold process pointed at a warm directory reuses every
tuned config (and the measured host-link efficiency) with **zero**
re-measurement.

Writes are atomic (tmp + ``os.replace`` — the policystore pattern) and
loads are corruption-safe: truncated or garbage JSON, a wrong schema
version, or malformed entries all fall back to an empty cache, never an
exception — an unreadable cache only costs a re-tune.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional, Sequence

from repro.kernels.autotune.table import dtype_name, shape_bucket

SCHEMA_VERSION = 1
CACHE_FILENAME = "autotune.json"


def cache_key(kernel: str, shape: Sequence[int], dtype,
              device_kind: str) -> str:
    return (f"{kernel}|{shape_bucket(shape)}|{dtype_name(dtype)}"
            f"|{device_kind}")


class AutotuneCache:
    """In-memory entry map + the optional directory it mirrors to."""

    def __init__(self, directory: str = "",
                 device_kind: str = "tpu_v5e"):
        self.dir = directory
        self.device_kind = device_kind
        self.entries: Dict[str, dict] = {}
        self.bwmodel: Optional[dict] = None    # BandwidthModel.to_dict()
        self.load_errors = 0                   # unreadable files skipped

    # ------------------------------------------------------------ lookup
    def get(self, kernel: str, shape: Sequence[int],
            dtype) -> Optional[dict]:
        return self.entries.get(
            cache_key(kernel, shape, dtype, self.device_kind))

    def put(self, kernel: str, shape: Sequence[int], dtype,
            entry: dict) -> str:
        key = cache_key(kernel, shape, dtype, self.device_kind)
        self.entries[key] = dict(entry)
        return key

    def table_entries(self) -> Dict[str, dict]:
        """Entries re-keyed for the process-wide table (device suffix
        dropped — the table serves exactly one device)."""
        out = {}
        for key, e in self.entries.items():
            kernel, bucket, dtype, kind = key.split("|")
            if kind != self.device_kind or "config" not in e:
                continue
            out[f"{kernel}|{bucket}|{dtype}"] = dict(e["config"])
        return out

    # ----------------------------------------------------- persistence
    @property
    def path(self) -> str:
        return os.path.join(self.dir, CACHE_FILENAME) if self.dir else ""

    def save(self) -> Optional[str]:
        """Atomic write (tmp + rename); no-op without a directory."""
        if not self.dir:
            return None
        os.makedirs(self.dir, exist_ok=True)
        payload = {"schema_version": SCHEMA_VERSION,
                   "device_kind": self.device_kind,
                   "entries": self.entries,
                   "bwmodel": self.bwmodel}
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)
        return self.path

    @classmethod
    def load(cls, directory: str,
             device_kind: str = "tpu_v5e") -> "AutotuneCache":
        """Load a cache dir; any corruption yields an empty cache with
        ``load_errors`` counted (re-tuning is the recovery path)."""
        cache = cls(directory, device_kind)
        path = cache.path
        if not path or not os.path.exists(path):
            return cache
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("schema_version") != SCHEMA_VERSION:
                raise ValueError(
                    f"schema {payload.get('schema_version')!r}")
            entries = payload.get("entries", {})
            if not isinstance(entries, dict):
                raise ValueError("entries is not a mapping")
            for key, e in entries.items():
                if (isinstance(key, str) and key.count("|") == 3
                        and isinstance(e, dict)
                        and isinstance(e.get("config"), dict)):
                    cache.entries[key] = e
                else:
                    cache.load_errors += 1
            bw = payload.get("bwmodel")
            cache.bwmodel = bw if isinstance(bw, dict) else None
        except Exception:            # noqa: BLE001 — corruption-safe load
            cache.entries = {}
            cache.bwmodel = None
            cache.load_errors += 1
        return cache

    def stats(self) -> dict:
        return {"dir": self.dir, "device_kind": self.device_kind,
                "entries": len(self.entries),
                "has_bwmodel": self.bwmodel is not None,
                "load_errors": self.load_errors}
