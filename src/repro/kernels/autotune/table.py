"""Process-wide tuned-config table the kernel wrappers consult.

Kept dependency-free (the ``ops`` modules import this at call time and
the autotuner populates it), so there is no cycle between
``kernels/*/ops.py`` and the autotune package.  Lookup is by the same
``(kernel, shape-bucket, dtype)`` key the cache uses; a miss returns
``None`` and the wrapper keeps its hardcoded default — an untuned
process behaves exactly as before.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

import numpy as np

_lock = threading.Lock()
_table: Dict[str, dict] = {}      # "kernel|bucket|dtype" -> config dict


def dtype_name(dtype) -> str:
    """Canonical dtype key: ``np.float32``, ``jnp.bfloat16``, a dtype
    object, and the string ``"float32"`` all map to the same name."""
    try:
        return np.dtype(dtype).name
    except TypeError:
        return str(getattr(dtype, "name", dtype))


def shape_bucket(shape: Sequence[int]) -> str:
    """Dims rounded up to the next power of two: nearby shapes share a
    tuned config (the win is block geometry, not the exact size)."""
    dims = []
    for d in shape:
        d = int(d)
        p = 1
        while p < d:
            p <<= 1
        dims.append(p)
    return "x".join(str(d) for d in dims)


def table_key(kernel: str, shape: Sequence[int], dtype) -> str:
    return f"{kernel}|{shape_bucket(shape)}|{dtype_name(dtype)}"


def install(entries: Dict[str, dict]) -> None:
    """Replace the installed table (``entries``: table_key -> config)."""
    with _lock:
        _table.clear()
        _table.update(entries)


def clear() -> None:
    with _lock:
        _table.clear()


def tuned_config(kernel: str, shape: Sequence[int],
                 dtype) -> Optional[dict]:
    """The installed winning config for this call site, or None."""
    if not _table:
        return None
    with _lock:
        return _table.get(table_key(kernel, shape, dtype))


def installed_count() -> int:
    with _lock:
        return len(_table)
