"""Per-kernel block-config search spaces + roofline byte accounting.

One :class:`KernelSpace` per swap-path Pallas kernel describes what the
autotuner can vary, how to build representative arguments, how to run a
variant, what the numerical oracle is (``kernels/*/ref.py``), and how
many bytes one call *must* move — the SNIPPETS-style dtype-bytes
accounting that turns a measured wall time into an achieved fraction of
the memory-bandwidth roofline (``bytes_moved / t / peak_bw``).

The run callables are backend-agnostic: they call the same wrappers
production uses (interpret mode off-TPU), so real TPU timing drops in
with no harness change.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class KernelSpace:
    """One kernel's tunable surface."""
    name: str
    variants: Tuple[dict, ...]           # candidate configs, default first
    default: dict
    make_args: Callable[[Sequence[int], object], tuple]
    run: Callable[[tuple, dict], object]
    ref: Callable[[tuple], object]
    bytes_moved: Callable[[Sequence[int], object], int]
    default_shape: Tuple[int, ...] = ()


def _itemsize(dtype) -> int:
    return int(np.dtype(dtype).itemsize)


# ------------------------------------------------------- quant_offload
def _quant_args(shape, dtype):
    import jax.numpy as jnp
    R, F = shape
    rng = np.random.RandomState(0)
    return (jnp.asarray(rng.randn(R, F) * 0.5, dtype),)


def _quant_run(args, config):
    from repro.kernels.quant_offload import kernel as K
    from repro.kernels.quant_offload.ops import _default_interpret
    return K.quantize_fwd(args[0], block_rows=config["block_rows"],
                          interpret=_default_interpret())


def _quant_ref(args):
    from repro.kernels.quant_offload.ref import quantize_ref
    return quantize_ref(args[0])


def _quant_bytes(shape, dtype) -> int:
    R, F = shape
    # read x (R,F,itemsize) + write int8 payload (R,F) + f32 scales (R,1)
    return R * F * _itemsize(dtype) + R * F + R * 4


def _dequant_args(shape, dtype):
    q, s = _quant_ref(_quant_args(shape, dtype))
    return (q, s, np.dtype(dtype))


def _dequant_run(args, config):
    from repro.kernels.quant_offload import kernel as K
    from repro.kernels.quant_offload.ops import _default_interpret
    q, s, out_dtype = args
    return K.dequantize_fwd(q, s, out_dtype,
                            block_rows=config["block_rows"],
                            interpret=_default_interpret())


def _dequant_ref(args):
    from repro.kernels.quant_offload.ref import dequantize_ref
    return dequantize_ref(*args)


def _dequant_bytes(shape, dtype) -> int:
    R, F = shape
    return R * F + R * 4 + R * F * _itemsize(dtype)


# ----------------------------------------------------- flash_attention
def _flash_args(shape, dtype):
    import jax.numpy as jnp
    B, S, H, D = shape
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.3, dtype)
    k = jnp.asarray(rng.randn(B, S, max(H // 2, 1), D) * 0.3, dtype)
    v = jnp.asarray(rng.randn(B, S, max(H // 2, 1), D) * 0.3, dtype)
    return (q, k, v)


def _flash_run(args, config):
    from repro.kernels.flash_attention.ops import flash_attention
    return flash_attention(*args, causal=True,
                           block_q=config["block_q"],
                           block_k=config["block_k"])


def _flash_ref(args):
    import jax.numpy as jnp
    import math
    from repro.kernels.flash_attention.ref import attention_ref
    q, k, v = args
    out = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), causal=True,
                        sm_scale=1.0 / math.sqrt(q.shape[-1]))
    return jnp.swapaxes(out, 1, 2)


def _flash_bytes(shape, dtype) -> int:
    B, S, H, D = shape
    kh = max(H // 2, 1)
    it = _itemsize(dtype)
    # q + k + v reads + o write: the memory-roofline lower bound (the
    # whole point of flash is that nothing quadratic touches HBM)
    return (B * S * H * D + 2 * B * S * kh * D + B * S * H * D) * it


# ------------------------------------------------------------ ssd_scan
def _ssd_args(shape, dtype):
    import jax.numpy as jnp
    B, S, H, P = shape
    N = 64
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(B, S, H, P) * 0.5, dtype)
    dt = jnp.asarray(np.abs(rng.randn(B, S, H)) * 0.1, dtype)
    A = -jnp.asarray(np.abs(rng.randn(H)) + 0.5, dtype)
    Bm = jnp.asarray(rng.randn(B, S, N) * 0.3, dtype)
    Cm = jnp.asarray(rng.randn(B, S, N) * 0.3, dtype)
    return (x, dt, A, Bm, Cm)


def _ssd_run(args, config):
    from repro.kernels.ssd_scan.ops import ssd_scan
    return ssd_scan(*args, chunk=config["chunk"])


def _ssd_ref(args):
    import jax.numpy as jnp
    from repro.kernels.ssd_scan.ref import ssd_ref
    x, dt, A, Bm, Cm = args
    y = ssd_ref(jnp.transpose(x, (0, 2, 1, 3)),
                jnp.transpose(dt, (0, 2, 1)), A, Bm, Cm)
    return jnp.transpose(y, (0, 2, 1, 3))


def _ssd_bytes(shape, dtype) -> int:
    B, S, H, P = shape
    N = 64
    it = _itemsize(dtype)
    # x + dt + Bm + Cm reads, y write (A is negligible)
    return (2 * B * S * H * P + B * S * H + 2 * B * S * N) * it


def _cfgs(key, values) -> Tuple[dict, ...]:
    return tuple({key: v} for v in values)


SPACES: Dict[str, KernelSpace] = {
    "quantize": KernelSpace(
        "quantize", _cfgs("block_rows", (256, 64, 128, 512)),
        {"block_rows": 256}, _quant_args, _quant_run, _quant_ref,
        _quant_bytes, default_shape=(1024, 1024)),
    "dequantize": KernelSpace(
        "dequantize", _cfgs("block_rows", (256, 64, 128, 512)),
        {"block_rows": 256}, _dequant_args, _dequant_run, _dequant_ref,
        _dequant_bytes, default_shape=(1024, 1024)),
    "flash_attention": KernelSpace(
        "flash_attention",
        tuple({"block_q": bq, "block_k": bk}
              for bq in (128, 256) for bk in (128, 256)),
        {"block_q": 128, "block_k": 128},
        _flash_args, _flash_run, _flash_ref, _flash_bytes,
        default_shape=(1, 256, 4, 64)),
    "ssd_scan": KernelSpace(
        "ssd_scan", _cfgs("chunk", (256, 64, 128)),
        {"chunk": 256}, _ssd_args, _ssd_run, _ssd_ref, _ssd_bytes,
        default_shape=(1, 256, 4, 64)),
}
