"""repro.kernels.autotune — roofline-driven swap-path kernel autotuner.

Pieces (see docs/kernels.md for the full data flow):

  * :mod:`device` — :class:`DeviceSpec` roofline peaks by device kind
    (shared with the dry-run roofline report);
  * :mod:`space` — per-kernel block-config search spaces + bytes-moved
    accounting;
  * :mod:`tuner` — measures each variant's achieved fraction of the
    memory-bandwidth roofline, keeps the winner;
  * :mod:`cache` — schema-versioned atomic JSON persistence keyed by
    ``(kernel, shape-bucket, dtype, device_kind)``, stored alongside the
    BandwidthModel snapshot (warm restarts re-measure nothing);
  * :mod:`table` — the process-wide tuned-config table the kernel
    wrappers consult (:func:`install` / :func:`tuned_config`);
  * :mod:`advisor` — prices raw-vs-int8 spill compression with the
    tuned numbers (``spill_compression="auto"``).
"""
from __future__ import annotations

from repro.kernels.autotune.advisor import CompressionAdvisor
from repro.kernels.autotune.cache import (AutotuneCache, SCHEMA_VERSION,
                                          cache_key)
from repro.kernels.autotune.device import (DEFAULT_DEVICE_KIND, DEVICE_SPECS,
                                           DeviceSpec, get_device_spec)
from repro.kernels.autotune.table import (clear, install, installed_count,
                                          shape_bucket, table_key,
                                          tuned_config)
from repro.kernels.autotune.tuner import (HOST_LINK_KERNEL, Autotuner,
                                          default_measure)

__all__ = [
    "Autotuner", "AutotuneCache", "CompressionAdvisor", "DeviceSpec",
    "DEVICE_SPECS", "DEFAULT_DEVICE_KIND", "HOST_LINK_KERNEL",
    "SCHEMA_VERSION", "cache_key", "clear", "default_measure",
    "get_device_spec", "install", "install_cache", "installed_count",
    "shape_bucket", "table_key", "tuned_config",
]


def install_cache(cache: AutotuneCache) -> int:
    """Publish a cache's winners to the process-wide table consulted by
    the kernel wrappers; returns the number of installed configs."""
    entries = cache.table_entries()
    install(entries)
    return len(entries)
