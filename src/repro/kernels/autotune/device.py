"""Device roofline specs (one source of truth).

``launch/roofline.py`` and the kernel autotuner used to carry their own
copies of the TPU v5e hardware constants; both now read one
:class:`DeviceSpec` selected by device kind, so the dry-run roofline
report and the autotuner's achieved-vs-peak efficiency are judged
against the same peaks.  The registry covers the targets the repo talks
about; unknown kinds fall back to the v5e numbers (the paper's target)
rather than crashing — an autotune cache records which kind it was
measured on, so a mismatched spec is visible, never silent.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class DeviceSpec:
    """Peak rates used as roofline denominators (bytes/s, FLOP/s)."""
    kind: str
    peak_flops: float            # bf16 matmul peak
    hbm_bw: float                # HBM bytes/s
    ici_bw: float                # per-link interconnect bytes/s
    host_bw: float               # host<->device link bytes/s

    def to_dict(self) -> dict:
        return asdict(self)


DEFAULT_DEVICE_KIND = "tpu_v5e"

DEVICE_SPECS: Dict[str, DeviceSpec] = {
    # paper target: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI,
    # 32 GB/s host link (the Eq. 3 constant)
    "tpu_v5e": DeviceSpec("tpu_v5e", 197e12, 819e9, 50e9, 32e9),
    "tpu_v5p": DeviceSpec("tpu_v5p", 459e12, 2765e9, 100e9, 32e9),
    "tpu_v4": DeviceSpec("tpu_v4", 275e12, 1228e9, 50e9, 32e9),
    # CPU interpret-mode runs: the peaks are nominal (one memory channel
    # class); efficiencies measured against them are tiny and honest
    "cpu": DeviceSpec("cpu", 1e12, 50e9, 10e9, 32e9),
}


def get_device_spec(kind: Optional[str] = None) -> DeviceSpec:
    """Spec for ``kind`` (default: the paper's TPU v5e target).  Unknown
    kinds fall back to the default spec's numbers under the asked-for
    name so cache keys still record what the caller believed it had."""
    if not kind:
        return DEVICE_SPECS[DEFAULT_DEVICE_KIND]
    spec = DEVICE_SPECS.get(kind)
    if spec is None:
        base = DEVICE_SPECS[DEFAULT_DEVICE_KIND]
        return DeviceSpec(kind, base.peak_flops, base.hbm_bw,
                          base.ici_bw, base.host_bw)
    return spec
