"""Roofline-driven block-config autotuner for the swap-path kernels.

For each ``(kernel, shape-bucket, dtype)`` the tuner measures every
variant in the kernel's :class:`~repro.kernels.autotune.space.KernelSpace`
and keeps the one with the highest achieved bytes/s; the entry records
the achieved fraction of the device's memory-bandwidth roofline
(``achieved_bps / DeviceSpec.hbm_bw`` — SNIPPETS-style
``efficiency = roofline / measured``).  Results land in the
:class:`~repro.kernels.autotune.cache.AutotuneCache`, so a warm cache
answers every later ``tune`` call with **zero** re-measurement
(``n_measured`` / ``n_cache_hits`` make that a testable counter, the
policystore restart pattern).

The measurement backend is a plain callable ``measure(fn) -> seconds``
so interpret-mode wall time (CPU CI) and real TPU timing use the same
harness.  Interpret-mode efficiencies are tiny — that is honest: the
number only has to *rank* variants and feed relative pricing.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro import obs
from repro.kernels.autotune.cache import AutotuneCache
from repro.kernels.autotune.device import DeviceSpec, get_device_spec
from repro.kernels.autotune.space import SPACES

HOST_LINK_KERNEL = "host_link"       # pseudo-kernel: measured link efficiency


def default_measure(fn: Callable[[], object], iters: int = 3) -> float:
    """Min-of-iters blocking wall time after one warmup call (min is the
    standard low-noise copy/kernel cost estimator — see
    ``HostMemTier.calibrate``)."""
    import jax
    jax.block_until_ready(fn())                    # warmup / compile
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


class Autotuner:
    def __init__(self, cache: Optional[AutotuneCache] = None,
                 spec: Optional[DeviceSpec] = None, *, iters: int = 3,
                 measure: Optional[Callable] = None):
        self.spec = spec or get_device_spec()
        self.cache = cache if cache is not None else AutotuneCache(
            device_kind=self.spec.kind)
        self.iters = iters
        self._measure = measure or (
            lambda fn: default_measure(fn, self.iters))
        self.n_measured = 0          # variant measurements actually run
        self.n_cache_hits = 0        # tune() calls answered from the cache

    # ------------------------------------------------------------- tuning
    def tune(self, kernel: str, shape: Optional[Sequence[int]] = None,
             dtype=np.float32) -> dict:
        """Winning config for ``(kernel, shape, dtype)`` — cached, or
        measured across the kernel's whole variant space."""
        space = SPACES[kernel]
        shape = tuple(shape or space.default_shape)
        hit = self.cache.get(kernel, shape, np.dtype(dtype))
        if hit is not None:
            self.n_cache_hits += 1
            return dict(hit["config"])
        args = space.make_args(shape, np.dtype(dtype))
        nbytes = space.bytes_moved(shape, np.dtype(dtype))
        best = None
        for config in space.variants:
            seconds = self._measure(lambda: space.run(args, config))
            self.n_measured += 1
            achieved = nbytes / seconds if seconds > 0 else 0.0
            if best is None or achieved > best["achieved_bps"]:
                best = {"config": dict(config), "achieved_bps": achieved,
                        "measured_s": seconds}
        best["bytes_moved"] = nbytes
        best["efficiency"] = min(best["achieved_bps"] / self.spec.hbm_bw,
                                 1.0)
        best["shape"] = list(shape)
        key = self.cache.put(kernel, shape, np.dtype(dtype), best)
        obs.audit().event("autotune.tuned", kernel=kernel, key=key,
                          config=best["config"],
                          efficiency=round(best["efficiency"], 6),
                          achieved_gbps=round(best["achieved_bps"] / 1e9,
                                              4))
        obs.metrics().gauge(f"kernel.efficiency.{kernel}",
                            best["efficiency"])
        return dict(best["config"])

    def tune_all(self, kernels: Optional[Sequence[str]] = None,
                 dtype=np.float32) -> dict:
        """Tune each named kernel at its default shape; returns
        kernel -> winning config."""
        out = {}
        for k in (kernels or tuple(SPACES)):
            out[k] = self.tune(k, dtype=dtype)
        return out

    # ------------------------------------------------ host-link efficiency
    def link_efficiency(self, bwmodel) -> float:
        """Measured asymptotic link bandwidth as a fraction of the spec's
        host-link peak.  Calibrated model: read the top of its curve
        (one cached entry — zero extra copies).  Uncalibrated: reuse a
        warm cache's stored value; otherwise 1.0 (the paper's nominal
        link, so untuned pricing is unchanged)."""
        stored = self.cache.entries.get(
            f"{HOST_LINK_KERNEL}|-|-|{self.cache.device_kind}")
        if bwmodel is None or not bwmodel.is_calibrated:
            if stored is not None:
                self.n_cache_hits += 1
                return float(stored["config"]["efficiency"])
            return 1.0
        curve = bwmodel.curve()
        size, _, gbps = curve[-1]          # asymptotic point of the sweep
        eff = min(max(gbps * 1e9 / self.spec.host_bw, 1e-3), 1.0)
        self.cache.entries[
            f"{HOST_LINK_KERNEL}|-|-|{self.cache.device_kind}"] = {
            "config": {"efficiency": eff},
            "achieved_bps": gbps * 1e9, "bytes_moved": int(size),
            "efficiency": eff, "shape": [int(size)]}
        obs.audit().event("autotune.link_efficiency",
                          efficiency=round(eff, 6),
                          achieved_gbps=round(gbps, 3),
                          peak_gbps=self.spec.host_bw / 1e9)
        obs.metrics().gauge("kernel.efficiency.host_link", eff)
        return eff

    def stats(self) -> dict:
        return {"n_measured": self.n_measured,
                "n_cache_hits": self.n_cache_hits,
                "device_kind": self.spec.kind,
                "cache": self.cache.stats()}
