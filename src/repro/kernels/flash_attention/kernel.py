"""Blockwise flash attention — Pallas TPU kernel.

TPU adaptation (not a CUDA port): the KV dimension is the *sequential* minor
grid axis; running (m, l, acc) statistics live in VMEM scratch that persists
across grid steps (the TPU analogue of a CUDA thread-block's registers/SMEM
accumulator).  Q/K/V tiles are MXU-aligned (128-multiple block sizes for
full tiles); GQA is handled in the K/V index_map (``h // group``), so grouped
query heads stream the same K/V tile without replication in HBM.

Causal masking skips fully-masked KV blocks with ``pl.when`` (no wasted MXU
work past the diagonal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                 acc_scr, *, causal: bool, sm_scale: float, block_q: int,
                 block_k: int, num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _body():
        q = q_ref[...].astype(jnp.float32) * sm_scale        # (bq, d)
        k = k_ref[...].astype(jnp.float32)                    # (bk, d)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq,bk)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(kpos < lens_ref[0, 0], s, NEG_INF)     # padded-KV mask
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip KV blocks entirely above the causal diagonal
        pl.when(ki * block_k <= (qi + 1) * block_q - 1)(_body)
    else:
        _body()

    @pl.when(ki == num_kv_blocks - 1)
    def _emit():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool, sm_scale: float,
                        block_q: int = 128, block_k: int = 128,
                        kv_lens=None, interpret: bool = False):
    """q (B, H, Sq, D); k/v (B, Kh, Sk, D); H % Kh == 0.  Returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    G = H // Kh
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _attn_kernel, causal=causal, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk)
    if kv_lens is None:
        kv_lens = jnp.full((B,), Sk, jnp.int32)
    lens2 = kv_lens.reshape(B, 1).astype(jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, qi, ki: (b, 0)),
            pl.BlockSpec((None, None, block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(lens2, q, k, v)


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, sm_scale: float, block_k: int,
                   num_kv_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32) * sm_scale        # (1, d)
    k = k_ref[...].astype(jnp.float32)                   # (bk, d)
    v = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (1,bk)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < lens_ref[0, 0], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _emit():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_fwd(q, k, v, lens, *, sm_scale: float, block_k: int = 128,
                     interpret: bool = False):
    """Single-token decode: q (B,H,1,D), k/v (B,Kh,Sk,D), lens (B,) valid
    lengths.  KV blocks stream through VMEM with a running-(m,l) merge —
    flash-decode structure, grid-sequential instead of warp-parallel."""
    B, H, _, D = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    G = H // Kh
    block_k = min(block_k, Sk)
    assert Sk % block_k == 0
    nk = Sk // block_k
    lens2 = lens.reshape(B, 1).astype(jnp.int32)
    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale,
                               block_k=block_k, num_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ki: (b, 0)),
            pl.BlockSpec((None, None, 1, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, 1, D),
                               lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(lens2, q, k, v)
