"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool, sm_scale: float,
                  lens=None):
    """q (B,H,Sq,D); k/v (B,Kh,Sk,D); optional lens (B,) valid KV lengths."""
    B, H, Sq, D = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    G = H // Kh
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * sm_scale, kf)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None], s, NEG_INF)
    if lens is not None:
        valid = jnp.arange(Sk)[None, :] < lens[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
