"""Jit'd public wrappers for the flash attention kernel.

Layout adapters: the model zoo uses (B, S, H, D); the kernel wants
(B, H, S, D).  Sequences are padded to block multiples; padded keys are
masked in-kernel via the per-batch ``kv_lens`` scalar.  The backward pass is
a custom_vjp that recomputes attention with the memory-efficient jnp
formulation (flash semantics: nothing quadratic is saved).  ``interpret``
defaults to True off-TPU so CPU tests execute the kernel body.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash(q, k, v, lens, causal, sm_scale, block_q, block_k):
    return K.flash_attention_fwd(q, k, v, causal=causal, sm_scale=sm_scale,
                                 block_q=block_q, block_k=block_k,
                                 kv_lens=lens,
                                 interpret=_default_interpret())


def _ref_attention(q, k, v, lens, causal, sm_scale):
    from repro.kernels.flash_attention.ref import attention_ref
    return attention_ref(q, k, v, causal=causal, sm_scale=sm_scale, lens=lens)


def _flash_fwd(q, k, v, lens, causal, sm_scale, block_q, block_k):
    out = _flash(q, k, v, lens, causal, sm_scale, block_q, block_k)
    return out, (q, k, v, lens)


def _flash_bwd(causal, sm_scale, block_q, block_k, res, g):
    q, k, v, lens = res
    _, vjp = jax.vjp(
        lambda q, k, v: _ref_attention(q, k, v, lens, causal, sm_scale),
        q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    sm_scale: Optional[float] = None,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None):
    """Model-layout entry point.  q (B,Sq,H,D); k/v (B,Sk,Kh,D).
    ``block_q``/``block_k`` default to the installed autotune table's
    winner for this shape (repro.kernels.autotune), else 128."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    if block_q is None or block_k is None:
        from repro.kernels.autotune.table import tuned_config
        cfg = tuned_config("flash_attention", q.shape, q.dtype) or {}
        block_q = block_q or int(cfg.get("block_q", 128))
        block_k = block_k or int(cfg.get("block_k", 128))
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    qt, sq0 = _pad_to(qt, 2, block_q)
    kt, sk0 = _pad_to(kt, 2, block_k)
    vt, _ = _pad_to(vt, 2, block_k)
    lens = jnp.full((B,), sk0, jnp.int32)
    out = _flash(qt, kt, vt, lens, causal, sm_scale,
                 min(block_q, qt.shape[2]), min(block_k, kt.shape[2]))
    out = out[:, :, :sq0, :]
    return jnp.swapaxes(out, 1, 2)


def flash_decode(q, k, v, lens, *, sm_scale: Optional[float] = None,
                 block_k: int = 128):
    """Decode entry point.  q (B,1,H,D); k/v (B,Smax,Kh,D); lens (B,)."""
    B, _, H, D = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    kt, sk0 = _pad_to(kt, 2, block_k)
    vt, _ = _pad_to(vt, 2, block_k)
    lens = jnp.minimum(lens.astype(jnp.int32), sk0)
    out = K.flash_decode_fwd(qt, kt, vt, lens, sm_scale=sm_scale,
                             block_k=min(block_k, kt.shape[2]),
                             interpret=_default_interpret())
    return jnp.swapaxes(out, 1, 2)
