"""Row-wise symmetric int8 quantize / dequantize — Pallas TPU kernels.

Beyond-paper compressed-swap mode (CSWAP-inspired): activations selected for
host offload cross the host link at 1/2 (bf16) or 1/4 (f32) width.  Rows are
the flattened leading dims; the scale is absmax/127 per row.  VPU-only
kernels (no MXU); block rows × full feature width tiles in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                  # (br, F)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (br, 1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s_ref[...]).astype(out_dtype)


def quantize_fwd(x2d, *, block_rows: int = 256, interpret: bool = False):
    """x2d (R, F) -> (int8 (R, F), scales (R, 1))."""
    R, F = x2d.shape
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    grid = (R // br,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, F), lambda r: (r, 0))],
        out_specs=[pl.BlockSpec((br, F), lambda r: (r, 0)),
                   pl.BlockSpec((br, 1), lambda r: (r, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, F), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(x2d)


def dequantize_fwd(q2d, scales, out_dtype, *, block_rows: int = 256,
                   interpret: bool = False):
    R, F = q2d.shape
    br = min(block_rows, R)
    assert R % br == 0
    kernel = functools.partial(_dequant_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, F), lambda r: (r, 0)),
                  pl.BlockSpec((br, 1), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((br, F), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, F), out_dtype),
        interpret=interpret,
    )(q2d, scales)
