"""Row-wise symmetric int8 quantize / dequantize — Pallas TPU kernels.

Beyond-paper compressed-swap mode (CSWAP-inspired): activations selected for
host offload cross the host link at 1/2 (bf16) or 1/4 (f32) width.  Rows are
the flattened leading dims; the scale is absmax/127 per row.  VPU-only
kernels (no MXU); block rows × full feature width tiles in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                  # (br, F)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (br, 1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s_ref[...]).astype(out_dtype)


def _pad_rows(x2d, block_rows: int):
    """Ragged row counts pad up to a whole number of blocks (each row is
    quantized independently, so zero-filled pad rows cannot leak into
    real rows); callers slice the pad back off."""
    R = x2d.shape[0]
    br = max(min(block_rows, R), 1)
    pad = (-R) % br
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    return x2d, br, R


def quantize_fwd(x2d, *, block_rows: int = 256, interpret: bool = False):
    """x2d (R, F) -> (int8 (R, F), scales (R, 1))."""
    R, F = x2d.shape
    x2d, br, _ = _pad_rows(x2d, block_rows)
    Rp = x2d.shape[0]
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((br, F), lambda r: (r, 0))],
        out_specs=[pl.BlockSpec((br, F), lambda r: (r, 0)),
                   pl.BlockSpec((br, 1), lambda r: (r, 0))],
        out_shape=[jax.ShapeDtypeStruct((Rp, F), jnp.int8),
                   jax.ShapeDtypeStruct((Rp, 1), jnp.float32)],
        interpret=interpret,
    )(x2d)
    return (q[:R], s[:R]) if Rp != R else (q, s)


def dequantize_fwd(q2d, scales, out_dtype, *, block_rows: int = 256,
                   interpret: bool = False):
    R, F = q2d.shape
    q2d, br, _ = _pad_rows(q2d, block_rows)
    Rp = q2d.shape[0]
    if Rp != R:
        scales = jnp.pad(scales, ((0, Rp - R), (0, 0)))
    kernel = functools.partial(_dequant_kernel, out_dtype=out_dtype)
    x = pl.pallas_call(
        kernel,
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((br, F), lambda r: (r, 0)),
                  pl.BlockSpec((br, 1), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((br, F), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, F), out_dtype),
        interpret=interpret,
    )(q2d, scales)
    return x[:R] if Rp != R else x
