"""Pure-jnp oracle for the quantize/dequantize kernels."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x2d):
    xf = x2d.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_ref(q2d, scales, out_dtype):
    return (q2d.astype(jnp.float32) * scales).astype(out_dtype)
