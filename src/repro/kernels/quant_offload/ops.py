"""Public wrappers + the compressed-offload site helper.

``compressed_offload(x, site)`` replaces the saved residual at a site with
its int8 row-quantized form: the quantized pair carries the site's
``checkpoint_name`` (so the swap policy offloads *it*), and the dequantize
is recomputed on the backward path.  Lossy (≤ 0.4% rel error per row);
disabled by default — enable with ChameleonConfig(offload_mode="compressed").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from repro.kernels.quant_offload import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to2d(x):
    F = x.shape[-1]
    R = int(x.size // F)
    return x.reshape(R, F), x.shape


def _tuned_block_rows(kernel: str, shape, dtype, default: int = 256) -> int:
    from repro.kernels.autotune.table import tuned_config
    cfg = tuned_config(kernel, shape, dtype)
    return int(cfg["block_rows"]) if cfg else default


def quantize(x, *, block_rows=None):
    """``block_rows=None`` consults the installed autotune table (see
    repro.kernels.autotune); kernel-level padding handles ragged R."""
    x2d, shape = _to2d(x)
    br = block_rows or _tuned_block_rows("quantize", x2d.shape, x2d.dtype)
    q, s = K.quantize_fwd(x2d, block_rows=br, interpret=_default_interpret())
    return q.reshape(shape), s.reshape(shape[:-1] + (1,))


def dequantize(q, scales, out_dtype, *, block_rows=None):
    q2d, shape = _to2d(q)
    s2d = scales.reshape(q2d.shape[0], 1)
    br = block_rows or _tuned_block_rows("dequantize", q2d.shape,
                                         jnp.dtype(out_dtype))
    x = K.dequantize_fwd(q2d, s2d, jnp.dtype(out_dtype), block_rows=br,
                         interpret=_default_interpret())
    return x.reshape(shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def compressed_offload(x, site: str):
    """Swap-compression boundary: forward value becomes dequant(quant(x));
    the int8 payload + scales carry the site name for the offload policy.
    Gradient is straight-through (the quantizer is a rounding identity)."""
    q, s = quantize(x)
    q = checkpoint_name(q, site)
    s = checkpoint_name(s, site)
    return dequantize(q, s, x.dtype)


def _co_fwd(x, site):
    return compressed_offload(x, site), None


def _co_bwd(site, _res, g):
    return (g,)


compressed_offload.defvjp(_co_fwd, _co_bwd)
