"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): the chunk axis is
the *sequential* minor grid dimension and the inter-chunk state (P × N) lives
in VMEM scratch carried across grid steps — where a CUDA implementation
would use a separate inter-chunk scan kernel + global-memory state passing,
the TPU grid's implicit sequentiality gives the recurrence for free and the
intra-chunk quadratic term maps straight onto the MXU.

Grid: (B, H, n_chunks).  Per step:
  y[c] = (C_c B_cᵀ ∘ L_c ∘ dt) x_c  +  (C_c · S) ∘ exp(cs)        (MXU)
  S    = S · exp(cs[-1]) + (x_c · dt · decay)ᵀ B_c                 (MXU)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[...].astype(jnp.float32)          # (cl, P)
    dt = dt_ref[...].astype(jnp.float32)        # (cl, 1)
    a = a_ref[0, 0]                              # scalar A_h (negative)
    Bm = b_ref[...].astype(jnp.float32)          # (cl, N)
    Cm = c_ref[...].astype(jnp.float32)          # (cl, N)

    dA = dt * a                                  # (cl, 1)
    cs = jnp.cumsum(dA, axis=0)                  # (cl, 1)
    # intra-chunk: masked decay matrix
    seg = cs - cs.T                              # (cl, cl) = cs_i - cs_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (cl, cl)
    M = CB * L * dt.T                            # (cl, cl) — dt_j on columns
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (cl, P)
    # inter-chunk contribution from carried state (P, N)
    y += jnp.exp(cs) * jax.lax.dot_general(
        Cm, state_scr[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                        # (cl, P)
    # state update
    decay_to_end = jnp.exp(cs[-1:] - cs)         # (cl, 1)
    xw = x * (dt * decay_to_end)                 # (cl, P)
    state_scr[...] = (state_scr[...] * jnp.exp(cs[-1])
                      + jax.lax.dot_general(
                          xw, Bm, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))     # (P, N)
    y_ref[...] = y.astype(y_ref.dtype)


def ssd_scan_fwd(x, dt, A, Bm, Cm, *, chunk: int, interpret: bool = False):
    """x (B,H,S,P) head-major; dt (B,H,S); A (H,); Bm/Cm (B,S,N).
    S must be a multiple of ``chunk``.  Returns y (B,H,S,P)."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    dt3 = dt[..., None]                                   # (B,H,S,1)
    a2 = jnp.broadcast_to(A.reshape(1, H), (B, H))
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((None, None, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
            pl.BlockSpec((None, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, chunk, P),
                               lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt3, a2, Bm, Cm)
