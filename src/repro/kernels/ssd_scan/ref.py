"""Pure-jnp oracle for the SSD scan kernel: the sequential recurrence
   S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_tᵀ ;  y_t = C_t · S_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm):
    """x (B,H,S,P); dt (B,H,S); A (H,); Bm/Cm (B,S,N) -> y (B,H,S,P)."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    def step(state, t):
        dA = jnp.exp(dtf[:, :, t] * A[None, :])            # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xf[:, :, t] * dtf[:, :, t, None],
                         Bf[:, t])
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Cf[:, t])
        return state, y

    init = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, init, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)          # (B,H,S,P)
