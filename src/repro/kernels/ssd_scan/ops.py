"""Jit'd wrapper for the SSD scan kernel (model layout adapters + padding)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=None):
    """Model layout: x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,N).
    ``chunk=None`` consults the installed autotune table, else 256."""
    B, S, H, P = x.shape
    if chunk is None:
        from repro.kernels.autotune.table import tuned_config
        cfg = tuned_config("ssd_scan", x.shape, x.dtype)
        chunk = int(cfg["chunk"]) if cfg else 256
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    xt = jnp.transpose(x, (0, 2, 1, 3))          # (B,H,S,P)
    dtt = jnp.transpose(dt, (0, 2, 1))           # (B,H,S)
    y = K.ssd_scan_fwd(xt, dtt, A, Bm, Cm, chunk=min(chunk, xt.shape[2]),
                       interpret=_default_interpret())
    y = jnp.transpose(y, (0, 2, 1, 3))[:, :S]
    return y
