"""Dynamic loss scaling (mixed-precision training).

The skip/update decision is made **host-side** (a Python branch), exactly
like PyTorch AMP: when gradients overflow, the optimizer dispatch is skipped
and the iteration's operator sequence *shortens* — the paper's primary
real-world source of varying operator sequences (§2.3).  The Chameleon
runtime observes the changed dispatch stream through its lightweight
profiler.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LossScaleState(NamedTuple):
    scale: jnp.ndarray          # f32 scalar
    growth_count: jnp.ndarray   # consecutive finite steps


def init_loss_scale(initial: float = 2.0 ** 15) -> LossScaleState:
    return LossScaleState(jnp.float32(initial), jnp.zeros((), jnp.int32))


def check_finite(grads) -> jnp.ndarray:
    leaves = [jnp.all(jnp.isfinite(g)) for g in jax.tree_util.tree_leaves(grads)]
    ok = leaves[0]
    for l in leaves[1:]:
        ok = jnp.logical_and(ok, l)
    return ok


def update_loss_scale(state: LossScaleState, finite: bool,
                      growth_interval: int = 200, factor: float = 2.0,
                      min_scale: float = 1.0) -> LossScaleState:
    """Host-side arithmetic (plain Python floats/bools)."""
    scale = float(state.scale)
    count = int(state.growth_count)
    if finite:
        count += 1
        if count >= growth_interval:
            scale *= factor
            count = 0
    else:
        scale = max(scale / factor, min_scale)
        count = 0
    return LossScaleState(jnp.float32(scale), jnp.int32(count))
