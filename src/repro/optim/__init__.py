from repro.optim.adamw import AdamWState, adamw_init, adamw_update, opt_state_axes  # noqa: F401
from repro.optim.loss_scale import LossScaleState, init_loss_scale, check_finite, update_loss_scale  # noqa: F401
from repro.optim.schedules import warmup_cosine  # noqa: F401
