"""AdamW in pure JAX with ZeRO-style optimizer-state sharding.

ZeRO stages map to sharding specs, not different math:
  stage 0: m/v replicated like params
  stage 1/2: m/v (and fp32 master copy) sharded across the `data` axis —
             grads arrive reduce-scattered by XLA because the update's
             output sharding demands it (the compiler fuses the RS into the
             backward collective schedule)
  stage 3: parameters themselves carry a data-axis (fsdp) sharding dim
           (see distributed.sharding "fsdp_embed" rule)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any            # fp32 master params (None when params are fp32)


def _needs_master(params) -> bool:
    return any(x.dtype != jnp.float32
               for x in jax.tree_util.tree_leaves(params))


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if _needs_master(params) else None)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros), master)


def opt_state_axes(param_axes, zero_stage: int):
    """Mirror of the params' logical-axes tree for m/v/master.  For ZeRO>=1
    the first shardable dim additionally maps to the data axis via the
    'fsdp_embed' rule (applied by the caller's rules override)."""
    return AdamWState(
        ("scalar",),
        param_axes,
        param_axes,
        param_axes,
    )


def adamw_update(params, grads, state: AdamWState, cfg: TrainConfig,
                 lr: jnp.ndarray):
    b1, b2, eps = 0.9, 0.95, 1e-8
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, pm):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m / c1
        vhat = v / c2
        base = pm if pm is not None else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + eps)
                           + cfg.weight_decay * base)
        return new.astype(p.dtype), m, v, new

    if state.master is not None:
        out = jax.tree.map(upd, params, grads, state.m, state.v, state.master)
    else:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                           params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_master = (jax.tree.map(lambda t: t[3], out,
                               is_leaf=lambda x: isinstance(x, tuple))
                  if state.master is not None else None)
    return new_params, AdamWState(step, new_m, new_v, new_master)


def global_norm(grads) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    # multiply in the gradient's own dtype: a f32 upcast here materializes
    # (and all-reduces) f32 copies of every gradient (§Perf cell B iter 2)
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
