"""LSH band-bucket index over MinHash signatures (repro.policystore).

``PolicyStore.nearest`` used to score every record against the query —
O(records) Python similarity calls per lookup, an open ROADMAP item once
stores grow past ~1k records.  This index applies the standard banding
scheme: a ``n_perms``-slot MinHash signature is split into ``n_bands``
bands of ``rows`` slots each; two signatures land in the same bucket for
a band iff that band's slots are identical.  A pair with Jaccard
similarity ``j`` collides in at least one band with probability
``1 - (1 - j^rows)^n_bands`` — with the default 16 bands x 4 rows a
reuse-grade pair (j >= 0.8) is found with probability > 0.999998, while
unrelated records almost never collide, so a probe touches a handful of
records instead of the whole store.

Band hashes are 8-byte blake2b digests of the band's raw slot bytes —
stable across processes (``hash()`` is salted per interpreter), so the
index can be persisted next to the JSON records and reloaded.  Every
record is indexed under both of its fingerprints (prepare + iteration).

The index is *recall-oriented, not authoritative*: the store treats a
probe as a shortcut and falls back to a vectorized bounded scan when the
probe yields nothing reuse-grade (see ``store.nearest``), so a missed
collision can cost time, never a wrong answer.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable, List, Set, Tuple

import numpy as np

INDEX_SCHEMA = 1


def _band_digest(band_bytes: bytes) -> int:
    return int.from_bytes(
        hashlib.blake2b(band_bytes, digest_size=8).digest(), "little")


class LSHIndex:
    """Band-bucket index: key -> band digests, (band, digest) -> keys."""

    def __init__(self, n_perms: int, n_bands: int):
        self.n_perms = int(n_perms)
        self.n_bands = max(1, min(int(n_bands), self.n_perms))
        self.rows = max(1, self.n_perms // self.n_bands)
        self._buckets: Dict[Tuple[int, int], Set[str]] = {}
        self._entries: Dict[str, List[int]] = {}   # key -> digests (flat)
        self.n_queries = 0
        self.n_candidates = 0                      # keys returned by queries

    # ------------------------------------------------------------ hashing
    def band_digests(self, sig: np.ndarray) -> List[int]:
        sig = np.ascontiguousarray(sig[: self.n_bands * self.rows], np.int64)
        if sig.size < self.n_bands * self.rows:    # foreign perm count:
            return []                              # unindexable, scan finds it
        bands = sig.reshape(self.n_bands, self.rows)
        return [_band_digest(bands[b].tobytes()) for b in range(self.n_bands)]

    # ------------------------------------------------------------ updates
    def add(self, key: str, sigs: Iterable[np.ndarray]) -> None:
        digests: List[int] = []
        for sig in sigs:
            digests.extend(self.band_digests(np.asarray(sig)))
        self.add_digests(key, digests)

    def add_digests(self, key: str, digests: List[int]) -> None:
        if key in self._entries:
            self.remove(key)
        self._entries[key] = list(digests)
        for b, d in enumerate(digests):
            self._buckets.setdefault((b % self.n_bands, d), set()).add(key)

    def remove(self, key: str) -> None:
        digests = self._entries.pop(key, None)
        if digests is None:
            return
        for b, d in enumerate(digests):
            bucket = self._buckets.get((b % self.n_bands, d))
            if bucket is None:
                continue
            bucket.discard(key)
            if not bucket:
                del self._buckets[(b % self.n_bands, d)]

    def clear(self) -> None:
        self._buckets.clear()
        self._entries.clear()

    # ------------------------------------------------------------- lookup
    def query(self, sig: np.ndarray) -> Set[str]:
        """Keys sharing at least one band bucket with ``sig``."""
        self.n_queries += 1
        out: Set[str] = set()
        for b, d in enumerate(self.band_digests(np.asarray(sig))):
            hit = self._buckets.get((b, d))
            if hit:
                out.update(hit)
        self.n_candidates += len(out)
        return out

    # ------------------------------------------------------ serialization
    def to_json(self) -> dict:
        return {
            "schema": INDEX_SCHEMA,
            "n_perms": self.n_perms,
            "n_bands": self.n_bands,
            "entries": {k: [str(d) for d in v]      # JSON has no int64
                        for k, v in self._entries.items()},
        }

    @classmethod
    def from_json(cls, d: dict) -> "LSHIndex":
        if d.get("schema") != INDEX_SCHEMA:
            raise ValueError(f"index schema {d.get('schema')!r}")
        idx = cls(int(d["n_perms"]), int(d["n_bands"]))
        for key, digests in d["entries"].items():
            idx.add_digests(key, [int(x) for x in digests])
        return idx

    # --------------------------------------------------------------- misc
    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> Set[str]:
        return set(self._entries)

    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "buckets": len(self._buckets),
            "bands": self.n_bands,
            "rows": self.rows,
            "queries": self.n_queries,
            "candidates": self.n_candidates,
        }
