"""repro.policystore — persistent policy cache with op-sequence
fingerprinting and tiered drift response.

Chameleon's stage machine treats every significant sequence change the
same way: WarmUp from scratch, then a fresh five-variant GenPolicy
search.  For *recurring* sequences (train→eval→train interleaves,
seq-len bucket cycling, periodic routing shifts) that adaptation tax is
pure waste — the policy that worked last time still works, it just needs
to be found and re-associated.  This package turns adaptation from
O(regen) into O(lookup):

  * :mod:`fingerprint` — drift-tolerant sketches of tokenized op streams
    (exact hash + shingled MinHash + aggregate features) with a
    calibrated similarity metric;
  * :mod:`store` — a versioned, corruption-safe LRU store (in-memory +
    optional on-disk JSON) mapping fingerprints to serialized policies,
    their measured iteration times, and the bandwidth snapshot they were
    priced under;
  * :mod:`drift` — the three-tier classifier routing an observed
    sequence to reuse / warm-start / regen.

Wired into :class:`~repro.core.runtime.ChameleonRuntime` (see
``docs/policystore.md``); the same store directory can be shared across
processes and restarts.
"""
from __future__ import annotations

from repro.policystore.drift import (DriftClassifier, DriftDecision, Tier,
                                     bandwidth_drift)
from repro.policystore.fingerprint import (Fingerprint,
                                           clear_fingerprint_cache,
                                           fingerprint_profile,
                                           fingerprint_signature,
                                           fingerprint_tokens,
                                           jaccard_estimate, length_ratio,
                                           minhash_signature, similarity)
from repro.policystore.lshindex import LSHIndex
from repro.policystore.store import (SCHEMA_VERSION, PolicyRecord,
                                     PolicyStore)

__all__ = [
    "DriftClassifier", "DriftDecision", "Fingerprint", "LSHIndex",
    "PolicyRecord", "PolicyStore", "SCHEMA_VERSION", "Tier",
    "bandwidth_drift", "clear_fingerprint_cache", "fingerprint_profile",
    "fingerprint_signature", "fingerprint_tokens", "jaccard_estimate",
    "length_ratio", "minhash_signature", "similarity",
]
