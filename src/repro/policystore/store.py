"""Persistent fingerprint-keyed policy store (repro.policystore).

One :class:`PolicyRecord` is everything a later adaptation needs to avoid
a cold GenPolicy cycle for a recurring op sequence:

  * the two fingerprints it is reachable by — the **prepare** fingerprint
    (the profiled train-step stream, exact-hit on process cold start) and
    the **iteration** fingerprint (the full dispatch-sequence signature,
    matched by similarity on mid-run drift);
  * the serialized :class:`~repro.core.policy.SwapPolicy` entries plus
    the candidate instances of the profile it was generated from (what
    ``core/matching.py`` needs to re-associate entries with a retraced
    program);
  * the winning grouping knob and its measured ``T_iter`` (what seeds a
    warm-started variant search);
  * a snapshot of the bandwidth-model curve it was priced under (what
    the drift guards compare against the live link before trusting the
    cached schedule).

The :class:`PolicyStore` keeps records in an in-memory LRU and, when a
directory is configured, mirrors each record to one JSON file
(``<key>.json``, atomic tmp+rename writes).  Loads are corruption-safe —
an unreadable or schema-incompatible file is skipped and counted, never
fatal — and eviction removes the disk file with the memory entry.
"""
from __future__ import annotations

import collections
import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.policystore.fingerprint import Fingerprint, similarity

SCHEMA_VERSION = 1

_ENTRY_FIELDS = ("uid", "site", "layer", "nbytes", "birth", "death",
                 "swap_in_op", "swap_out_done_op", "stalled", "score")
_CAND_FIELDS = ("uid", "nbytes", "birth", "death", "site", "layer",
                "dtype_code", "shape", "producer_token")


class _ProfileStub:
    """The slice of ProfileData that ``core.matching`` reads: candidate
    instances plus the op count (for position bucketing)."""

    def __init__(self, candidates, n_ops: int):
        self.candidates = candidates
        self.n_ops = n_ops


@dataclass
class PolicyRecord:
    key: str                               # prepare-fingerprint exact hash
    fingerprint: Fingerprint               # iteration-sequence signature
    prepare_fingerprint: Fingerprint       # profiled train-step stream
    entries: List[dict] = field(default_factory=list)
    # what the adaptation winner was: "swap" (entries carry the schedule),
    # "baseline" (fit without swapping — re-verified against the observed
    # timeline before reuse), or "conservative" (offload-all fallback —
    # always safe to reapply)
    policy_kind: str = "swap"
    policy_meta: dict = field(default_factory=dict)
    candidates: List[dict] = field(default_factory=list)
    n_ops: int = 0
    knob: float = 1.0
    measured_t: float = 0.0
    budget: int = 0
    bw_constant_gbps: float = 0.0
    bw_curve: List[Tuple[int, float]] = field(default_factory=list)
    created: float = 0.0
    uses: int = 0

    # ------------------------------------------------------ construction
    @classmethod
    def from_policy(cls, *, fingerprint: Fingerprint,
                    prepare_fingerprint: Fingerprint, swap, candidates,
                    n_ops: int, knob: float, measured_t: float, budget: int,
                    bwmodel=None, policy_kind: str = "swap") -> "PolicyRecord":
        import numbers

        def _plain(v):
            if isinstance(v, bool) or v is None or isinstance(v, str):
                return v
            if isinstance(v, numbers.Integral):
                return int(v)           # numpy ints -> JSON-safe
            return float(v)

        entries = []
        meta: dict = {}
        if swap is not None:
            entries = [{f: _plain(getattr(e, f)) for f in _ENTRY_FIELDS}
                       for e in swap.entries]
            meta = {"projected_peak": int(swap.projected_peak),
                    "baseline_peak": int(swap.baseline_peak),
                    "budget": int(swap.budget),
                    "stall_time": float(swap.stall_time),
                    "t_iter": float(swap.t_iter), "n_ops": int(swap.n_ops),
                    "contention_s": float(swap.contention_s)}
        cands = [{f: ([int(d) for d in getattr(t, f)] if f == "shape"
                      else _plain(getattr(t, f))) for f in _CAND_FIELDS}
                 for t in candidates]
        curve: List[Tuple[int, float]] = []
        gbps = 0.0
        if bwmodel is not None:
            curve = [(int(s), float(t)) for s, t, _gbps in bwmodel.curve()]
            gbps = float(bwmodel.constant_gbps)
        return cls(key=prepare_fingerprint.exact, fingerprint=fingerprint,
                   prepare_fingerprint=prepare_fingerprint, entries=entries,
                   policy_kind=("swap" if entries else policy_kind),
                   policy_meta=meta, candidates=cands, n_ops=int(n_ops),
                   knob=float(knob), measured_t=float(measured_t),
                   budget=int(budget), bw_constant_gbps=gbps,
                   bw_curve=curve, created=time.time())

    # -------------------------------------------------------- reanimation
    def swap_policy(self):
        """Rebuild the stored SwapPolicy (None when the cached adaptation
        concluded the baseline fits without swapping)."""
        if not self.entries:
            return None
        from repro.core.policy import SwapPolicy
        from repro.core.simulator import PolicyEntry
        entries = [PolicyEntry(**{f: e[f] for f in _ENTRY_FIELDS})
                   for e in self.entries]
        m = self.policy_meta
        return SwapPolicy(entries, m.get("projected_peak", 0),
                          m.get("baseline_peak", 0),
                          m.get("budget", self.budget),
                          m.get("stall_time", 0.0), m.get("t_iter", 0.0),
                          m.get("n_ops", self.n_ops),
                          contention_s=m.get("contention_s", 0.0))

    def profile_stub(self) -> _ProfileStub:
        from repro.core.profiler import TensorInstance
        cands = [TensorInstance(
            uid=c["uid"], nbytes=c["nbytes"], birth=c["birth"],
            death=c["death"], site=c["site"], layer=c["layer"],
            dtype_code=c["dtype_code"], shape=tuple(c["shape"]),
            producer_token=c.get("producer_token", 0))
            for c in self.candidates]
        return _ProfileStub(cands, self.n_ops)

    # ------------------------------------------------------ serialization
    def to_json(self) -> dict:
        return {
            "schema": SCHEMA_VERSION,
            "key": self.key,
            "fingerprint": self.fingerprint.to_dict(),
            "prepare_fingerprint": self.prepare_fingerprint.to_dict(),
            "entries": self.entries,
            "policy_kind": self.policy_kind,
            "policy_meta": self.policy_meta,
            "candidates": self.candidates,
            "n_ops": self.n_ops,
            "knob": self.knob,
            "measured_t": self.measured_t,
            "budget": self.budget,
            "bw_constant_gbps": self.bw_constant_gbps,
            "bw_curve": [[s, t] for s, t in self.bw_curve],
            "created": self.created,
            "uses": self.uses,
        }

    @classmethod
    def from_json(cls, d: dict) -> "PolicyRecord":
        if d.get("schema") != SCHEMA_VERSION:
            raise ValueError(f"schema {d.get('schema')!r} != {SCHEMA_VERSION}")
        return cls(key=d["key"],
                   fingerprint=Fingerprint.from_dict(d["fingerprint"]),
                   prepare_fingerprint=Fingerprint.from_dict(
                       d["prepare_fingerprint"]),
                   entries=list(d.get("entries", [])),
                   policy_kind=str(d.get("policy_kind", "swap")),
                   policy_meta=dict(d.get("policy_meta", {})),
                   candidates=list(d.get("candidates", [])),
                   n_ops=int(d.get("n_ops", 0)),
                   knob=float(d.get("knob", 1.0)),
                   measured_t=float(d.get("measured_t", 0.0)),
                   budget=int(d.get("budget", 0)),
                   bw_constant_gbps=float(d.get("bw_constant_gbps", 0.0)),
                   bw_curve=[(int(s), float(t))
                             for s, t in d.get("bw_curve", [])],
                   created=float(d.get("created", 0.0)),
                   uses=int(d.get("uses", 0)))


class PolicyStore:
    """In-memory LRU over :class:`PolicyRecord`, optionally mirrored to a
    directory of JSON files (one per record, named by key)."""

    def __init__(self, cfg, readonly: bool = False):
        self.cfg = cfg
        self.dir: Optional[str] = cfg.dir or None
        # read-only attach (e.g. a serving process inspecting a trainer's
        # store): never writes, never deletes — in particular a shared dir
        # holding more than max_records must not lose records to this
        # reader's load-time eviction
        self.readonly = readonly
        self.max_records = max(int(cfg.max_records), 1)
        self._records: "collections.OrderedDict[str, PolicyRecord]" = \
            collections.OrderedDict()
        self.n_lookups = self.n_exact_hits = self.n_sim_hits = 0
        self.n_misses = self.n_evictions = 0
        self.n_loaded = self.n_corrupt = 0
        if self.dir:
            self._load_dir()

    # ----------------------------------------------------------- loading
    def _load_dir(self) -> None:
        try:
            os.makedirs(self.dir, exist_ok=True)
            names = [n for n in os.listdir(self.dir) if n.endswith(".json")]
        except OSError:
            self.n_corrupt += 1
            return
        paths = [os.path.join(self.dir, n) for n in names]
        # oldest-modified first, so insertion order doubles as LRU order
        paths.sort(key=lambda p: (os.path.getmtime(p)
                                  if os.path.exists(p) else 0.0))
        for path in paths:
            try:
                with open(path) as f:
                    rec = PolicyRecord.from_json(json.load(f))
            except (OSError, ValueError, KeyError, TypeError,
                    json.JSONDecodeError):
                self.n_corrupt += 1
                continue
            self._records[rec.key] = rec
            self.n_loaded += 1
        self._evict_over_capacity()

    # ------------------------------------------------------------ writes
    def _path(self, key: str) -> str:
        return os.path.join(self.dir, f"{key}.json")

    def _persist(self, rec: PolicyRecord) -> None:
        if not self.dir or self.readonly:
            return
        os.makedirs(self.dir, exist_ok=True)
        tmp = self._path(rec.key) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec.to_json(), f)
        os.replace(tmp, self._path(rec.key))

    def _evict_over_capacity(self) -> None:
        while len(self._records) > self.max_records:
            key, _ = self._records.popitem(last=False)
            self.n_evictions += 1
            if self.dir and not self.readonly:
                try:
                    os.remove(self._path(key))
                except OSError:
                    pass

    def put(self, rec: PolicyRecord) -> None:
        self._records[rec.key] = rec
        self._records.move_to_end(rec.key)
        self._evict_over_capacity()
        self._persist(rec)

    def touch(self, rec: PolicyRecord) -> None:
        """Record a use: bumps LRU recency and the use counter.  The disk
        side only needs its mtime refreshed (restart LRU order follows
        mtime) — rewriting the whole record per hit would serialize every
        candidate on every reuse; the ``uses`` counter is informational
        and flushed whenever the record is next ``put``."""
        rec.uses += 1
        if rec.key in self._records:
            self._records.move_to_end(rec.key)
        if self.dir and not self.readonly:
            try:
                os.utime(self._path(rec.key), None)
            except OSError:
                self._persist(rec)          # file vanished: restore it

    # ------------------------------------------------------------ lookup
    def get_exact(self, key: str) -> Optional[PolicyRecord]:
        return self._records.get(key)

    def nearest(self, fp: Fingerprint) -> Tuple[Optional[PolicyRecord], float]:
        """Best-matching record and its calibrated similarity: each record
        is reachable through either of its two fingerprints (max taken).
        A best match below the warm-start floor is counted as a miss —
        it cannot influence adaptation, so reporting it as a hit would
        make a never-matching cache look warm."""
        self.n_lookups += 1
        hit = self._records.get(fp.exact)   # O(1) fast path (keys are
        if hit is not None:                 # prepare-fingerprint hashes)
            self.n_exact_hits += 1
            return hit, 1.0
        best: Optional[PolicyRecord] = None
        best_sim = 0.0
        for rec in self._records.values():
            sim = max(similarity(fp, rec.prepare_fingerprint),
                      similarity(fp, rec.fingerprint))
            if sim > best_sim or best is None:
                best, best_sim = rec, sim
        floor = getattr(self.cfg, "warm_threshold", 0.0)
        if best is None or best_sim < floor:
            self.n_misses += 1
        elif best_sim >= 1.0:
            self.n_exact_hits += 1
        else:
            self.n_sim_hits += 1
        return best, best_sim

    # ------------------------------------------------------------- misc
    def __len__(self) -> int:
        return len(self._records)

    def records(self) -> List[PolicyRecord]:
        return list(self._records.values())

    def stats(self) -> dict:
        return {
            "records": len(self._records),
            "dir": self.dir or "",
            "lookups": self.n_lookups,
            "exact_hits": self.n_exact_hits,
            "sim_hits": self.n_sim_hits,
            "misses": self.n_misses,
            "evictions": self.n_evictions,
            "loaded": self.n_loaded,
            "corrupt_skipped": self.n_corrupt,
        }
