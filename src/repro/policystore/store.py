"""Persistent fingerprint-keyed policy store (repro.policystore).

One :class:`PolicyRecord` is everything a later adaptation needs to avoid
a cold GenPolicy cycle for a recurring op sequence:

  * the two fingerprints it is reachable by — the **prepare** fingerprint
    (the profiled train-step stream, exact-hit on process cold start) and
    the **iteration** fingerprint (the full dispatch-sequence signature,
    matched by similarity on mid-run drift);
  * the serialized :class:`~repro.core.policy.SwapPolicy` entries plus
    the candidate instances of the profile it was generated from (what
    ``core/matching.py`` needs to re-associate entries with a retraced
    program);
  * the winning grouping knob and its measured ``T_iter`` (what seeds a
    warm-started variant search);
  * a snapshot of the bandwidth-model curve it was priced under (what
    the drift guards compare against the live link before trusting the
    cached schedule).

The :class:`PolicyStore` keeps records in an in-memory LRU and, when a
directory is configured, mirrors each record to one JSON file
(``<key>.json``, atomic tmp+rename writes).  Loads are corruption-safe —
an unreadable or schema-incompatible file is skipped and counted, never
fatal — and eviction removes the disk file with the memory entry.

``nearest`` is sublinear: an LSH band-bucket index over the MinHash
signatures (``lshindex.py``, persisted as ``lsh.index`` next to the
records and rebuilt when missing, corrupt, or out of sync) shortlists
probable matches; only when the probe finds nothing reuse-grade does a
vectorized fallback run — one numpy pass computes a per-record *upper
bound* on the calibrated similarity, and exact scoring proceeds in
decreasing-bound order, stopping as soon as the bound cannot beat the
best hit.  The bound is tight: the operator-histogram and site-byte
cosines are evaluated exactly as dense matrix products over the bounded
token/site vocabularies (rows normalized once, rebuilt lazily after
mutations), so the per-row bound *equals* the blended score up to
rounding — a true miss scores O(1) records after the vectorized pass
instead of falling back to O(records) scalar evaluations.  Rows whose
histogram overflows the vocab cap keep the old optimistic constant (the
bound must stay an upper bound).  The result is identical to the
exhaustive scan whenever the exhaustive best is below the reuse
threshold, and reuse-grade otherwise; ``n_sim_evals`` counts full
similarity evaluations so tests can assert probe work ≪ records —
``nearest_exhaustive`` stays as the parity oracle.

The store is thread-safe (one re-entrant lock around record/index/row
state): the training thread and the repro.adapt background worker both
read and write it.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import faults, obs
from repro.policystore.fingerprint import Fingerprint, similarity
from repro.policystore.lshindex import LSHIndex

SCHEMA_VERSION = 1

_ENTRY_FIELDS = ("uid", "site", "layer", "nbytes", "birth", "death",
                 "swap_in_op", "swap_out_done_op", "stalled", "score")
_CAND_FIELDS = ("uid", "nbytes", "birth", "death", "site", "layer",
                "dtype_code", "shape", "producer_token")


class _ProfileStub:
    """The slice of ProfileData that ``core.matching`` reads: candidate
    instances plus the op count (for position bucketing)."""

    def __init__(self, candidates, n_ops: int):
        self.candidates = candidates
        self.n_ops = n_ops


@dataclass
class PolicyRecord:
    key: str                               # prepare-fingerprint exact hash
    fingerprint: Fingerprint               # iteration-sequence signature
    prepare_fingerprint: Fingerprint       # profiled train-step stream
    entries: List[dict] = field(default_factory=list)
    # what the adaptation winner was: "swap" (entries carry the schedule),
    # "baseline" (fit without swapping — re-verified against the observed
    # timeline before reuse), or "conservative" (offload-all fallback —
    # always safe to reapply)
    policy_kind: str = "swap"
    policy_meta: dict = field(default_factory=dict)
    candidates: List[dict] = field(default_factory=list)
    n_ops: int = 0
    knob: float = 1.0
    measured_t: float = 0.0
    budget: int = 0
    bw_constant_gbps: float = 0.0
    bw_curve: List[Tuple[int, float]] = field(default_factory=list)
    created: float = 0.0
    uses: int = 0

    # ------------------------------------------------------ construction
    @classmethod
    def from_policy(cls, *, fingerprint: Fingerprint,
                    prepare_fingerprint: Fingerprint, swap, candidates,
                    n_ops: int, knob: float, measured_t: float, budget: int,
                    bwmodel=None, policy_kind: str = "swap") -> "PolicyRecord":
        import numbers

        def _plain(v):
            if isinstance(v, bool) or v is None or isinstance(v, str):
                return v
            if isinstance(v, numbers.Integral):
                return int(v)           # numpy ints -> JSON-safe
            return float(v)

        entries = []
        meta: dict = {}
        if swap is not None:
            entries = [{f: _plain(getattr(e, f)) for f in _ENTRY_FIELDS}
                       for e in swap.entries]
            meta = {"projected_peak": int(swap.projected_peak),
                    "baseline_peak": int(swap.baseline_peak),
                    "budget": int(swap.budget),
                    "stall_time": float(swap.stall_time),
                    "t_iter": float(swap.t_iter), "n_ops": int(swap.n_ops),
                    "contention_s": float(swap.contention_s),
                    "occupancy": float(getattr(swap, "occupancy", 0.0))}
        cands = [{f: ([int(d) for d in getattr(t, f)] if f == "shape"
                      else _plain(getattr(t, f))) for f in _CAND_FIELDS}
                 for t in candidates]
        curve: List[Tuple[int, float]] = []
        gbps = 0.0
        if bwmodel is not None:
            curve = [(int(s), float(t)) for s, t, _gbps in bwmodel.curve()]
            gbps = float(bwmodel.constant_gbps)
        return cls(key=prepare_fingerprint.exact, fingerprint=fingerprint,
                   prepare_fingerprint=prepare_fingerprint, entries=entries,
                   policy_kind=("swap" if entries else policy_kind),
                   policy_meta=meta, candidates=cands, n_ops=int(n_ops),
                   knob=float(knob), measured_t=float(measured_t),
                   budget=int(budget), bw_constant_gbps=gbps,
                   bw_curve=curve, created=time.time())

    # -------------------------------------------------------- reanimation
    def swap_policy(self):
        """Rebuild the stored SwapPolicy (None when the cached adaptation
        concluded the baseline fits without swapping)."""
        if not self.entries:
            return None
        from repro.core.policy import SwapPolicy
        from repro.core.simulator import PolicyEntry
        entries = [PolicyEntry(**{f: e[f] for f in _ENTRY_FIELDS})
                   for e in self.entries]
        m = self.policy_meta
        return SwapPolicy(entries, m.get("projected_peak", 0),
                          m.get("baseline_peak", 0),
                          m.get("budget", self.budget),
                          m.get("stall_time", 0.0), m.get("t_iter", 0.0),
                          m.get("n_ops", self.n_ops),
                          contention_s=m.get("contention_s", 0.0),
                          occupancy=m.get("occupancy", 0.0))

    def profile_stub(self) -> _ProfileStub:
        from repro.core.profiler import TensorInstance
        cands = [TensorInstance(
            uid=c["uid"], nbytes=c["nbytes"], birth=c["birth"],
            death=c["death"], site=c["site"], layer=c["layer"],
            dtype_code=c["dtype_code"], shape=tuple(c["shape"]),
            producer_token=c.get("producer_token", 0))
            for c in self.candidates]
        return _ProfileStub(cands, self.n_ops)

    # ------------------------------------------------------ serialization
    def to_json(self) -> dict:
        return {
            "schema": SCHEMA_VERSION,
            "key": self.key,
            "fingerprint": self.fingerprint.to_dict(),
            "prepare_fingerprint": self.prepare_fingerprint.to_dict(),
            "entries": self.entries,
            "policy_kind": self.policy_kind,
            "policy_meta": self.policy_meta,
            "candidates": self.candidates,
            "n_ops": self.n_ops,
            "knob": self.knob,
            "measured_t": self.measured_t,
            "budget": self.budget,
            "bw_constant_gbps": self.bw_constant_gbps,
            "bw_curve": [[s, t] for s, t in self.bw_curve],
            "created": self.created,
            "uses": self.uses,
        }

    @classmethod
    def from_json(cls, d: dict) -> "PolicyRecord":
        if d.get("schema") != SCHEMA_VERSION:
            raise ValueError(f"schema {d.get('schema')!r} != {SCHEMA_VERSION}")
        return cls(key=d["key"],
                   fingerprint=Fingerprint.from_dict(d["fingerprint"]),
                   prepare_fingerprint=Fingerprint.from_dict(
                       d["prepare_fingerprint"]),
                   entries=list(d.get("entries", [])),
                   policy_kind=str(d.get("policy_kind", "swap")),
                   policy_meta=dict(d.get("policy_meta", {})),
                   candidates=list(d.get("candidates", [])),
                   n_ops=int(d.get("n_ops", 0)),
                   knob=float(d.get("knob", 1.0)),
                   measured_t=float(d.get("measured_t", 0.0)),
                   budget=int(d.get("budget", 0)),
                   bw_constant_gbps=float(d.get("bw_constant_gbps", 0.0)),
                   bw_curve=[(int(s), float(t))
                             for s, t in d.get("bw_curve", [])],
                   created=float(d.get("created", 0.0)),
                   uses=int(d.get("uses", 0)))


class PolicyStore:
    """In-memory LRU over :class:`PolicyRecord`, optionally mirrored to a
    directory of JSON files (one per record, named by key)."""

    def __init__(self, cfg, readonly: bool = False):
        self.cfg = cfg
        self.dir: Optional[str] = cfg.dir or None
        # read-only attach (e.g. a serving process inspecting a trainer's
        # store): never writes, never deletes — in particular a shared dir
        # holding more than max_records must not lose records to this
        # reader's load-time eviction
        self.readonly = readonly
        self.max_records = max(int(cfg.max_records), 1)
        self._records: "collections.OrderedDict[str, PolicyRecord]" = \
            collections.OrderedDict()
        self.n_lookups = self.n_exact_hits = self.n_sim_hits = 0
        self.n_misses = self.n_evictions = 0
        self.n_loaded = self.n_corrupt = 0
        self.n_sim_evals = self.n_index_rebuilds = 0
        self.n_io_errors = 0
        self.index = LSHIndex(int(getattr(cfg, "minhash_perms", 64)),
                              int(getattr(cfg, "lsh_bands", 16)))
        self._rows_dirty = True
        self._index_dirty_puts = 0
        # training thread + adaptation worker (repro.adapt) share the
        # store; re-entrant because classify->nearest and the runtime's
        # touch can nest through the same thread's call chain
        self._lock = threading.RLock()
        if self.dir:
            self._load_dir()
            self._attach_index()

    # ----------------------------------------------------------- loading
    def _load_dir(self) -> None:
        try:
            os.makedirs(self.dir, exist_ok=True)
            names = [n for n in os.listdir(self.dir) if n.endswith(".json")]
        except OSError:
            self.n_corrupt += 1
            return
        paths = [os.path.join(self.dir, n) for n in names]
        # oldest-modified first, so insertion order doubles as LRU order
        paths.sort(key=lambda p: (os.path.getmtime(p)
                                  if os.path.exists(p) else 0.0))
        for path in paths:
            try:
                if faults.inject("store.load",
                                 key=os.path.basename(path)) is not None:
                    raise ValueError("injected corrupt record at load")
                with open(path) as f:
                    rec = PolicyRecord.from_json(json.load(f))
            except (OSError, ValueError, KeyError, TypeError,
                    json.JSONDecodeError):
                self.n_corrupt += 1
                continue
            self._records[rec.key] = rec
            self.n_loaded += 1
        self._evict_over_capacity()

    # ----------------------------------------------------------- lsh index
    def _index_path(self) -> str:
        # not *.json: record loading globs that suffix
        return os.path.join(self.dir, "lsh.index")

    def _attach_index(self) -> None:
        """Load the persisted band index; rebuild from the records when it
        is missing, corrupt, parameter-mismatched, or out of sync with the
        loaded record set (e.g. another writer evicted since)."""
        try:
            with open(self._index_path()) as f:
                idx = LSHIndex.from_json(json.load(f))
            if (idx.n_perms == self.index.n_perms
                    and idx.n_bands == self.index.n_bands
                    and idx.keys() == set(self._records)):
                self.index = idx
                return
        except (OSError, ValueError, KeyError, TypeError,
                json.JSONDecodeError):
            pass
        self._rebuild_index()

    def _rebuild_index(self) -> None:
        self.index.clear()
        for key, rec in self._records.items():
            self.index.add(key, (rec.prepare_fingerprint.minhash,
                                 rec.fingerprint.minhash))
        self.n_index_rebuilds += 1
        self._persist_index()

    def _persist_index(self) -> None:
        if not self.dir or self.readonly:
            return
        try:
            os.makedirs(self.dir, exist_ok=True)
            tmp = self._index_path() + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self.index.to_json(), f)
            os.replace(tmp, self._index_path())
            self._index_dirty_puts = 0
        except OSError as e:
            # a lost index write is self-healing (rebuilt at next attach
            # by the key-set check) — never worth failing a put over
            self.n_io_errors += 1
            obs.audit().event("store.io_error", op="persist_index",
                              error=str(e))
            obs.metrics().counter("store_io_errors")

    # the index file serializes every record's band digests, so writing it
    # per put would make N inserts O(N^2) disk work at the ~1k-record scale
    # the index exists for.  Small stores flush every put (restart never
    # rebuilds); large ones amortize — a stale on-disk index is detected at
    # load by the key-set check in _attach_index and rebuilt, so deferral
    # trades a cheap rebuild-on-restart for O(1) amortized writes.
    _INDEX_FLUSH_SMALL = 128
    _INDEX_FLUSH_EVERY = 16

    def _persist_index_amortized(self) -> None:
        self._index_dirty_puts += 1
        if (len(self._records) <= self._INDEX_FLUSH_SMALL
                or self._index_dirty_puts >= self._INDEX_FLUSH_EVERY):
            self._persist_index()

    # ------------------------------------------------------------ writes
    def _path(self, key: str) -> str:
        return os.path.join(self.dir, f"{key}.json")

    def _persist(self, rec: PolicyRecord) -> None:
        if not self.dir or self.readonly:
            return
        os.makedirs(self.dir, exist_ok=True)
        tmp = self._path(rec.key) + ".tmp"
        payload = json.dumps(rec.to_json())
        with open(tmp, "w") as f:
            if faults.inject("store.put", key=rec.key) is not None:
                # model a mid-write crash: half the payload lands, then
                # the writer dies — the *.tmp file is left behind and the
                # record file is never replaced (atomicity under test)
                f.write(payload[: len(payload) // 2])
                raise OSError("injected mid-write failure persisting record")
            f.write(payload)
        os.replace(tmp, self._path(rec.key))

    def _persist_safe(self, rec: PolicyRecord) -> bool:
        """Mirror a record to disk without ever raising into the caller:
        a full disk or injected write fault costs durability of this one
        record (the in-memory copy keeps serving), never the train loop."""
        try:
            self._persist(rec)
            return True
        except OSError as e:
            self.n_io_errors += 1
            obs.audit().event("store.io_error", op="persist",
                              key=rec.key, error=str(e))
            obs.metrics().counter("store_io_errors")
            return False

    def _evict_over_capacity(self) -> None:
        while len(self._records) > self.max_records:
            key, _ = self._records.popitem(last=False)
            self.index.remove(key)
            self._rows_dirty = True
            self.n_evictions += 1
            if self.dir and not self.readonly:
                try:
                    os.remove(self._path(key))
                except OSError:
                    pass

    def put(self, rec: PolicyRecord) -> None:
        with self._lock:
            self._records[rec.key] = rec
            self._records.move_to_end(rec.key)
            self.index.add(rec.key, (rec.prepare_fingerprint.minhash,
                                     rec.fingerprint.minhash))
            self._rows_dirty = True
            self._evict_over_capacity()
            self._persist_safe(rec)
            self._persist_index_amortized()

    def touch(self, rec: PolicyRecord) -> None:
        """Record a use: bumps LRU recency and the use counter.  The disk
        side only needs its mtime refreshed (restart LRU order follows
        mtime) — rewriting the whole record per hit would serialize every
        candidate on every reuse; the ``uses`` counter is informational
        and flushed whenever the record is next ``put``."""
        with self._lock:
            rec.uses += 1
            if rec.key in self._records:
                self._records.move_to_end(rec.key)
            if self.dir and not self.readonly:
                try:
                    os.utime(self._path(rec.key), None)
                except OSError:
                    self._persist_safe(rec)  # file vanished: restore it

    def refresh(self) -> int:
        """Pick up records another writer added to the directory since
        load — a readonly attach in a serving process keeps seeing the
        trainer's newly cached policies without a restart.  Returns the
        number of newly loaded records."""
        if not self.dir:
            return 0
        with self._lock:
            try:
                names = [n for n in os.listdir(self.dir)
                         if n.endswith(".json")]
            except OSError:
                return 0
            new = 0
            for name in names:
                if name[:-5] in self._records:
                    continue
                try:
                    with open(os.path.join(self.dir, name)) as f:
                        rec = PolicyRecord.from_json(json.load(f))
                except (OSError, ValueError, KeyError, TypeError,
                        json.JSONDecodeError):
                    self.n_corrupt += 1
                    continue
                self._records[rec.key] = rec
                self.index.add(rec.key, (rec.prepare_fingerprint.minhash,
                                         rec.fingerprint.minhash))
                self._rows_dirty = True
                self.n_loaded += 1
                new += 1
            if new and not self.readonly:
                self._evict_over_capacity()
            return new

    # ------------------------------------------------------------ lookup
    def get_exact(self, key: str) -> Optional[PolicyRecord]:
        with self._lock:
            return self._records.get(key)

    # the token-histogram vocabulary across all rows is bounded (interned
    # op tokens), but a pathological store could still blow the dense
    # matrix up — rows beyond the cap keep the optimistic constant bound
    _HIST_VOCAB_CAP = 8192

    # ---- flat row views for the vectorized fallback (2 rows per record:
    # prepare + iteration fingerprint), rebuilt lazily after mutations
    def _ensure_rows(self) -> None:
        if not self._rows_dirty:
            return
        w = self.index.n_perms
        keys: List[str] = []
        sigs: List[np.ndarray] = []
        lens: List[int] = []
        has_site: List[bool] = []
        sig_ok: List[bool] = []
        fps: List[Fingerprint] = []
        for key, rec in self._records.items():
            for f in (rec.prepare_fingerprint, rec.fingerprint):
                keys.append(key)
                fps.append(f)
                lens.append(int(f.length))
                has_site.append(bool(f.site_bytes))
                if f.minhash.size == w:
                    sigs.append(f.minhash)
                    sig_ok.append(True)
                else:                       # foreign perm count: never prune
                    sigs.append(np.zeros(w, np.int64))
                    sig_ok.append(False)
        self._row_keys = keys
        self._row_sigs = (np.stack(sigs) if sigs
                          else np.zeros((0, w), np.int64))
        self._row_lens = np.asarray(lens, np.float64)
        self._row_site = np.asarray(has_site, bool)
        self._row_ok = np.asarray(sig_ok, bool)
        self._build_cosine_rows(fps)
        self._rows_dirty = False

    def _build_cosine_rows(self, fps: List[Fingerprint]) -> None:
        """Dense unit-normalized histogram/site matrices over the bounded
        vocabularies, so ``_upper_bounds`` evaluates the cosine terms of
        the calibrated similarity *exactly* (a row's support is always a
        subset of the vocab, so the dot over mapped query entries is the
        true dot).  Rows whose histogram would overflow the vocab cap are
        flagged; their bound falls back to the optimistic constant."""
        n = len(fps)
        hist_vocab: Dict[int, int] = {}
        site_vocab: Dict[str, int] = {}
        hist_full = np.ones(n, bool)        # row fully inside the vocab?
        for i, f in enumerate(fps):
            if len(hist_vocab) + len(f.histogram) <= self._HIST_VOCAB_CAP:
                for t in f.histogram:
                    if t not in hist_vocab:
                        hist_vocab[t] = len(hist_vocab)
            if not all(t in hist_vocab for t in f.histogram):
                hist_full[i] = False
            for s in f.site_bytes:
                if s not in site_vocab:
                    site_vocab[s] = len(site_vocab)
        hmat = np.zeros((n, max(len(hist_vocab), 1)), np.float64)
        smat = np.zeros((n, max(len(site_vocab), 1)), np.float64)
        hist_empty = np.zeros(n, bool)
        cand = np.zeros(n, np.float64)
        for i, f in enumerate(fps):
            hist_empty[i] = not f.histogram
            cand[i] = float(f.cand_bytes)
            if hist_full[i]:
                for t, c in f.histogram.items():
                    hmat[i, hist_vocab[t]] = c
            for s, b in f.site_bytes.items():
                smat[i, site_vocab[s]] = b
        for mat in (hmat, smat):
            norms = np.linalg.norm(mat, axis=1)
            nz = norms > 0
            mat[nz] /= norms[nz, None]
        self._hist_vocab, self._site_vocab = hist_vocab, site_vocab
        self._row_hist, self._row_svec = hmat, smat
        self._row_hist_full, self._row_hist_empty = hist_full, hist_empty
        self._row_cand = cand

    def _query_cos(self, q: Dict, vocab: Dict, mat: np.ndarray,
                   row_empty: np.ndarray) -> np.ndarray:
        """Exact cosine of ``q`` against every (unit-normalized) row.
        Out-of-vocab query entries contribute to the query norm only —
        rows carry no mass there, so the dot is still exact."""
        if not q:
            return np.where(row_empty, 1.0, 0.0)
        qv = np.zeros(mat.shape[1], np.float64)
        qn2 = 0.0
        for k, v in q.items():
            qn2 += float(v) * float(v)
            j = vocab.get(k)
            if j is not None:
                qv[j] = v
        dots = mat @ qv
        cos = dots / max(np.sqrt(qn2), 1e-300)
        return np.where(row_empty, 0.0, cos)

    def _upper_bounds(self, fp: Fingerprint) -> np.ndarray:
        """Per-row upper bound on the calibrated similarity.  With the
        dense cosine rows the bound equals the blended score (every term
        exact) for vocab-covered rows, so a true miss prunes after O(1)
        exact evaluations; overflow rows keep the optimistic constant and
        width-mismatched rows get 1.0 (never prune what we cannot score)."""
        n = len(self._row_keys)
        if fp.minhash.size == self.index.n_perms and n:
            jac = (self._row_sigs == fp.minhash[None, :]).mean(axis=1)
        else:
            jac = np.ones(n)
        fl = float(fp.length)
        lens = self._row_lens
        with np.errstate(divide="ignore", invalid="ignore"):
            lr = np.where((lens <= 0) & (fl <= 0), 1.0,
                          np.where((lens <= 0) | (fl <= 0), 0.0,
                                   np.minimum(lens, fl)
                                   / np.maximum(np.maximum(lens, fl), 1e-12)))
        cos = self._query_cos(fp.histogram, self._hist_vocab,
                              self._row_hist, self._row_hist_empty)
        use_prof = self._row_site & bool(fp.site_bytes)
        sc_token = 0.45 * jac + 0.30 * cos + 0.25 * lr
        sc = sc_token
        if use_prof.any():
            site_cos = self._query_cos(
                fp.site_bytes, self._site_vocab, self._row_svec,
                ~self._row_site)
            qc = float(fp.cand_bytes)
            rc = self._row_cand
            with np.errstate(divide="ignore", invalid="ignore"):
                bytes_r = np.where((rc <= 0) & (qc <= 0), 1.0,
                                   np.where((rc <= 0) | (qc <= 0), 0.0,
                                            np.minimum(rc, qc)
                                            / np.maximum(np.maximum(rc, qc),
                                                         1e-12)))
            sc_prof = (0.40 * jac + 0.20 * cos + 0.20 * lr
                       + 0.10 * site_cos + 0.10 * bytes_r)
            sc = np.where(use_prof, sc_prof, sc_token)
        # overflow rows: histogram cosine unknown -> optimistic constant
        ub_token = 0.45 * jac + 0.25 * lr + 0.30
        ub_prof = 0.40 * jac + 0.20 * lr + 0.40
        ub_loose = np.where(use_prof, ub_prof, ub_token)
        ub = np.where(self._row_hist_full, sc, ub_loose)
        ub = np.where(self._row_ok, ub, 1.0)
        return ub + 1e-9                    # absorb float rounding slack

    def nearest(self, fp: Fingerprint) -> Tuple[Optional[PolicyRecord], float]:
        """Best-matching record and its calibrated similarity: each record
        is reachable through either of its two fingerprints (max taken).
        A best match below the warm-start floor is counted as a miss —
        it cannot influence adaptation, so reporting it as a hit would
        make a never-matching cache look warm.

        Lookup is LSH-first: band-bucket collisions are scored exactly,
        and if a reuse-grade match surfaces the scan stops there (probe
        work ≪ records).  Otherwise the vectorized bounded fallback
        recovers the exact exhaustive-scan result."""
        with self._lock:
            return self._nearest_locked(fp)

    def _nearest_locked(
            self, fp: Fingerprint) -> Tuple[Optional[PolicyRecord], float]:
        self.n_lookups += 1
        hit = self._records.get(fp.exact)   # O(1) fast path (keys are
        if hit is not None:                 # prepare-fingerprint hashes)
            self.n_exact_hits += 1
            return hit, 1.0
        floor = getattr(self.cfg, "warm_threshold", 0.0)
        if not self._records:
            self.n_misses += 1
            return None, 0.0
        reuse_floor = getattr(self.cfg, "reuse_threshold", 1.0)
        scored: Dict[str, float] = {}

        def _score(key: str) -> float:
            rec = self._records[key]
            s = max(similarity(fp, rec.prepare_fingerprint),
                    similarity(fp, rec.fingerprint))
            self.n_sim_evals += 1
            scored[key] = s
            return s

        best: Optional[PolicyRecord] = None
        best_sim = 0.0
        for key in self.index.query(fp.minhash):
            if key not in self._records:
                continue
            s = _score(key)
            if s > best_sim or best is None:
                best, best_sim = self._records[key], s
        if best is None or best_sim < reuse_floor:
            self._ensure_rows()
            ub = self._upper_bounds(fp)
            for ri in np.argsort(-ub):
                if best is not None and ub[ri] <= best_sim:
                    break                   # bounds sorted: nothing beats it
                key = self._row_keys[ri]
                if key in scored:
                    continue
                s = _score(key)
                if s > best_sim or best is None:
                    best, best_sim = self._records[key], s
        if best is None or best_sim < floor:
            self.n_misses += 1
        elif best_sim >= 1.0:
            self.n_exact_hits += 1
        else:
            self.n_sim_hits += 1
        return best, best_sim

    def nearest_exhaustive(
            self, fp: Fingerprint) -> Tuple[Optional[PolicyRecord], float]:
        """Reference O(records) scan — the parity oracle for the LSH path
        (tests/benchmarks).  Does not touch hit counters."""
        best: Optional[PolicyRecord] = None
        best_sim = 0.0
        with self._lock:
            recs = list(self._records.values())
        for rec in recs:
            sim = max(similarity(fp, rec.prepare_fingerprint),
                      similarity(fp, rec.fingerprint))
            if sim > best_sim or best is None:
                best, best_sim = rec, sim
        return best, best_sim

    # ------------------------------------------------------------- misc
    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def records(self) -> List[PolicyRecord]:
        with self._lock:
            return list(self._records.values())

    def stats(self) -> dict:
        with self._lock:
            return {
                "records": len(self._records),
                "dir": self.dir or "",
                "lookups": self.n_lookups,
                "exact_hits": self.n_exact_hits,
                "sim_hits": self.n_sim_hits,
                "misses": self.n_misses,
                "evictions": self.n_evictions,
                "loaded": self.n_loaded,
                "corrupt_skipped": self.n_corrupt,
                "io_errors": self.n_io_errors,
                "sim_evals": self.n_sim_evals,
                "index_rebuilds": self.n_index_rebuilds,
                "index": self.index.stats(),
            }
