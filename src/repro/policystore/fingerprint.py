"""Drift-tolerant op-sequence fingerprints (repro.policystore).

A fingerprint is a fixed-size sketch of one tokenized operator stream
(``repro.core.tokenizer``), built from three layers of evidence:

  * an **exact hash** of the token bytes plus the aggregate features —
    identical programs collide deliberately, different-shape variants of
    the same op stream (e.g. seq-len buckets, which tokenize identically
    but carry different per-site byte totals) do not;
  * a **shingled MinHash signature**: the stream's ``shingle``-gram set
    is sketched with ``n_perms`` universal-hash permutations, so the
    Jaccard similarity of two streams' n-gram sets is estimated from the
    fraction of matching signature slots — robust to reordering and to
    local insertions (an interleaved eval block changes a bounded number
    of shingles);
  * **aggregate features**: op count, operator-histogram, and (when a
    profile is available) the per-site candidate-byte histogram plus the
    total candidate bytes — these catch what MinHash deliberately
    ignores, a uniform rescale of the whole program.

``similarity`` combines the layers into one calibrated score in [0, 1];
the tier *gates* (length ratio floors) live in ``drift.py`` where the
reuse/warm-start/regen decision is made.
"""
from __future__ import annotations

import collections
import hashlib
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

# universal-hash modulus (Mersenne prime 2^31 - 1): with a, b, h < p the
# product a*h + b fits in uint64, so the whole permutation bank runs as one
# vectorized numpy expression.  Fixed seeds make signatures stable across
# processes — a store written by one run must be readable by the next.
_MERSENNE = (1 << 31) - 1
_PERM_SEED = 0x5EED_CAFE
_SHINGLE_BASE = np.uint64(1_000_003)
_CHUNK = 1 << 16                      # windows hashed per vectorized block


_PERM_CACHE: Dict[int, np.ndarray] = {}


def _permutations(n_perms: int) -> np.ndarray:
    """(2, n_perms, 1) uint64 [a; b] for h -> (a*h + b) mod p (memoized —
    the bank is fixed-seed, so one materialization per perm count)."""
    bank = _PERM_CACHE.get(n_perms)
    if bank is None:
        rng = np.random.RandomState(_PERM_SEED)
        a = rng.randint(1, _MERSENNE, size=n_perms).astype(np.uint64)
        b = rng.randint(0, _MERSENNE, size=n_perms).astype(np.uint64)
        bank = np.stack([a, b])[:, :, None]
        _PERM_CACHE[n_perms] = bank
    return bank


def _shingle_hashes(tokens: np.ndarray, shingle: int) -> np.ndarray:
    """Polynomial hash of every length-``shingle`` window (uint64)."""
    t = tokens.astype(np.uint64)
    if t.size == 0:
        return t
    k = min(shingle, t.size)
    w = t.size - k + 1
    h = np.zeros(w, np.uint64)
    for j in range(k):
        h = h * _SHINGLE_BASE + t[j:j + w]
    return h


def minhash_signature(tokens: np.ndarray, n_perms: int = 64,
                      shingle: int = 4) -> np.ndarray:
    """MinHash sketch of the stream's shingle set (int64, ``n_perms``)."""
    hashes = np.unique(_shingle_hashes(np.asarray(tokens), shingle))
    if hashes.size == 0:
        return np.full(n_perms, -1, np.int64)
    a, b = _permutations(n_perms)
    p = np.uint64(_MERSENNE)
    sig = np.full(n_perms, _MERSENNE, np.uint64)
    h = hashes % p
    for lo in range(0, h.size, _CHUNK):
        blk = h[None, lo:lo + _CHUNK]               # (1, chunk)
        vals = ((a * blk + b) % p).min(axis=1)      # (n_perms,)
        sig = np.minimum(sig, vals)
    return sig.astype(np.int64)


@dataclass
class Fingerprint:
    """Sketch of one tokenized op stream (JSON-serializable)."""
    exact: str                         # sha1 over tokens + aggregates
    length: int                        # op count
    minhash: np.ndarray                # (n_perms,) int64
    histogram: Dict[int, int]          # token -> count
    site_bytes: Dict[str, int] = field(default_factory=dict)
    cand_bytes: int = 0                # total candidate bytes (0 = unknown)

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {
            "exact": self.exact,
            "length": int(self.length),
            "minhash": [int(v) for v in self.minhash],
            "histogram": {str(k): int(v) for k, v in self.histogram.items()},
            "site_bytes": {k: int(v) for k, v in self.site_bytes.items()},
            "cand_bytes": int(self.cand_bytes),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Fingerprint":
        return cls(exact=d["exact"], length=int(d["length"]),
                   minhash=np.asarray(d["minhash"], np.int64),
                   histogram={int(k): int(v)
                              for k, v in d["histogram"].items()},
                   site_bytes=dict(d.get("site_bytes", {})),
                   cand_bytes=int(d.get("cand_bytes", 0)))


def _exact_hash(tokens: np.ndarray, site_bytes: Dict[str, int],
                cand_bytes: int, extra: bytes = b"") -> str:
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    for k in sorted(site_bytes):
        h.update(f"{k}={site_bytes[k]};".encode())
    h.update(str(cand_bytes).encode())
    h.update(extra)
    return h.hexdigest()


# sketch memo: the monitoring loop re-fingerprints *recurring* streams
# (train/eval interleaves, seq-len bucket cycling) — the exact hash is
# cheap (one sha1 over the token bytes) and fully determines the sketch,
# so the shingling/MinHash/unique work runs once per distinct stream.
_FP_CACHE: "collections.OrderedDict[tuple, Fingerprint]" = \
    collections.OrderedDict()
_FP_CACHE_MAX = 256


def clear_fingerprint_cache() -> None:
    _FP_CACHE.clear()


def fingerprint_tokens(tokens: np.ndarray,
                       site_bytes: Optional[Dict[str, int]] = None,
                       n_perms: int = 64, shingle: int = 4,
                       cache: bool = True,
                       virtual_len: Optional[int] = None,
                       histogram: Optional[Dict[int, int]] = None
                       ) -> Fingerprint:
    """Sketch one token stream.

    ``virtual_len``/``histogram`` carry the *true* run-length-aware
    accounting when ``tokens`` is a REPEAT_CAP-capped materialization
    (``tokenizer.Signature``): the exact hash, length, and histogram then
    reflect the virtual stream — two deep-scan variants whose capped
    materializations collide (80 vs 96 layers) must not fingerprint
    identically.  When the virtual accounting matches the materialized
    stream the fingerprint is bit-identical to the plain form, so
    iteration fingerprints still exact-hit prepare fingerprints of the
    same program.  MinHash stays on the materialized stream — shingle
    *sets* saturate after one scan repeat, so the cap cannot change them.
    """
    tokens = np.asarray(tokens, np.int32)
    site_bytes = dict(site_bytes or {})
    cand_bytes = sum(site_bytes.values())
    length = int(tokens.size) if virtual_len is None else int(virtual_len)
    extra = b""
    if length != tokens.size:
        # capped materialization: hash the virtual accounting too (the
        # true histogram can only diverge from the stream when it did)
        hist_ser = ",".join(f"{k}:{v}"
                            for k, v in sorted((histogram or {}).items()))
        extra = f"vlen={length};hist={hist_ser}".encode()
    exact = _exact_hash(tokens, site_bytes, cand_bytes, extra)
    key = (exact, n_perms, shingle)
    if cache:
        hit = _FP_CACHE.get(key)
        if hit is not None:
            _FP_CACHE.move_to_end(key)
            return hit
    hist: Dict[int, int] = dict(histogram or {})
    if not hist and tokens.size:
        vals, counts = np.unique(tokens, return_counts=True)
        hist = {int(v): int(c) for v, c in zip(vals, counts)}
    fp = Fingerprint(
        exact=exact,
        length=length,
        minhash=minhash_signature(tokens, n_perms=n_perms, shingle=shingle),
        histogram=hist, site_bytes=site_bytes, cand_bytes=cand_bytes)
    if cache:
        _FP_CACHE[key] = fp
        while len(_FP_CACHE) > _FP_CACHE_MAX:
            _FP_CACHE.popitem(last=False)
    return fp


def fingerprint_signature(sig, n_perms: int = 64, shingle: int = 4,
                          cache: bool = True) -> Fingerprint:
    """Fingerprint an iteration :class:`~repro.core.tokenizer.Signature`:
    the materialized (capped) stream for shingling plus the signature's
    virtual length and true histogram for the exact/length/histogram
    layers."""
    hist = {int(i): int(c) for i, c in enumerate(sig.hist) if c}
    return fingerprint_tokens(sig.materialize(), n_perms=n_perms,
                              shingle=shingle, cache=cache,
                              virtual_len=len(sig), histogram=hist)


def fingerprint_profile(prof, n_perms: int = 64,
                        shingle: int = 4) -> Fingerprint:
    """Fingerprint a Detailed-mode profile: the expanded op stream plus the
    per-site candidate-byte histogram (the shape-sensitive aggregate that
    separates seq-len buckets whose op streams tokenize identically)."""
    site_bytes: Dict[str, int] = {}
    for t in prof.candidates:
        if t.site:
            site_bytes[t.site] = site_bytes.get(t.site, 0) + t.nbytes
    return fingerprint_tokens(prof.op_tokens, site_bytes,
                              n_perms=n_perms, shingle=shingle)


# ------------------------------------------------------------- similarity
def _hist_cosine(a: Dict, b: Dict) -> float:
    if not a or not b:
        return 1.0 if not a and not b else 0.0
    keys = set(a) | set(b)
    va = np.array([a.get(k, 0) for k in keys], np.float64)
    vb = np.array([b.get(k, 0) for k in keys], np.float64)
    denom = np.linalg.norm(va) * np.linalg.norm(vb)
    return float(va @ vb / denom) if denom else 0.0


def _ratio(a: float, b: float) -> float:
    if a <= 0 and b <= 0:
        return 1.0
    if a <= 0 or b <= 0:
        return 0.0
    return min(a, b) / max(a, b)


def length_ratio(a: Fingerprint, b: Fingerprint) -> float:
    return _ratio(a.length, b.length)


def jaccard_estimate(a: Fingerprint, b: Fingerprint) -> float:
    if a.minhash.size == 0 or a.minhash.size != b.minhash.size:
        return 0.0
    return float(np.mean(a.minhash == b.minhash))


# non-identical fingerprints can blend to a perfect component score
# (e.g. a token-only fingerprint vs an identically tokenizing program of
# different shapes); the cap keeps 1.0 the exclusive mark of exact-hash
# equality so callers may use it as an identity test
_NON_EXACT_CAP = 1.0 - 1e-6


def similarity(a: Fingerprint, b: Fingerprint) -> float:
    """Calibrated similarity in [0, 1]; returns exactly 1.0 *only* for
    equal exact hashes.

    Weights (validated by tests/test_policystore.py property sweeps):
    the shingle Jaccard carries sequence *content and order*, the
    histogram cosine carries operator mix, the length ratio penalizes
    growth/shrinkage, and — when both sides carry profile aggregates —
    the per-site byte cosine and total-byte ratio penalize shape drift
    that is invisible to the token stream."""
    if a.exact == b.exact:
        return 1.0
    jac = jaccard_estimate(a, b)
    cos = _hist_cosine(a.histogram, b.histogram)
    lr = length_ratio(a, b)
    if a.site_bytes and b.site_bytes:
        site_cos = _hist_cosine(a.site_bytes, b.site_bytes)
        bytes_r = _ratio(a.cand_bytes, b.cand_bytes)
        score = (0.40 * jac + 0.20 * cos + 0.20 * lr
                 + 0.10 * site_cos + 0.10 * bytes_r)
    else:
        score = 0.45 * jac + 0.30 * cos + 0.25 * lr
    return min(score, _NON_EXACT_CAP)
