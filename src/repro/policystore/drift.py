"""Tiered drift response (repro.policystore).

An observed op sequence is routed to one of three adaptation tiers:

  * **REUSE** — similarity at or above the reuse threshold (or an exact
    fingerprint hit): the cached policy is re-associated with the new
    program via ``core/matching.py`` and applied directly, skipping
    GenPolicy entirely (O(lookup) adaptation);
  * **WARM_START** — moderate similarity: GenPolicy still runs, but its
    variant search is seeded from the cached record's winning knob and
    shortened to 1–2 steps instead of the paper's five (§7.1);
  * **REGEN** — low similarity or an empty store: the full cold
    WarmUp→GenPolicy path; the result is written back to the store.

Thresholds come from :class:`~repro.common.config.PolicyStoreConfig`.
On top of the calibrated similarity score, two *gates* guard against
structural drift the score can under-penalize:

  * length-ratio floors — a layer-count or model change roughly rescales
    the stream length, but its shingle set (scans repeat the same
    n-grams) and histogram direction barely move, so reuse additionally
    requires ``len_ratio >= reuse_len_ratio`` and warm-start
    ``len_ratio >= warm_len_ratio``;
  * invalidation guards (:meth:`DriftClassifier.classify`) — a record
    generated under a different HBM budget, or under a bandwidth curve
    that has since drifted beyond ``bw_drift_limit`` at any measured
    size, is capped at WARM_START: its schedule may no longer fit or
    overlap, but its knob is still a good search seed.

The runtime demotes REUSE to WARM_START itself when fuzzy matching
cannot re-associate enough entries (``min_reuse_hit_rate``) — the
classifier scores *sequences*, matching validates *tensors*.
"""
from __future__ import annotations

import enum
import threading
from dataclasses import dataclass
from typing import Optional

from repro import obs
from repro.policystore.fingerprint import Fingerprint, length_ratio
from repro.policystore.store import PolicyRecord, PolicyStore


class Tier(enum.Enum):
    REUSE = "reuse"
    WARM_START = "warm_start"
    REGEN = "regen"


@dataclass
class DriftDecision:
    tier: Tier
    record: Optional[PolicyRecord]
    similarity: float
    reason: str = ""


def bandwidth_drift(record: PolicyRecord, bwmodel) -> float:
    """Worst-case ratio between the live link curve and the record's
    snapshot across the snapshot's measured sizes (1.0 = unchanged;
    2.0 = some size is now 2x slower or 2x faster than when the policy
    was priced).  An *uncalibrated* live model prices with the constant
    fallback — not evidence of drift — so it compares as unchanged."""
    if (bwmodel is None or not record.bw_curve
            or not getattr(bwmodel, "is_calibrated", False)):
        return 1.0
    worst = 1.0
    for size, then_s in record.bw_curve:
        now_s = bwmodel.transfer_time(size)
        if then_s <= 0 or now_s <= 0:
            continue
        r = now_s / then_s
        worst = max(worst, r, 1.0 / r)
    return worst


class DriftClassifier:
    def __init__(self, cfg):
        self.cfg = cfg
        self.counters = {t.value: 0 for t in Tier}
        self.counters["demoted"] = 0
        # classify runs on the repro.adapt worker while the runtime's
        # inline paths (and stats readers) touch the same counters
        self._lock = threading.Lock()

    # ------------------------------------------------------------- tiers
    def classify(self, fp: Fingerprint, store: PolicyStore, *,
                 budget: Optional[int] = None,
                 bwmodel=None) -> DriftDecision:
        rec, sim = store.nearest(fp)
        if rec is None:
            return self._count(self._audit(
                fp, DriftDecision(Tier.REGEN, None, 0.0, "store empty")))
        lr = max(length_ratio(fp, rec.prepare_fingerprint),
                 length_ratio(fp, rec.fingerprint))
        tier = Tier.REGEN
        reason = f"sim={sim:.3f}"
        if sim >= self.cfg.reuse_threshold and lr >= self.cfg.reuse_len_ratio:
            tier = Tier.REUSE
        elif (sim >= self.cfg.warm_threshold
              and lr >= self.cfg.warm_len_ratio):
            tier = Tier.WARM_START
        else:
            reason += f" len_ratio={lr:.3f}"

        # ---- invalidation guards: never REUSE across a changed budget
        # or a drifted link curve — the cached schedule was priced for a
        # different machine state; its knob still seeds the search.
        if tier is Tier.REUSE:
            if budget is not None and rec.budget and budget != rec.budget:
                tier = Tier.WARM_START
                reason += f" budget {rec.budget}->{budget}"
            else:
                bw = bandwidth_drift(rec, bwmodel)
                if bw > self.cfg.bw_drift_limit:
                    tier = Tier.WARM_START
                    reason += f" bw_drift={bw:.2f}"
        return self._count(self._audit(
            fp, DriftDecision(tier, rec, sim, reason)))

    def demote(self, decision: DriftDecision, why: str = "") -> DriftDecision:
        """REUSE failed at apply time (matching hit-rate too low): fall to
        WARM_START around the same record.  The original tier's count is
        taken back — it never actually applied — so the per-tier counters
        always sum to the number of adaptations."""
        with self._lock:
            self.counters[decision.tier.value] -= 1
            self.counters["demoted"] += 1
            self.counters[Tier.WARM_START.value] += 1
        obs.audit().event(
            "drift.demote", why=why,
            from_tier=decision.tier.value, to_tier=Tier.WARM_START.value,
            similarity=round(decision.similarity, 6),
            record=decision.record.key[:12] if decision.record else None)
        return DriftDecision(Tier.WARM_START, decision.record,
                             decision.similarity,
                             (decision.reason + " " + why).strip())

    @staticmethod
    def _audit(fp: Fingerprint, d: DriftDecision) -> DriftDecision:
        obs.audit().event(
            "drift.classify", tier=d.tier.value,
            similarity=round(d.similarity, 6), reason=d.reason,
            fp=fp.exact[:12], fp_length=fp.length,
            record=d.record.key[:12] if d.record else None)
        return d

    def _count(self, d: DriftDecision) -> DriftDecision:
        with self._lock:
            self.counters[d.tier.value] += 1
        return d

    def stats(self) -> dict:
        with self._lock:
            return dict(self.counters)
