"""Serving entry point: batched decode over the slot server.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced

Over-subscription: ``--max-active`` beyond ``--max-batch`` admits more
concurrent requests than HBM-resident slots by spilling preempted decode
state into the pinned host pool (repro.hostmem).
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="HBM-resident decode slots")
    ap.add_argument("--max-active", type=int, default=0,
                    help="admitted concurrency (> max-batch spills KV state "
                         "to the host pool; 0 = max-batch)")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--calibrate-link", action="store_true",
                    help="measure the host link before serving")
    ap.add_argument("--spill-compression", choices=["none", "int8", "auto"],
                    default="none",
                    help="int8: KV spill crosses the link row-quantized "
                         "(2-4x fewer bytes, <=0.4%% per-row error); "
                         "auto: raw-vs-int8 priced per row from the tuned "
                         "kernel rates + measured link curve")
    ap.add_argument("--autotune", action="store_true",
                    help="tune the swap-path kernels against the roofline "
                         "at startup (repro.kernels.autotune); feeds the "
                         "auto spill-compression advisor")
    ap.add_argument("--autotune-cache-dir", default="",
                    help="persist/reuse tuned configs here (warm cache = "
                         "zero re-measurement)")
    ap.add_argument("--policy-store-dir", default="",
                    help="attach the shared adaptation cache (read-only "
                         "visibility: cache warmth is reported in stats)")
    ap.add_argument("--adapt-mode",
                    choices=["inline", "async", "speculative"],
                    default="inline",
                    help="adaptation placement (repro.adapt): async/"
                         "speculative enable the background policy-store "
                         "refresher so a co-located trainer's new policies "
                         "become visible without a tick-loop stall")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON here on exit "
                         "(open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default="",
                    help="write one repro.obs metrics-registry snapshot "
                         "(JSONL) here on exit")
    args = ap.parse_args()

    import jax
    import numpy as np
    import repro.configs as C
    from repro.common.config import HostMemConfig, PolicyStoreConfig
    from repro.hostmem import HostMemTier
    from repro.models.registry import get_api
    from repro.runtime.server import Server

    cfg = C.get_reduced(args.arch) if args.reduced else C.get_config(args.arch)
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(0))
    max_active = args.max_active or args.max_batch
    hostmem = None
    if (max_active > args.max_batch or args.calibrate_link
            or args.spill_compression != "none" or args.autotune):
        hostmem = HostMemTier(HostMemConfig(
            spill_compression=args.spill_compression))
        if args.calibrate_link:
            hostmem.calibrate()        # engine-path sweep, not raw device_put
        if args.autotune:
            from repro.common.config import AutotuneConfig
            hostmem.autotune(AutotuneConfig(
                enabled=True, cache_dir=args.autotune_cache_dir))
    policystore = None
    if args.policy_store_dir:
        from repro.policystore import PolicyStore
        # readonly: a shared training store must not lose records to this
        # reader's load-time eviction
        policystore = PolicyStore(PolicyStoreConfig(dir=args.policy_store_dir),
                                  readonly=True)
    srv = Server(cfg, params, max_batch=args.max_batch, max_len=args.max_len,
                 max_active=max_active, hostmem=hostmem,
                 policystore=policystore, adapt_mode=args.adapt_mode)
    rng = np.random.RandomState(0)
    for _ in range(args.requests):
        srv.submit(rng.randint(0, cfg.vocab_size, size=rng.randint(4, 16)),
                   max_new_tokens=args.new_tokens)
    t0 = time.time()
    results = srv.run_until_done(max_ticks=10_000)
    dt = time.time() - t0
    toks = sum(len(v) for v in results.values())
    print(f"{len(results)} requests, {toks} tokens, {dt:.2f}s, "
          f"{toks / dt:.1f} tok/s, {srv.ticks} ticks, "
          f"{srv.n_preemptions} preemptions")
    lat = srv.latency_stats()
    print(f"tick p50 {lat['tick_ms']['p50']:.1f} ms / "
          f"p95 {lat['tick_ms']['p95']:.1f} ms, "
          f"occupancy {lat['slot_occupancy']:.1%}, "
          f"queue-wait p95 {lat['queue_wait_ticks']['p95']:.0f} ticks")
    if hostmem is not None:
        print(hostmem.summary())          # includes per-traffic-class lines
        kv = srv.stats()["kv_spill_class"]
        if kv is not None and (kv["n_out"] or kv["n_in"]):
            print(f"kv_spill link: {kv['n_out']} spills staged / "
                  f"{kv['n_in']} restored, "
                  f"stalled {kv['stall_s'] * 1e3:.1f} ms behind "
                  f"higher-priority traffic")
        ks = hostmem.kvspill.stats()
        if ks["compression"] != "none" and ks["n_spills"]:
            print(f"spill compression ({ks['compression']}): "
                  f"{ks['bytes_raw'] / 2**20:.1f} MiB raw -> "
                  f"{ks['bytes_spilled'] / 2**20:.1f} MiB staged "
                  f"({ks['compression_ratio']:.2f}x)")
    if policystore is not None:
        print(f"policystore: {policystore.stats()}")
        ad = srv.stats()["adapt"]
        if ad["mode"] != "inline":
            print(f"adapt[{ad['mode']}]: "
                  f"store_refreshes={ad['store_refreshes']} "
                  f"records_refreshed={ad['store_records_refreshed']}")
    from repro import obs
    if args.metrics_out:
        obs.metrics().write_jsonl(args.metrics_out)
    if args.trace_out:
        obs.export_chrome_trace(args.trace_out, obs.tracer(),
                                counters=obs.ledger().counter_tracks(),
                                meta={"arch": args.arch,
                                      "requests": args.requests})
        print(f"trace: {args.trace_out} "
              f"({obs.tracer().stats()['retained']} events)")


if __name__ == "__main__":
    main()
