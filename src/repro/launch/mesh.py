"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required: the dry-run pins the device count via
XLA_FLAGS before any jax initialization; tests and benches must keep
seeing 1 CPU device).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.common.config import MeshConfig, MULTI_POD_MESH, SINGLE_POD_MESH


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_from_config(mc: MeshConfig):
    return jax.make_mesh(
        mc.shape, mc.axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(mc.axes))


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CPU tests (requires host-platform device override)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_config(multi_pod: bool) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH
