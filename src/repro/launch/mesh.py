"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required: the dry-run pins the device count via
XLA_FLAGS before any jax initialization; tests and benches must keep
seeing 1 CPU device).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.common.config import MeshConfig, MULTI_POD_MESH, SINGLE_POD_MESH


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on newer JAX (>= 0.5 explicit-sharding
    line); older versions default every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh_from_config(mc: MeshConfig):
    return jax.make_mesh(mc.shape, mc.axes, **_axis_type_kwargs(len(mc.axes)))


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CPU tests (requires host-platform device override)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def mesh_config(multi_pod: bool) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH
