"""Production training entry point.

Single-host CPU (reduced configs) or multi-host TPU (full configs):

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 50

On a real cluster each host runs this under the pod launcher (see
launch/scripts/) with JAX_COORDINATOR_ADDRESS etc. set; jax.distributed
initializes from env and the per-host data shards come from
process_index/process_count.
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-paper")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--budget-gib", type=float, default=16.0)
    ap.add_argument("--no-chameleon", action="store_true")
    ap.add_argument("--policy-store-dir", default="",
                    help="persist adaptation policies here (fingerprint-"
                         "keyed; a restart with a warm store skips "
                         "GenPolicy for recurring sequences)")
    ap.add_argument("--no-policy-store", action="store_true",
                    help="disable the in-memory policy cache too")
    ap.add_argument("--autotune", action="store_true",
                    help="tune the swap-path Pallas kernels against the "
                         "memory-bandwidth roofline at startup and price "
                         "the achieved efficiency into policy generation "
                         "(repro.kernels.autotune)")
    ap.add_argument("--autotune-cache-dir", default="",
                    help="persist tuned configs + bandwidth snapshot here "
                         "(schema-versioned autotune.json; a warm cache "
                         "means restart re-measures nothing).  Defaults "
                         "to <policy-store-dir>/autotune when a policy "
                         "store dir is set")
    ap.add_argument("--adapt-mode",
                    choices=["inline", "async", "speculative"],
                    default="inline",
                    help="adaptation placement (repro.adapt): inline runs "
                         "the paper's measured GenPolicy iterations; async "
                         "moves the variant search to a background worker "
                         "(drift never stalls an iteration); speculative "
                         "additionally pre-generates policies for "
                         "predicted-recurring op sequences")
    ap.add_argument("--multihost", action="store_true",
                    help="initialize jax.distributed from env")
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON here on exit "
                         "(open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default="",
                    help="append repro.obs metrics-registry snapshots "
                         "(JSONL) here during training")
    ap.add_argument("--metrics-every", type=int, default=25,
                    help="snapshot cadence for --metrics-out (steps)")
    ap.add_argument("--stats-json", default="",
                    help="dump the full runtime stats() dict + obs "
                         "snapshot as JSON here on exit")
    ap.add_argument("--fault-plan", default="",
                    help="arm a repro.faults FaultPlan from this JSON file "
                         "(chaos drills: seeded fault schedules keyed by "
                         "site x iteration; see docs/robustness.md)")
    ap.add_argument("--audit-out", default="",
                    help="write the repro.obs audit log (JSONL) here on "
                         "exit — the evidence trail for fault drills")
    args = ap.parse_args()

    if args.multihost:
        import jax
        jax.distributed.initialize()

    import jax
    import repro.configs as C
    from repro.common.config import (AdaptConfig, AutotuneConfig,
                                     ChameleonConfig, PolicyStoreConfig,
                                     TrainConfig)
    from repro.data.synthetic import SyntheticTokens
    from repro.launch.mesh import make_production_mesh
    from repro.runtime.trainer import Trainer

    cfg = C.get_reduced(args.arch) if args.reduced else C.get_config(args.arch)
    seq = args.seq or (128 if args.reduced else 4096)
    gb = args.global_batch or (8 if args.reduced else 256)
    tcfg = TrainConfig(steps=args.steps, checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=max(args.steps // 4, 1),
                       eval_every=max(args.steps // 3, 1))
    at_dir = args.autotune_cache_dir
    if args.autotune and not at_dir and args.policy_store_dir:
        # warm-start colocation: tuned configs restart with the policies
        at_dir = os.path.join(args.policy_store_dir, "autotune")
    cham = ChameleonConfig(enabled=not args.no_chameleon,
                           hbm_budget_bytes=int(args.budget_gib * 2 ** 30),
                           policystore=PolicyStoreConfig(
                               enabled=not args.no_policy_store,
                               dir=args.policy_store_dir),
                           adapt=AdaptConfig(mode=args.adapt_mode),
                           autotune=AutotuneConfig(
                               enabled=args.autotune, cache_dir=at_dir))
    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    data = SyntheticTokens(cfg.vocab_size, seq, gb,
                           host_index=jax.process_index(),
                           host_count=jax.process_count()).start()
    if args.audit_out:
        # stream every audit event (not just the in-memory tail): the
        # chaos-drill evidence trail must survive a crash
        from repro import obs
        obs.audit().attach_file(args.audit_out)
    if args.fault_plan:
        from repro import faults
        faults.arm(faults.FaultPlan.load(args.fault_plan))
    tr = None
    try:
        tr = Trainer(cfg, tcfg, cham, mesh=mesh, data=data,
                     metrics_out=args.metrics_out or None,
                     metrics_every=args.metrics_every)
        if args.resume:
            tr.resume()
        rep = tr.train(args.steps)
        print(f"done: loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}; "
              f"stages={set(rep.stages)}; "
              f"chameleon={tr.rt.stats()['applied'][:60]}")
        ov = tr.rt.obs_stats()["overlap"]
        if ov["measured"]:
            print(f"overlap efficiency: last {ov['last']:.1%} / "
                  f"mean {ov['mean']:.1%} over {ov['measured']} "
                  f"transfer-active iterations "
                  f"({ov['hidden_s'] * 1e3:.1f} of "
                  f"{ov['transfer_s'] * 1e3:.1f} ms hidden)")
        from repro import obs as _obs
        sb = _obs.ledger().scoreboard()
        if sb["n"]:
            print(f"memory ledger: {sb['n']} scored iterations, peak error "
                  f"mean |e| {sb['mean_abs_error']:.2%} / "
                  f"max |e| {sb['max_abs_error']:.2%}")
        ps = rep.policystore
        if ps is not None:
            t, s = ps["tiers"], ps["store"]
            print(f"policystore: {s['records']} records "
                  f"({s['dir'] or 'memory-only'}); tiers "
                  f"reuse={t['reuse']} warm={t['warm_start']} "
                  f"regen={t['regen']} demoted={t['demoted']}; "
                  f"genpolicy_steps={ps['genpolicy_steps_total']}; "
                  f"adaptations={len(ps['adaptations'])}")
        ad = rep.adapt
        if ad is not None and ad["mode"] != "inline":
            print(f"adapt[{ad['mode']}]: jobs={ad['jobs']} "
                  f"published={ad['published']} installed={ad['installed']} "
                  f"discarded={ad['discarded']} failed={ad['failed']} "
                  f"spec_hits={ad['speculative_hits']}")
    finally:
        data.stop()
        if args.fault_plan:
            from repro import faults
            plan = faults.active()
            if plan is not None:
                print(f"fault plan: fired={plan.stats()['fired']}")
            faults.disarm()
        if tr is not None:
            lad = tr.rt.ladder
            if lad is not None and lad.transitions:
                print(f"ladder: rung={lad.name} "
                      f"descents={lad.n_descents} ascents={lad.n_ascents}")
            tr.rt.close()
            _export_obs(args, tr.rt)


def _export_obs(args, rt) -> None:
    """Flush the repro.obs artifacts the flags asked for.  Runs from the
    ``finally`` block so a crashed run still leaves its trace behind."""
    import json

    from repro import obs

    if getattr(args, "metrics_out", ""):
        obs.metrics().write_jsonl(args.metrics_out)
    if getattr(args, "trace_out", ""):
        counters = {"overlap_efficiency": [
            (h["t"], h["efficiency"]) for h in rt.overlap_history
            if h["efficiency"] is not None]}
        counters.update(obs.ledger().counter_tracks())
        obs.export_chrome_trace(args.trace_out, obs.tracer(),
                                counters=counters,
                                meta={"arch": args.arch,
                                      "steps": args.steps})
        print(f"trace: {args.trace_out} "
              f"({obs.tracer().stats()['retained']} events)")
    if getattr(args, "stats_json", ""):
        snap = {"runtime": rt.stats(), "obs_snapshot": obs.metrics().snapshot(),
                "audit_tail": obs.audit().tail(200)}
        with open(args.stats_json, "w") as f:
            json.dump(snap, f, indent=1, default=repr)
        print(f"stats: {args.stats_json}")


if __name__ == "__main__":
    main()
