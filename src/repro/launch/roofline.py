"""Roofline term extraction from a compiled dry-run artifact.

  compute    = step_FLOPs_per_chip / peak_FLOP/s
  memory     = step_HBM_bytes_per_chip / HBM_bw
  collective = wire_bytes_per_chip / link_bw

Two accounting pitfalls (both verified empirically on the CPU backend) are
handled explicitly:

  1. ``cost_analysis()`` does NOT multiply while-loop (scan) body flops by
     the trip count, so a scanned 24-layer model reports ~1 layer of flops.
     We therefore walk the *jaxpr* of the final (policy-applied) step and
     count dot_general flops with scan multiplicity — remat recompute is
     visible in the jaxpr, so the MODEL_FLOPS/step_FLOPs ratio honestly
     reflects recompute waste.  XLA's number is kept as ``xla_flops`` for
     reference.

  2. Collectives inside scan bodies appear once in the HLO text but run
     once per iteration.  We parse the compiled module structurally:
     computations reached as a ``while`` body inherit the loop's trip count
     (read from the integer constant in its condition computation), and
     nested whiles compose multiplicatively.

HBM bytes use the same jaxpr walk (dot operands/outputs + tagged residual
stores), a post-fusion traffic proxy: elementwise chains fuse into the
surrounding matmuls on TPU.  Hardware constants come from the shared
:class:`~repro.kernels.autotune.device.DeviceSpec` registry (TPU v5e
default: 197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s/link ICI · 32 GB/s
host link) — one spec feeds this report and the kernel autotuner.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field, asdict
from typing import Dict, Optional, Tuple

import numpy as np

from repro.kernels.autotune.device import (DEVICE_SPECS, DeviceSpec,
                                           get_device_spec)

_DEFAULT_SPEC = get_device_spec()
# module-level aliases kept for existing callers/tests; the spec registry
# is the source of truth
PEAK_FLOPS = _DEFAULT_SPEC.peak_flops
HBM_BW = _DEFAULT_SPEC.hbm_bw
ICI_BW = _DEFAULT_SPEC.ici_bw
HOST_BW = _DEFAULT_SPEC.host_bw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_WIRE_FACTOR = {
    "all-reduce": 2.0,        # ring RS + AG
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


# ====================================================== HLO structural walk
def _split_computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    cur, buf = None, []
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY )?(%?[\w.\-]+) \(.*\{", line)
        if m:
            if cur is not None:
                comps[cur] = "\n".join(buf)
            cur, buf = m.group(1).lstrip("%"), []
        elif cur is not None:
            buf.append(line)
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


_WHILE_RE = re.compile(
    r"while\([^)]*\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALL_RE = re.compile(
    r"(?:call|fusion)\([^)]*\), (?:to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(cond_text: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def _collectives_in(text: str) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for line in text.splitlines():
        eq = line.find(" = ")
        if eq < 0:
            continue
        rest = line[eq + 3:]
        for kind in _COLL_KINDS:
            k = rest.find(kind + "(")
            if k < 0:
                k = rest.find(kind + "-start(")
                if k < 0:
                    continue
            shapes_str = rest[:k]
            total = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(shapes_str))
            out[kind] = out.get(kind, 0.0) + total * _WIRE_FACTOR[kind]
            break
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind, with while-body collectives
    multiplied by their loop trip counts (nested loops compose)."""
    comps = _split_computations(hlo_text)
    local = {name: _collectives_in(text) for name, text in comps.items()}
    # computation -> list of (child computation, multiplier)
    children: Dict[str, list] = {name: [] for name in comps}
    roots = set(comps)
    for name, text in comps.items():
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            trip = _trip_count(comps.get(cond, ""))
            children[name].append((body, trip))
            roots.discard(body)
            roots.discard(cond)
        for m in _CALL_RE.finditer(text):
            callee = m.group(1)
            if callee in comps:
                children[name].append((callee, 1))
                roots.discard(callee)

    totals: Dict[str, float] = {}

    def accumulate(name: str, mult: float, seen: Tuple[str, ...] = ()):
        if name in seen or name not in comps:   # cycle guard
            return
        for kind, b in local.get(name, {}).items():
            totals[kind] = totals.get(kind, 0.0) + b * mult
        for child, trip in children.get(name, []):
            accumulate(child, mult * trip, seen + (name,))

    entry = None
    for name in comps:
        if "main" in name or "entry" in name.lower():
            entry = name
            break
    if entry is None and comps:
        entry = max(comps, key=lambda n: len(comps[n]))
    if entry:
        accumulate(entry, 1.0)
    # computations never reached from entry (conservatively count once)
    reached = set()

    def mark(name, seen=()):
        if name in seen or name in reached or name not in comps:
            return
        reached.add(name)
        for child, _ in children.get(name, []):
            mark(child, seen + (name,))

    if entry:
        mark(entry)
    for name in comps:
        if name not in reached:
            for kind, b in local.get(name, {}).items():
                totals[kind] = totals.get(kind, 0.0) + b
    return totals


# ============================================================= jaxpr costs
def _dot_flops(eqn) -> float:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = 1.0
    for d in lb:
        batch *= lhs.shape[d]
    k = 1.0
    for d in lc:
        k *= lhs.shape[d]
    m = 1.0
    for i, s in enumerate(lhs.shape):
        if i not in lc and i not in lb:
            m *= s
    n = 1.0
    for i, s in enumerate(rhs.shape):
        if i not in rc and i not in rb:
            n *= s
    return 2.0 * batch * m * n * k


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0.0


def jaxpr_cost(closed_jaxpr) -> Tuple[float, float]:
    """(flops, hbm_bytes) with scan multiplicity; recurses into remat/
    pjit/cond sub-jaxprs.  Bytes = dot operands+outputs + conv + tagged
    residual stores (post-fusion HBM-traffic proxy)."""
    from repro.core.tokenizer import _sub_jaxprs, _unwrap

    def walk(j) -> Tuple[float, float]:
        j = _unwrap(j)
        fl = by = 0.0
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name == "scan":
                f, b = walk(eqn.params["jaxpr"])
                L = eqn.params.get("length", 1)
                fl += f * L
                by += b * L
                continue
            subs = _sub_jaxprs(eqn)
            if subs:
                for s in subs:
                    f, b = walk(s)
                    fl += f
                    by += b
                continue
            if name == "dot_general":
                fl += _dot_flops(eqn)
                by += sum(_aval_bytes(v.aval) for v in eqn.invars)
                by += sum(_aval_bytes(v.aval) for v in eqn.outvars)
            elif name == "conv_general_dilated":
                by += sum(_aval_bytes(v.aval) for v in eqn.invars)
                by += sum(_aval_bytes(v.aval) for v in eqn.outvars)
            elif name == "name":
                by += 2.0 * _aval_bytes(eqn.outvars[0].aval)  # store + load
            elif name in ("gather", "take", "dynamic_slice",
                          "dynamic_update_slice"):
                by += sum(_aval_bytes(v.aval) for v in eqn.outvars)
        return fl, by

    return walk(closed_jaxpr)


# ================================================================== report
@dataclass
class RooflineTerms:
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    collectives: Dict[str, float]
    chips: int
    xla_flops_per_chip: float = 0.0
    xla_bytes_per_chip: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0
    useful_flops_ratio: float = 0.0
    step_time_bound_s: float = 0.0
    mfu_bound: float = 0.0

    def finalize(self, spec: Optional[DeviceSpec] = None):
        spec = spec or _DEFAULT_SPEC
        self.compute_s = self.flops_per_chip / spec.peak_flops
        self.memory_s = self.bytes_per_chip / spec.hbm_bw
        self.collective_s = self.wire_bytes_per_chip / spec.ici_bw
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.step_time_bound_s = max(terms.values())
        if self.model_flops and self.step_time_bound_s > 0:
            self.mfu_bound = (self.model_flops
                              / (self.chips * spec.peak_flops
                                 * self.step_time_bound_s))
        if self.flops_per_chip:
            self.useful_flops_ratio = (self.model_flops
                                       / (self.flops_per_chip * self.chips))
        return self

    def to_dict(self):
        return asdict(self)


def analyze(compiled, chips: int, model_flops: float = 0.0,
            hlo_text: Optional[str] = None,
            step_jaxpr=None,
            device_kind: Optional[str] = None) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # older JAX: one dict per program
        cost = cost[0] if cost else {}
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    colls = collective_bytes(txt)
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    if step_jaxpr is not None:
        jf, jb = jaxpr_cost(step_jaxpr)
        flops_chip = max(jf / chips, xla_flops)
        bytes_chip = max(jb / chips, xla_bytes)
    else:
        flops_chip, bytes_chip = xla_flops, xla_bytes
    terms = RooflineTerms(
        flops_per_chip=flops_chip,
        bytes_per_chip=bytes_chip,
        wire_bytes_per_chip=float(sum(colls.values())),
        collectives=colls,
        chips=chips,
        xla_flops_per_chip=xla_flops,
        xla_bytes_per_chip=xla_bytes,
        model_flops=model_flops,
    )
    return terms.finalize(get_device_spec(device_kind)
                          if device_kind else None)


def model_flops_train(param_count: int, tokens: int) -> float:
    return 6.0 * param_count * tokens


def model_flops_decode(param_count: int, batch: int) -> float:
    # one token per sequence: 2·N per token, forward only
    return 2.0 * param_count * batch
