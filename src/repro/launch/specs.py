"""``input_specs`` — ShapeDtypeStruct stand-ins for every (arch × shape)
cell: weak-type-correct, shardable, zero device allocation.

For ``train`` cells the spec covers the full train-step signature
(params, opt_state, batch, loss_scale); ``prefill`` covers (params, batch);
``decode`` covers (params, tokens, decode_state with a seq_len KV cache).
Modality frontends are stubs: ``memory`` is the precomputed frame/patch
embedding tensor.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import ModelConfig, ShapeConfig
from repro.data.synthetic import make_batch_specs
from repro.distributed import sharding as shd
from repro.distributed import steps as S
from repro.models.registry import get_api


def train_input_specs(cfg: ModelConfig) -> Dict[str, Any]:
    params_sds, opt_sds = S.abstract_train_state(cfg)
    return {"params": params_sds, "opt_state": opt_sds,
            "loss_scale": jax.ShapeDtypeStruct((), jnp.float32)}


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig):
    api = get_api(cfg)
    B = shape.global_batch
    max_len = shape.seq_len
    params_sds = S.abstract_params(cfg)
    mem = _memory_spec(cfg, B)

    def build(params, memory):
        return api.init_decode_state(cfg, B, max_len, memory=memory,
                                     params=params)

    if mem is not None:
        return jax.eval_shape(build, params_sds, mem)
    return jax.eval_shape(lambda p: build(p, None), params_sds)


def _memory_spec(cfg: ModelConfig, B: int):
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((B, cfg.image_tokens, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        return jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[tuple, dict]:
    """Returns (args_specs, meta) for the cell's step function."""
    if shape.kind == "train":
        base = train_input_specs(cfg)
        batch = make_batch_specs(cfg, shape)
        args = (base["params"], base["opt_state"], batch, base["loss_scale"])
        return args, {"step": "train"}
    if shape.kind == "prefill":
        params_sds = S.abstract_params(cfg)
        batch = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        mem = _memory_spec(cfg, shape.global_batch)
        if mem is not None:
            batch["memory"] = mem
        return (params_sds, batch), {"step": "prefill"}
    # decode: one new token against a seq_len KV cache
    params_sds = S.abstract_params(cfg)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    state = decode_state_specs(cfg, shape)
    return (params_sds, tokens, state), {"step": "decode"}


# ------------------------------------------------------------- shardings
def _batch_axes(mesh: Mesh, batch: int):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # replicate tiny batches (e.g. long_500k batch=1) instead of 1/16 shards
    import numpy as np
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return axes if batch >= size else ()


def train_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    zero_stage: int = 2):
    axes = S.param_axes(cfg)
    params_sds, opt_sds = S.abstract_train_state(cfg)
    p_spec = S.param_specs(axes, mesh, zero3=(zero_stage >= 3),
                           sds_tree=params_sds)
    o_spec = S.opt_specs(axes, mesh, zero_stage, opt_sds=opt_sds)
    b_axes = _batch_axes(mesh, shape.global_batch)

    def batch_spec(x):
        spec = [None] * len(x.shape)
        if spec:
            spec[0] = b_axes if b_axes else None
        return P(*spec)

    batch = make_batch_specs(cfg, shape)
    b_spec = jax.tree.map(batch_spec, batch)
    ls_spec = P()
    in_specs = (p_spec, o_spec, b_spec, ls_spec)
    out_specs = (p_spec, o_spec, P())  # params, opt, metrics(replicated)
    to = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return to(in_specs), to(out_specs)


def decode_state_spec_tree(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                           state_sds):
    """PartitionSpecs for the decode state: KV cache sharded batch->data and
    kv_seq->model (decode-time sequence parallelism); SSM state on heads."""
    b_axes = _batch_axes(mesh, shape.global_batch)

    def one(path_name, sds):
        nd = len(sds.shape)
        if path_name in ("attn_k", "attn_v"):
            spec = [None] * nd
            spec[1] = b_axes or None          # (L, B, S, Kh, D)
            spec[2] = "model"
            return P(*spec)
        if path_name in ("cross_k", "cross_v"):
            spec = [None] * nd
            spec[1] = b_axes or None
            return P(*spec)
        if path_name in ("ssm_conv",):
            spec = [None] * nd
            spec[1] = b_axes or None
            spec[-1] = "model"                # channels
            return P(*spec)
        if path_name in ("ssm_ssd",):
            spec = [None] * nd
            spec[1] = b_axes or None
            spec[2] = "model"                 # heads
            return P(*spec)
        if path_name == "pos":
            return P()
        return P(*([None] * nd))

    fields = type(state_sds)._fields
    return type(state_sds)(*[
        None if getattr(state_sds, f) is None else jax.tree.map(
            lambda s, f=f: one(f, s), getattr(state_sds, f))
        for f in fields])


def serve_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    state_sds=None):
    axes = S.param_axes(cfg)
    p_spec = S.param_specs(axes, mesh, sds_tree=S.abstract_params(cfg))
    b_axes = _batch_axes(mesh, shape.global_batch)
    to = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    if shape.kind == "prefill":
        def batch_spec(x):
            spec = [None] * len(x.shape)
            spec[0] = b_axes or None
            return P(*spec)
        batch = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        mem = _memory_spec(cfg, shape.global_batch)
        if mem is not None:
            batch["memory"] = mem
        in_specs = (p_spec, jax.tree.map(batch_spec, batch))
        out_specs = batch_spec(jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len, cfg.vocab_size), jnp.float32))
        return to(in_specs), to(out_specs)
    # decode
    assert state_sds is not None
    tok_spec = P(b_axes or None, None)
    st_spec = decode_state_spec_tree(cfg, shape, mesh, state_sds)
    st_spec = type(state_sds)(*[
        None if getattr(state_sds, f) is None else S.sanitize_specs(
            getattr(st_spec, f), getattr(state_sds, f), mesh)
        for f in type(state_sds)._fields])
    logits_spec = P(b_axes or None, None, None)
    in_specs = (p_spec, tok_spec, st_spec)
    out_specs = (logits_spec, st_spec)
    return to(in_specs), to(out_specs)
