import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.configs as C                                    # noqa: E402
from repro.common.config import (ChameleonConfig, SHAPES_BY_NAME,  # noqa: E402
                                 TrainConfig)
from repro.core.executor import Executor                     # noqa: E402
from repro.core.memtrace import build_timeline               # noqa: E402
from repro.core.policy import ChameleonOOMError, generate_policy  # noqa: E402
from repro.core.profiler import profile_jaxpr                # noqa: E402
from repro.distributed import sharding as shd                # noqa: E402
from repro.distributed import steps as S                     # noqa: E402
from repro.launch import roofline as R                       # noqa: E402
from repro.launch import specs as SP                         # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402

"""Multi-pod dry-run: ``lower().compile()`` every (arch × shape × mesh) cell
on the production mesh — 16×16 single-pod and 2×16×16 multi-pod — and emit
memory analysis + roofline terms to artifacts/dryrun/*.json.

Policy modes for train cells:
  none         save-everything baseline (the PyTorch-analogue; may exceed HBM
               — the memory analysis shows by how much)
  chameleon    paper-faithful: profile the baseline jaxpr, generate the swap
               policy (Algo 2), re-lower with the offload remat policy
  remat        full recomputation (the paper's main competitor)
  offload_all  WarmUp-stage conservative policy
"""

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def _zero_stage(arch: str) -> int:
    return 3 if arch == "llama3_2_vision_90b" else 2


def _estimate_t_iter(cfg, shape, chips: int) -> float:
    tokens = shape.global_batch * shape.seq_len
    mf = R.model_flops_train(cfg.active_param_count(), tokens)
    return mf / (chips * R.PEAK_FLOPS * 0.4)   # assume 40% MFU


# sites whose activation shards on BOTH batch (dp) and model (tp) axes
_TP_SHARDED_SITES = {"ffn_pre", "ffn_act", "qkv_proj", "attn_ctx",
                     "moe_dispatch", "moe_act", "router_logits",
                     "ssm_in", "ssm_conv", "ssm_gate", "ssm_state"}


def _per_chip_profile(prof, cfg, mesh):
    """Rescale the (global-shape) profile to per-chip bytes using each
    site's logical sharding: batch-sharded sites divide by dp, tensor-
    parallel sites by dp·tp; params by tp; optimizer state by dp·tp
    (ZeRO).  The per-device MRL then works in the same units as the
    paper's (and XLA's memory analysis)."""
    import copy
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    prof2 = copy.copy(prof)
    prof2.tensors = []
    for t in prof.tensors:
        f = dp
        if t.site in _TP_SHARDED_SITES:
            f = dp * tp
        elif t.site is None and t.shape and t.shape[-1] == cfg.vocab_size:
            f = dp * tp   # logits / softmax family: vocab dim on `model`
        t2 = copy.copy(t)
        t2.nbytes = max(t.nbytes // f, 1)
        prof2.tensors.append(t2)
    params_b = sum(
        int(jnp.dtype(x.dtype).itemsize) * int(jnp.asarray(x.shape).prod())
        for x in jax.tree_util.tree_leaves(S.abstract_params(cfg)))
    opt_b = 12 * (params_b // max(jnp.dtype(cfg.param_dtype).itemsize, 1))
    prof2.static_bytes = params_b // tp + opt_b // (dp * tp)
    return prof2


def _chameleon_policy(cfg, shape, step_fn, args_specs, chips: int,
                      budget_per_chip: int, mesh,
                      calib_xla_dyn_peak: Optional[int] = None):
    """Paper flow adapted to trace time: profile -> MRL -> policy -> apply.
    All quantities per-chip.  ``calib_xla_dyn_peak`` (the baseline compile's
    per-chip temp bytes) calibrates the reconstructed timeline against
    XLA's buffer assignment (double-buffering, co-live remat pairs, and
    fragmentation that liveness analysis alone cannot see)."""
    cj = jax.make_jaxpr(step_fn)(*args_specs)
    prof = profile_jaxpr(cj, t_iter=_estimate_t_iter(cfg, shape, chips))
    prof = _per_chip_profile(prof, cfg, mesh)
    tl = build_timeline(prof)
    if calib_xla_dyn_peak:
        dyn = max(tl.peak - prof.static_bytes, 1)
        calib = max(1.0, calib_xla_dyn_peak / dyn)
        if calib > 1.0:
            for t in prof.tensors:
                t.nbytes = int(t.nbytes * calib)
            tl = build_timeline(prof)
    info = {"baseline_peak_per_chip": int(tl.peak),
            "static_per_chip": int(prof.static_bytes),
            "budget_per_chip": int(budget_per_chip)}
    if tl.peak <= budget_per_chip:
        return Executor(ChameleonConfig()).baseline().to_jax(), \
            {**info, "policy": "fits-baseline"}
    ccfg = ChameleonConfig(hbm_budget_bytes=budget_per_chip)
    try:
        swap = generate_policy(prof, ccfg, budget_per_chip, timeline=tl)
        applied = Executor(ccfg).lower(swap, prof)
        info.update(policy="chameleon", summary=swap.summary(),
                    offload_sites=sorted(applied.offload),
                    projected_peak_per_chip=int(swap.projected_peak),
                    stall_s=swap.stall_time,
                    swapped_bytes_per_chip=int(swap.swapped_bytes))
        return applied.to_jax(), info
    except ChameleonOOMError as e:
        info.update(policy="offload_all-fallback", error=str(e))
        ccfg2 = ChameleonConfig(hbm_budget_bytes=budget_per_chip)
        return Executor(ccfg2).conservative(prof).to_jax(), info


def _baseline_dyn_peak(arch, shape_name, mesh_name, out_dir,
                       mesh=None, cfg=None, shape=None) -> Optional[int]:
    """Per-chip temp bytes of the baseline compile: read the cached
    ``none``-policy artifact, or compile it now (and cache)."""
    if out_dir:
        fname = os.path.join(out_dir,
                             f"{arch}__{shape_name}__{mesh_name}__none.json")
        if os.path.exists(fname):
            with open(fname) as f:
                rec = json.load(f)
            if rec.get("status") == "ok":
                return int(rec["memory"]["temp_bytes"])
    rec = run_cell(arch, shape_name, mesh_name == "multi", "none", out_dir,
                   verbose=False, mesh=mesh, cfg=cfg, shape=shape)
    if rec.get("status") == "ok":
        return int(rec["memory"]["temp_bytes"])
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy_mode: str = "chameleon",
             out_dir: Optional[str] = None, verbose: bool = True,
             mesh=None, cfg=None, shape=None,
             rules_name: str = "default") -> dict:
    """``mesh``/``cfg``/``shape`` overrides exist for the reduced-config
    smoke path (tests run this on an 8-device child process).
    ``rules_name='dp_only'`` applies the TP->DP hillclimb mapping."""
    cfg = cfg if cfg is not None else C.get_config(arch)
    shape = shape if shape is not None else SHAPES_BY_NAME[shape_name]
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "full-attention arch; long_500k needs "
                          "sub-quadratic decode (DESIGN.md §5)"}
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    chips = mesh.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "chips": chips, "policy_mode": policy_mode,
           "rules": rules_name}
    rules = shd.DP_ONLY_RULES if rules_name == "dp_only" else None
    t0 = time.time()
    with shd.use_mesh(mesh, rules):
        args_specs, meta = SP.input_specs(cfg, shape)
        tcfg = TrainConfig()
        if shape.kind == "train":
            # dp_only: ZeRO-3 semantics come from the rules themselves
            zero = 0 if rules_name == "dp_only" else _zero_stage(arch)
            in_sh, out_sh = SP.train_shardings(cfg, shape, mesh, zero)
            policy, pol_info = None, {"policy": policy_mode}
            if policy_mode == "chameleon":
                calib = _baseline_dyn_peak(arch, shape_name, rec["mesh"],
                                           out_dir, mesh=mesh, cfg=cfg,
                                           shape=shape)
                base_step = S.make_train_step(
                    cfg, tcfg, Executor(ChameleonConfig()).baseline().to_jax())
                policy, pol_info = _chameleon_policy(
                    cfg, shape, base_step, args_specs, chips,
                    ChameleonConfig().hbm_budget_bytes, mesh,
                    calib_xla_dyn_peak=calib)
            elif policy_mode == "none":
                policy = Executor(ChameleonConfig()).baseline().to_jax()
            elif policy_mode == "raw":
                policy = None
            elif policy_mode == "remat":
                policy = "full_remat"
            elif policy_mode == "offload_all":
                policy = Executor(ChameleonConfig()).conservative(None).to_jax()
            elif policy_mode == "offload_inputs":
                # §Perf cell C iter 3: offload only the per-layer residual
                # stream snapshot to host; rematerialize everything else
                # from it (the 3-way save/offload/remat decision at its
                # memory-minimal extreme — giant models whose activations
                # exceed host DRAM if swapped wholesale).
                from repro.core.executor import jax_offload_policy
                policy = jax_offload_policy(["ln_in"], [])
            # grads pinned to the optimizer-state sharding (2D: ZeRO axis x
            # model) so XLA reduce-scatters instead of all-reducing full
            # gradients (§Perf cell C iter 3)
            gsh = in_sh[1].m if in_sh[1].m is not None else in_sh[0]
            step = S.make_train_step(cfg, tcfg, policy, grad_shardings=gsh)
            # NOTE: out_shardings must be omitted when offload is active —
            # XLA's SPMD partitioner rejects the placement annotations that
            # explicit output shardings put on scalar outputs (RET_CHECK
            # "Side-effect HLO must have sharding").  Input shardings pin
            # the layout; outputs inherit via propagation.
            jf = jax.jit(step, in_shardings=in_sh, donate_argnums=(0, 1))
            tokens = shape.global_batch * shape.seq_len
            mf = R.model_flops_train(cfg.active_param_count(), tokens)
            rec["zero_stage"] = zero
            rec["policy_info"] = pol_info
        elif meta["step"] == "prefill":
            in_sh, out_sh = SP.serve_shardings(cfg, shape, mesh)
            step = S.make_prefill_step(cfg)
            jf = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            mf = 2.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len
        else:  # decode
            state_sds = args_specs[2]
            in_sh, out_sh = SP.serve_shardings(cfg, shape, mesh, state_sds)
            step = S.make_decode_step(cfg)
            jf = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(2,))
            mf = R.model_flops_decode(cfg.active_param_count(),
                                      shape.global_batch)

        step_cj = jax.make_jaxpr(step)(*args_specs)
        lowered = jf.lower(*args_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        terms = R.analyze(compiled, chips, model_flops=mf,
                          step_jaxpr=step_cj)

    hbm = ChameleonConfig().hbm_budget_bytes
    per_chip = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "host_temp_bytes": ma.host_temp_size_in_bytes,
            "peak_per_chip": per_chip,
            "fits_16g": bool(per_chip <= hbm),
        },
        roofline=terms.to_dict(),
    )
    # CPU backend folds pinned_host into device memory: report the analytic
    # device/host split that holds on real TPU.
    pol_info = rec.get("policy_info", {})
    if "swapped_bytes_per_chip" in pol_info:
        off = pol_info["swapped_bytes_per_chip"]
        rec["memory"]["offloaded_per_chip_analytic"] = int(off)
        rec["memory"]["device_peak_est_tpu"] = int(per_chip - off)
        rec["memory"]["fits_16g_with_offload"] = bool(
            per_chip - off <= hbm)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if rules_name == "default" else f"__{rules_name}"
        fname = (f"{arch}__{shape_name}__{rec['mesh']}"
                 f"__{policy_mode}{suffix}.json")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    if verbose:
        r = rec["roofline"]
        print(f"[{rec['mesh']:6s}] {arch:24s} {shape_name:12s} "
              f"compile={rec['compile_s']:7.1f}s "
              f"peak/chip={per_chip/2**30:6.2f}GiB "
              f"compute={r['compute_s']*1e3:8.2f}ms "
              f"mem={r['memory_s']*1e3:8.2f}ms "
              f"coll={r['collective_s']*1e3:8.2f}ms "
              f"-> {r['bottleneck']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--policy", default="chameleon",
                    choices=["none", "raw", "chameleon", "remat", "offload_all", "offload_inputs"])
    ap.add_argument("--rules", choices=["default", "dp_only"],
                    default="default")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACTS)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else C.ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES_BY_NAME)
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                sfx = "" if args.rules == "default" else f"__{args.rules}"
                fname = os.path.join(
                    args.out,
                    f"{arch}__{shape}__{mesh}__{args.policy}{sfx}.json")
                if os.path.exists(fname) and not args.force:
                    print(f"cached: {fname}")
                    continue
                try:
                    run_cell(arch, shape, mesh == "multi", args.policy,
                             args.out, rules_name=args.rules)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mesh, repr(e)))
                    print(f"FAIL {arch} {shape} {mesh}: {e!r}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
