"""Async transfer engine: queued swap-out / swap-in over the pinned pool.

Models a dedicated copy stream pair (D2H + H2D) with a bounded number of
in-flight transfers (``depth``, default 2 = double buffering).  Submission
is non-blocking and returns a :class:`TransferEvent`; the copy itself runs
when (a) the in-flight window overflows — submitting transfer *k+depth*
forces transfer *k* to retire, exactly like recycling the front buffer of
a double buffer — or (b) someone waits on the event.  Completion order is
FIFO per direction, which is what a hardware copy stream guarantees.

The **swap-out completion event is the memory release point**: the engine
holds the device-array reference until the D2H copy retires and drops it
there — the custom-``recordStream`` analogue from paper §5.4.2.  The
policy's free-times map onto these events via :meth:`plan_release`, and
the Fig-8 "reuse interval" is observable as ``event.release_op``.

Every executed copy is timed and fed to the attached
:class:`~repro.hostmem.bwmodel.BandwidthModel`, so steady-state traffic
keeps the measured latency curve fresh for the simulator.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

import numpy as np

from repro.hostmem.pool import HostBlock, PinnedSlabPool

SWAP_OUT = "out"                 # device -> host
SWAP_IN = "in"                   # host -> device


@dataclass
class TransferEvent:
    eid: int
    kind: str                    # SWAP_OUT | SWAP_IN
    tag: str
    nbytes: int
    done: bool = False
    seconds: float = 0.0         # measured copy time once done
    block: Optional[HostBlock] = None   # staging slab (owned until swap-in)
    result: Any = None           # device array (swap-in only)
    release_op: int = -1         # policy-planned release point (§5.4.2)
    _source: Any = field(default=None, repr=False)   # device ref held to done
    _callbacks: List[Callable] = field(default_factory=list, repr=False)

    def on_done(self, fn: Callable[["TransferEvent"], None]) -> None:
        if self.done:
            fn(self)
        else:
            self._callbacks.append(fn)


class TransferEngine:
    def __init__(self, pool: PinnedSlabPool, *, depth: int = 2,
                 bwmodel=None, device_put: Optional[Callable] = None):
        assert depth >= 1
        self.pool = pool
        self.depth = depth
        self.bwmodel = bwmodel
        self._device_put = device_put or self._default_device_put
        self._pending: Dict[str, Deque[TransferEvent]] = {
            SWAP_OUT: collections.deque(), SWAP_IN: collections.deque()}
        self._eid = 0
        self._planned_release: Dict[str, int] = {}
        # ---- counters ----
        self.n_out = self.n_in = 0
        self.bytes_out = self.bytes_in = 0
        self.time_out_s = self.time_in_s = 0.0
        self.forced_retires = 0          # completions forced by a full window

    @staticmethod
    def _default_device_put(arr: np.ndarray):
        import jax
        # block: ev.seconds must measure the copy, not async dispatch
        return jax.block_until_ready(jax.device_put(arr))

    # --------------------------------------------------------- submission
    def submit_swap_out(self, array, tag: str = "") -> TransferEvent:
        """Queue a D2H copy of ``array`` into a recycled pool slab."""
        nbytes = int(np.asarray(array).nbytes) if not hasattr(array, "nbytes") \
            else int(array.nbytes)
        self._eid += 1
        ev = TransferEvent(self._eid, SWAP_OUT, tag, nbytes, _source=array)
        ev.release_op = self._planned_release.get(tag, -1)
        self._enqueue(ev)
        return ev

    def submit_swap_in(self, block_or_event, tag: str = "",
                       free_block: bool = True) -> TransferEvent:
        """Queue an H2D copy restoring a staged block to the device."""
        blk = block_or_event.block if isinstance(block_or_event, TransferEvent) \
            else block_or_event
        if blk is None:
            raise ValueError("swap-in requires a completed swap-out block")
        self._eid += 1
        ev = TransferEvent(self._eid, SWAP_IN, tag or blk.tag, blk.nbytes,
                           block=blk)
        ev._free_block = free_block
        self._enqueue(ev)
        return ev

    def _enqueue(self, ev: TransferEvent) -> None:
        q = self._pending[ev.kind]
        q.append(ev)
        while len(q) > self.depth:       # double-buffer window overflow
            self.forced_retires += 1
            self._execute(q.popleft())

    # ---------------------------------------------------------- execution
    def _execute(self, ev: TransferEvent) -> None:
        t0 = time.perf_counter()
        if ev.kind == SWAP_OUT:
            ev.block = self.pool.alloc(ev.nbytes, tag=ev.tag)
            ev.block.write(ev._source)
            ev._source = None            # recordStream analogue: release here
        else:
            host = ev.block.read()
            ev.result = self._device_put(host)
            if getattr(ev, "_free_block", True):
                self.pool.free(ev.block)
        ev.seconds = time.perf_counter() - t0
        ev.done = True
        if ev.kind == SWAP_OUT:
            self.n_out += 1
            self.bytes_out += ev.nbytes
            self.time_out_s += ev.seconds
        else:
            self.n_in += 1
            self.bytes_in += ev.nbytes
            self.time_in_s += ev.seconds
        if self.bwmodel is not None:
            self.bwmodel.observe(ev.nbytes, ev.seconds)
        for fn in ev._callbacks:
            fn(ev)
        ev._callbacks.clear()

    # ------------------------------------------------------------ waiting
    def wait(self, ev: TransferEvent) -> TransferEvent:
        """Retire transfers (FIFO) until ``ev`` completes."""
        q = self._pending[ev.kind]
        while not ev.done:
            if not q:
                raise RuntimeError(f"event {ev.eid} lost from queue")
            self._execute(q.popleft())
        return ev

    def synchronize(self) -> None:
        """Retire everything in flight, in global submission order."""
        while self._pending[SWAP_OUT] or self._pending[SWAP_IN]:
            heads = [q[0] for q in self._pending.values() if q]
            nxt = min(heads, key=lambda e: e.eid)
            self._execute(self._pending[nxt.kind].popleft())

    @property
    def in_flight(self) -> int:
        return sum(len(q) for q in self._pending.values())

    # --------------------------------------- policy free-time hand-off
    def plan_release(self, tag: str, op_index: int) -> None:
        """Record the op at which the simulator promised the D2H for ``tag``
        retires (PolicyEntry.swap_out_done_op) — later swap-outs carry it."""
        self._planned_release[tag] = op_index

    def clear_planned_releases(self) -> None:
        """Drop all planned release points (a new policy supersedes them)."""
        self._planned_release.clear()

    def planned_releases(self) -> Dict[str, int]:
        return dict(self._planned_release)

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        tput = lambda b, s: b / s / 1e9 if s > 0 else 0.0   # noqa: E731
        return {
            "n_out": self.n_out, "n_in": self.n_in,
            "bytes_out": self.bytes_out, "bytes_in": self.bytes_in,
            "time_out_s": self.time_out_s, "time_in_s": self.time_in_s,
            "gbps_out": tput(self.bytes_out, self.time_out_s),
            "gbps_in": tput(self.bytes_in, self.time_in_s),
            "in_flight": self.in_flight,
            "forced_retires": self.forced_retires,
            "planned_releases": len(self._planned_release),
        }
