"""Async transfer engine: prioritized per-traffic-class streams over the
pinned pool.

The host link is shared by three kinds of traffic with very different
latency requirements, so the engine models one logical D2H/H2D stream
pair **per traffic class**:

  * ``policy_swap`` — activation swaps scheduled by the policy (§5.4);
    latency-critical: a late swap-in stalls the training step directly;
  * ``kv_spill``    — serving-side decode-slot spill/restore;
  * ``checkpoint``  — bulk checkpoint drains; huge, latency-tolerant.

Each class keeps its own FIFO queue pair and its own bounded in-flight
window (``depth``, default 2 = double buffering).  Submission is
non-blocking and returns a :class:`TransferEvent`; the copy itself runs
when (a) the class window overflows — submitting transfer *k+depth*
forces transfer *k* to retire, exactly like recycling the front buffer of
a double buffer — or (b) someone waits on the event.  Whenever the link
must run *something*, a **strict-priority scheduler** picks the head of
the highest-priority non-empty class queue: a policy swap preempts a
checkpoint drain at transfer granularity (the in-flight copy finishes,
then the swap jumps the queue), which is exactly the stall ProTrain's
interleaved chunk engine avoids (arXiv 2406.08334).  Within a class,
completion order is FIFO per direction — what a hardware copy stream
guarantees.

The **swap-out completion event is the memory release point**: the engine
holds the device-array reference until the D2H copy retires and drops it
there — the custom-``recordStream`` analogue from paper §5.4.2.  The
policy's free-times map onto these events via :meth:`plan_release`, and
the execution path drives them via :meth:`advance_op`: when the op stream
reaches a swap-out's promised ``release_op``, the transfer is retired
*then* — HBM is freed at the simulator-promised op instead of at first
reuse.

Every executed copy is timed and fed to the attached
:class:`~repro.hostmem.bwmodel.BandwidthModel`, so steady-state traffic
keeps the measured latency curve fresh for the simulator; the simulator
in turn can price link *contention* from the live per-class backlog via
:meth:`queued_delay`.

The engine is thread-safe (one re-entrant lock around queue mutation):
the checkpoint writer thread drains its class concurrently with the
training thread submitting policy swaps.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro import faults, obs
from repro.common.config import ResilienceConfig
from repro.faults.health import MEM_CLASS, HealthMonitor
from repro.hostmem.pool import HostBlock, HostMemError, PinnedSlabPool

SWAP_OUT = "out"                 # device -> host
SWAP_IN = "in"                   # host -> device


class TransferError(RuntimeError):
    """A D2H/H2D copy failed (link fault, dropped DMA, device error)."""

# Traffic classes, highest priority first (index == priority level).
TC_POLICY_SWAP = "policy_swap"
TC_KV_SPILL = "kv_spill"
TC_CHECKPOINT = "checkpoint"
TRAFFIC_CLASSES: Tuple[str, ...] = (TC_POLICY_SWAP, TC_KV_SPILL,
                                    TC_CHECKPOINT)
PRIORITY: Dict[str, int] = {c: i for i, c in enumerate(TRAFFIC_CLASSES)}

_EST_FALLBACK_GBPS = 32.0        # queued_delay estimate without a bwmodel

# arrival-rate EWMA time constant: how much enqueue history "sustained
# contention" remembers.  ~2 s spans several iterations of the reduced
# configs while forgetting a finished drain within a few constants.
ARRIVAL_TAU_S = 2.0


@dataclass
class TransferEvent:
    eid: int
    kind: str                    # SWAP_OUT | SWAP_IN
    tag: str
    nbytes: int
    cls: str = TC_POLICY_SWAP    # traffic class (stream selector)
    done: bool = False
    failed: bool = False         # terminal failure (swap-out: retained in HBM)
    seconds: float = 0.0         # measured copy time once done
    block: Optional[HostBlock] = None   # staging slab (owned until swap-in)
    result: Any = None           # device array (swap-in only)
    release_op: int = -1         # policy-planned release point (§5.4.2)
    t_submit: float = 0.0        # perf_counter at submission (queue wait)
    _source: Any = field(default=None, repr=False)   # device ref held to done
    _callbacks: List[Callable] = field(default_factory=list, repr=False)

    def on_done(self, fn: Callable[["TransferEvent"], None]) -> None:
        if self.done:
            fn(self)
        else:
            self._callbacks.append(fn)


@dataclass
class ClassCounters:
    """Per-traffic-class byte/time/stall accounting."""
    n_out: int = 0
    n_in: int = 0
    bytes_out: int = 0
    bytes_in: int = 0
    time_out_s: float = 0.0
    time_in_s: float = 0.0
    forced_retires: int = 0      # completions forced by this class's window
    stall_s: float = 0.0         # link time spent on other classes while
    stall_transfers: int = 0     # ... this class had a transfer waiting
    preemptions: int = 0         # times this class jumped a lower-class head
    released_at_op: int = 0      # swap-outs retired by advance_op (§5.4.2)
    retries: int = 0             # copy attempts re-issued after an error
    timeouts: int = 0            # copies slower than the health limit
    failures: int = 0            # terminal failures after retries exhausted
    hwm_queued_bytes: int = 0    # high-water mark of the class backlog

    def as_dict(self) -> dict:
        return {
            "n_out": self.n_out, "n_in": self.n_in,
            "bytes_out": self.bytes_out, "bytes_in": self.bytes_in,
            "time_out_s": self.time_out_s, "time_in_s": self.time_in_s,
            "forced_retires": self.forced_retires,
            "stall_s": self.stall_s,
            "stall_transfers": self.stall_transfers,
            "preemptions": self.preemptions,
            "released_at_op": self.released_at_op,
            "retries": self.retries,
            "timeouts": self.timeouts,
            "failures": self.failures,
            "hwm_queued_bytes": self.hwm_queued_bytes,
        }


class TransferEngine:
    def __init__(self, pool: PinnedSlabPool, *, depth: int = 2,
                 bwmodel=None, device_put: Optional[Callable] = None,
                 class_depths: Optional[Dict[str, int]] = None,
                 resilience: Optional[ResilienceConfig] = None):
        assert depth >= 1
        self.pool = pool
        self.depth = depth
        self.bwmodel = bwmodel
        self.resilience = resilience or ResilienceConfig()
        rs = self.resilience
        # the extra "memory" pseudo-class carries budget-headroom pressure
        # from the obs memory ledger into the same FSM the ladder reads
        self.health = HealthMonitor(
            TRAFFIC_CLASSES + (MEM_CLASS,), degrade_score=rs.degrade_score,
            fail_score=rs.fail_score,
            recover_successes=rs.recover_successes,
            residual_limit=rs.residual_limit, decay=rs.health_decay)
        self._device_put = device_put or self._default_device_put
        self._depths = {c: depth for c in TRAFFIC_CLASSES}
        for c, d in (class_depths or {}).items():
            self._check_class(c)
            self._depths[c] = max(int(d), 1)
        self._pending: Dict[Tuple[str, str], Deque[TransferEvent]] = {
            (c, k): collections.deque()
            for c in TRAFFIC_CLASSES for k in (SWAP_OUT, SWAP_IN)}
        self._eid = 0
        # per-class arrival-rate EWMA (bytes/s enqueued): exponential
        # decay over ARRIVAL_TAU_S, updated at every submit — the input
        # to sustained_contention(), which prices steady other-class
        # traffic into policy generation instead of only the
        # point-in-time backlog queued_delay() sees
        self._arr_rate_bps: Dict[str, float] = {c: 0.0
                                                for c in TRAFFIC_CLASSES}
        self._arr_mean_bytes: Dict[str, float] = {c: 0.0
                                                  for c in TRAFFIC_CLASSES}
        self._arr_last_t: Dict[str, float] = {c: 0.0
                                              for c in TRAFFIC_CLASSES}
        self._planned_release: Dict[str, int] = {}
        self._lock = threading.RLock()
        self.current_op = -1             # execution-path op cursor
        self.by_class: Dict[str, ClassCounters] = {
            c: ClassCounters() for c in TRAFFIC_CLASSES}
        # ---- aggregate counters ----
        self.n_out = self.n_in = 0
        self.bytes_out = self.bytes_in = 0
        self.time_out_s = self.time_in_s = 0.0
        self.forced_retires = 0          # completions forced by a full window
        # ---- recovery counters (repro.faults) ----
        self.n_retries = 0               # re-issued copy attempts
        self._n_latency_obs = 0          # completed copies fed to health
        self.n_timeouts = 0              # copies over the health time limit
        self.n_failed_out = 0            # swap-outs retained in HBM
        self.n_failed_in = 0             # swap-ins with data unavailable
        self.n_sync_fallback_in = 0      # swap-ins served by synchronous copy
        self.n_hbm_fallback_in = 0       # swap-ins short-circuited from HBM

    @staticmethod
    def _default_device_put(arr: np.ndarray):
        import jax
        # block: ev.seconds must measure the copy, not async dispatch
        return jax.block_until_ready(jax.device_put(arr))

    @staticmethod
    def _check_class(cls: str) -> str:
        if cls not in PRIORITY:
            raise ValueError(f"unknown traffic class {cls!r}; "
                             f"expected one of {TRAFFIC_CLASSES}")
        return cls

    # --------------------------------------------------------- submission
    def submit_swap_out(self, array, tag: str = "",
                        cls: str = TC_POLICY_SWAP) -> TransferEvent:
        """Queue a D2H copy of ``array`` on the class's stream."""
        self._check_class(cls)
        nbytes = int(np.asarray(array).nbytes) if not hasattr(array, "nbytes") \
            else int(array.nbytes)
        with self._lock:
            self._eid += 1
            ev = TransferEvent(self._eid, SWAP_OUT, tag, nbytes, cls=cls,
                               t_submit=time.perf_counter(), _source=array)
            ev.release_op = self._planned_release.get(tag, -1)
            self._enqueue(ev)
        return ev

    def submit_swap_in(self, block_or_event, tag: str = "",
                       free_block: bool = True,
                       cls: Optional[str] = None) -> TransferEvent:
        """Queue an H2D copy restoring a staged block to the device.

        Accepts a still-queued swap-out event: the dependency is
        auto-chained by retiring the swap-out first (it must have staged
        its bytes before they can come back).
        """
        with self._lock:
            if isinstance(block_or_event, TransferEvent):
                if not block_or_event.done:
                    self.wait(block_or_event)     # auto-chain the dependency
                if cls is None:
                    cls = block_or_event.cls
                src = block_or_event
                if src.failed and src.result is not None:
                    # the swap-out never left HBM (terminal D2H failure →
                    # source retained): the swap-in short-circuits to the
                    # retained device reference — bit-exact, zero copies
                    self._eid += 1
                    ev = TransferEvent(self._eid, SWAP_IN,
                                       tag or src.tag, src.nbytes,
                                       cls=self._check_class(cls),
                                       done=True, result=src.result,
                                       t_submit=time.perf_counter())
                    self.n_hbm_fallback_in += 1
                    obs.audit().event("engine.hbm_fallback_in",
                                      cls=ev.cls, tag=ev.tag[:48],
                                      nbytes=ev.nbytes)
                    return ev
                blk = src.block
            else:
                blk = block_or_event
            cls = self._check_class(cls or TC_POLICY_SWAP)
            if blk is None:
                raise ValueError(
                    "swap-in requires a staged block: the source swap-out's "
                    "slab was already consumed (freed or swapped in)")
            self._eid += 1
            ev = TransferEvent(self._eid, SWAP_IN, tag or blk.tag, blk.nbytes,
                               cls=cls, block=blk,
                               t_submit=time.perf_counter())
            ev._free_block = free_block
            self._enqueue(ev)
        return ev

    def _note_arrival(self, cls: str, nbytes: int, now: float) -> None:
        """Decay-then-add rate update: each arrival contributes
        ``nbytes / tau`` and decays exponentially, so the estimator
        converges to the true sustained bytes/s of a steady stream."""
        last = self._arr_last_t[cls]
        rate = self._arr_rate_bps[cls]
        if last > 0.0:
            rate *= float(np.exp(-(now - last) / ARRIVAL_TAU_S))
        self._arr_rate_bps[cls] = rate + nbytes / ARRIVAL_TAU_S
        mean = self._arr_mean_bytes[cls]
        self._arr_mean_bytes[cls] = (nbytes if mean == 0.0
                                     else 0.8 * mean + 0.2 * nbytes)
        self._arr_last_t[cls] = now

    def _enqueue(self, ev: TransferEvent) -> None:
        self._note_arrival(ev.cls, ev.nbytes, ev.t_submit)
        q = self._pending[(ev.cls, ev.kind)]
        q.append(ev)
        cc = self.by_class[ev.cls]
        qb = sum(e.nbytes for k in (SWAP_OUT, SWAP_IN)
                 for e in self._pending[(ev.cls, k)])
        if qb > cc.hwm_queued_bytes:
            cc.hwm_queued_bytes = qb
        while len(q) > self._depths[ev.cls]:  # class window overflow
            ran = self._step(ev.kind, waiting_cls=ev.cls)
            if ran is not None and ran.cls == ev.cls:
                # count only this class's own retirement — higher-priority
                # transfers jumping ahead are stall, not window pressure
                self.forced_retires += 1
                self.by_class[ev.cls].forced_retires += 1

    # ---------------------------------------------------------- execution
    def _step(self, kind: str,
              waiting_cls: Optional[str] = None) -> Optional[TransferEvent]:
        """Run the head of the highest-priority non-empty ``kind`` queue
        (strict priority, transfer-granularity preemption).  When a class
        is known to be waiting on the link, link time spent serving other
        classes is charged to its stall counters."""
        best = None
        for c in TRAFFIC_CLASSES:            # priority order
            q = self._pending[(c, kind)]
            if q:
                best = (c, q)
                break
        if best is None:
            return None
        c, q = best
        ev = q.popleft()
        if waiting_cls is not None and c != waiting_cls:
            # a higher-priority class jumped ahead of the waiting one
            w = self.by_class[waiting_cls]
            w.stall_transfers += 1
            self.by_class[c].preemptions += 1
        self._execute(ev)
        if waiting_cls is not None and c != waiting_cls:
            self.by_class[waiting_cls].stall_s += ev.seconds
        return ev

    def _copy_once(self, ev: TransferEvent) -> None:
        """One copy attempt, with the repro.faults hook points.  Raises on
        failure; the staging slab survives across attempts.  A swap-out
        *verifies* the payload landed before the device reference is
        dropped, so a dropped D2H is caught while the source is still
        held — the data can never be lost between retries."""
        f = faults.inject("engine.transfer_stall", key=ev.tag)
        if f is not None and f.seconds > 0:
            time.sleep(f.seconds)
        if ev.kind == SWAP_OUT:
            if ev.block is None:
                ev.block = self.pool.alloc(ev.nbytes, tag=ev.tag)
            if faults.inject("engine.transfer_error", key=ev.tag) is not None:
                raise TransferError(f"injected D2H failure ({ev.tag!r})")
            if faults.inject("engine.transfer_drop", key=ev.tag) is None:
                ev.block.write(ev._source)
            if ev.block.shape is None:   # staging never landed (dropped DMA)
                raise TransferError(f"D2H for {ev.tag!r} staged nothing")
            ev._source = None            # recordStream analogue: release here
        else:
            if faults.inject("engine.transfer_error", key=ev.tag) is not None:
                raise TransferError(f"injected H2D failure ({ev.tag!r})")
            host = ev.block.read()
            if faults.inject("engine.transfer_drop", key=ev.tag) is not None:
                raise TransferError(f"H2D for {ev.tag!r} dropped")
            ev.result = self._device_put(host)
            if getattr(ev, "_free_block", True):
                self.pool.free(ev.block)

    def _fail_transfer(self, ev: TransferEvent, err: BaseException) -> None:
        """Terminal failure after retries: degrade, don't crash.

        Swap-out: retain the source in HBM (the block simply never leaves
        the device; a later swap-in short-circuits) — bit-exact at the
        cost of budget headroom.  Swap-in: fall back to a synchronous
        host-side copy that bypasses the async device-put path; only if
        even the slab read fails is the original error surfaced (the
        payload genuinely does not exist)."""
        cc = self.by_class[ev.cls]
        self.health.note_error(ev.cls)
        if ev.kind == SWAP_OUT:
            if ev.block is not None and not ev.block.freed:
                self.pool.free(ev.block)     # exactly-once slab release
            ev.block = None
            ev.result, ev._source = ev._source, None
            ev.failed = True
            ev.done = True
            self.n_failed_out += 1
            cc.failures += 1
            obs.audit().event("engine.swap_out_failed", cls=ev.cls,
                              tag=ev.tag[:48], nbytes=ev.nbytes,
                              error=repr(err)[:120])
            obs.metrics().counter("engine_failed_out")
            # the retained tensor never left HBM: the ledger replays it
            # as resident and flags the iteration's conservation check
            obs.ledger().note_transfer("out", ev.cls, ev.tag, ev.nbytes,
                                       failed=True, release_op=ev.release_op)
        else:
            try:
                host = ev.block.read()
            except Exception:
                ev.failed = True
                ev.done = True
                self.n_failed_in += 1
                cc.failures += 1
                obs.audit().event("engine.swap_in_failed", cls=ev.cls,
                                  tag=ev.tag[:48], nbytes=ev.nbytes,
                                  error=repr(err)[:120])
                obs.ledger().note_transfer("in", ev.cls, ev.tag, ev.nbytes,
                                           failed=True)
                raise err
            ev.result = host                 # numpy result: jax converts
            if getattr(ev, "_free_block", True):
                self.pool.free(ev.block)
            ev.done = True
            self.n_sync_fallback_in += 1
            obs.audit().event("engine.sync_fallback_in", cls=ev.cls,
                              tag=ev.tag[:48], nbytes=ev.nbytes,
                              error=repr(err)[:120])
            obs.ledger().note_transfer("in", ev.cls, ev.tag, ev.nbytes)
        for fn in ev._callbacks:
            fn(ev)
        ev._callbacks.clear()

    def _note_latency(self, ev: TransferEvent, residual: Optional[float]
                      ) -> None:
        """Feed the health machine: a copy far over the bandwidth-model
        prediction (or the absolute floor) is a timeout, anything else a
        clean success carrying its residual."""
        rs = self.resilience
        self._n_latency_obs += 1
        if self._n_latency_obs <= rs.health_warmup_transfers:
            # cold start: predictions are not trustworthy yet, and the
            # first copies pay jax dispatch/slab-alloc initialization —
            # count them as plain successes, no residual
            self.health.note_success(ev.cls, None)
            return
        limit = rs.timeout_floor_s
        if residual is not None:
            limit = max(limit, rs.timeout_factor * (ev.seconds / residual))
        if ev.seconds > limit:
            self.n_timeouts += 1
            self.by_class[ev.cls].timeouts += 1
            self.health.note_timeout(ev.cls)
            obs.audit().event("engine.timeout", cls=ev.cls, tag=ev.tag[:48],
                              seconds=round(ev.seconds, 4),
                              limit=round(limit, 4))
        else:
            self.health.note_success(ev.cls, residual)

    def _execute(self, ev: TransferEvent) -> None:
        rs = self.resilience
        attempts = 0
        while True:
            t0 = time.perf_counter()
            try:
                self._copy_once(ev)
                break
            except Exception as err:     # noqa: BLE001 — injected or organic
                if not rs.enabled:
                    raise                # legacy behavior: surface directly
                attempts += 1
                if attempts > rs.max_retries:
                    self._fail_transfer(ev, err)
                    return
                self.n_retries += 1
                self.by_class[ev.cls].retries += 1
                self.health.note_retry(ev.cls)
                obs.audit().event("engine.retry", cls=ev.cls, dir=ev.kind,
                                  tag=ev.tag[:48], attempt=attempts,
                                  error=repr(err)[:120])
                delay = min(rs.retry_backoff_s * (2 ** (attempts - 1)),
                            rs.backoff_cap_s)
                if delay > 0:
                    time.sleep(delay)
        t1 = time.perf_counter()
        ev.seconds = t1 - t0
        ev.done = True
        # trace lane == traffic class: one Chrome-trace row per stream.
        # submit→start is the queue wait; start→done is the copy itself.
        obs.tracer().record(
            ev.cls, "swap_out" if ev.kind == SWAP_OUT else "swap_in",
            t0, t1,
            arg=(ev.tag, ev.nbytes,
                 round(max(t0 - ev.t_submit, 0.0), 6) if ev.t_submit else 0.0))
        obs.ledger().note_transfer(ev.kind, ev.cls, ev.tag, ev.nbytes,
                                   release_op=ev.release_op, t=t1)
        cc = self.by_class[ev.cls]
        if ev.kind == SWAP_OUT:
            self.n_out += 1
            self.bytes_out += ev.nbytes
            self.time_out_s += ev.seconds
            cc.n_out += 1
            cc.bytes_out += ev.nbytes
            cc.time_out_s += ev.seconds
        else:
            self.n_in += 1
            self.bytes_in += ev.nbytes
            self.time_in_s += ev.seconds
            cc.n_in += 1
            cc.bytes_in += ev.nbytes
            cc.time_in_s += ev.seconds
        residual = None
        if self.bwmodel is not None:
            # residual against the *pre-sample* curve, then feed the EMA;
            # the uncalibrated constant fallback wildly underestimates
            # dispatch-bound copies, so its residuals are not evidence
            pred = self.bwmodel.transfer_time(ev.nbytes)
            if pred > 0 and self.bwmodel.is_calibrated:
                residual = ev.seconds / pred
            self.bwmodel.observe(ev.nbytes, ev.seconds)
        if self.resilience.enabled:
            self._note_latency(ev, residual)
        for fn in ev._callbacks:
            fn(ev)
        ev._callbacks.clear()

    # ------------------------------------------------------------ waiting
    def wait(self, ev: TransferEvent) -> TransferEvent:
        """Retire transfers (strict priority across classes, FIFO within
        ``ev``'s class) until ``ev`` completes."""
        with self._lock:
            while not ev.done:
                if self._step(ev.kind, waiting_cls=ev.cls) is None:
                    raise RuntimeError(f"event {ev.eid} lost from queue")
        return ev

    def synchronize(self) -> None:
        """Retire everything in flight: strict priority first, submission
        order within a class."""
        with self._lock:
            while True:
                heads = [(PRIORITY[c], q[0].eid, c, k)
                         for (c, k), q in self._pending.items() if q]
                if not heads:
                    return
                _, _, c, k = min(heads)
                self._execute(self._pending[(c, k)].popleft())

    def drain_class(self, cls: str) -> int:
        """Retire every queued transfer of one class (e.g. the checkpoint
        writer flushing its drain).  Higher-priority traffic still jumps
        ahead transfer-by-transfer; returns the number of transfers run."""
        self._check_class(cls)
        n = 0
        with self._lock:
            for kind in (SWAP_OUT, SWAP_IN):
                while self._pending[(cls, kind)]:
                    self._step(kind, waiting_cls=cls)
                    n += 1
        return n

    def set_class_depth(self, cls: str, depth: int) -> None:
        """Widen a class's in-flight window (never shrinks it): a bulk
        drain raises its own depth so submission stays non-blocking and
        the whole drain remains preemptible by higher classes."""
        self._check_class(cls)
        with self._lock:
            self._depths[cls] = max(self._depths[cls], int(depth))

    @property
    def in_flight(self) -> int:
        return sum(len(q) for q in self._pending.values())

    def class_in_flight(self, cls: str) -> int:
        self._check_class(cls)
        return sum(len(self._pending[(cls, k)]) for k in (SWAP_OUT, SWAP_IN))

    # --------------------------------------- policy free-time hand-off
    def plan_release(self, tag: str, op_index: int) -> None:
        """Record the op at which the simulator promised the D2H for ``tag``
        retires (PolicyEntry.swap_out_done_op) — later swap-outs carry it."""
        self._planned_release[tag] = op_index

    def clear_planned_releases(self) -> None:
        """Drop all planned release points (a new policy supersedes them)."""
        self._planned_release.clear()

    def planned_releases(self) -> Dict[str, int]:
        return dict(self._planned_release)

    # -------------------------------------- §5.4.2 execution-path feedback
    def begin_iteration(self) -> None:
        """Reset the op cursor at an iteration boundary."""
        with self._lock:
            self.current_op = -1

    def advance_op(self, op_index: int) -> int:
        """The execution path reached ``op_index``: retire every queued
        swap-out whose simulator-promised ``release_op`` has arrived, so
        its HBM reference drops at the promised op instead of lingering
        until first reuse.  Returns the number of transfers released."""
        n = 0
        with self._lock:
            self.current_op = max(self.current_op, op_index)
            for c in TRAFFIC_CLASSES:
                q = self._pending[(c, SWAP_OUT)]
                while q and 0 <= q[0].release_op <= self.current_op:
                    ev = q.popleft()
                    self._execute(ev)
                    self.by_class[c].released_at_op += 1
                    obs.tracer().instant(c, "release@op",
                                         arg=(ev.release_op, ev.tag))
                    n += 1
        return n

    # ------------------------------------------- contention introspection
    def _est_seconds(self, nbytes: int) -> float:
        if self.bwmodel is not None:
            return self.bwmodel.transfer_time(nbytes)
        return nbytes / (_EST_FALLBACK_GBPS * 1e9)

    def queued_delay(self, cls: str = TC_POLICY_SWAP,
                     kind: str = SWAP_OUT) -> float:
        """Estimated seconds a *new* ``cls`` transfer would wait on the
        link right now: the backlog of same-or-higher-priority traffic
        plus (non-preemptive, transfer-granularity) head-of-line blocking
        by at most one lower-priority transfer."""
        self._check_class(cls)
        pri = PRIORITY[cls]
        with self._lock:
            ahead = 0.0
            hol = 0.0
            for c in TRAFFIC_CLASSES:
                q = self._pending[(c, kind)]
                if not q:
                    continue
                if PRIORITY[c] <= pri:
                    ahead += sum(self._est_seconds(e.nbytes) for e in q)
                else:
                    hol = max(hol, self._est_seconds(q[0].nbytes))
        return ahead + hol

    def arrival_rate_bps(self, cls: str, now: Optional[float] = None
                         ) -> float:
        """Current EWMA of bytes/s enqueued on ``cls`` (decayed to now)."""
        self._check_class(cls)
        with self._lock:
            last = self._arr_last_t[cls]
            rate = self._arr_rate_bps[cls]
            if last <= 0.0 or rate <= 0.0:
                return 0.0
            now = now if now is not None else time.perf_counter()
            return rate * float(np.exp(-max(now - last, 0.0)
                                       / ARRIVAL_TAU_S))

    def sustained_contention(self, cls: str = TC_POLICY_SWAP) -> float:
        """Fraction of link time *other* traffic classes occupy in steady
        state: Σ arrival_rate × est-seconds-per-byte over every class but
        ``cls``, clamped to [0, 0.95].  Scheduling is strict-priority at
        transfer granularity, so sustained lower-priority traffic still
        costs ``cls`` one head-of-line block per dispatch — in steady
        state that erosion approaches the other classes' link occupancy,
        which is what this prices (the docs/hostmem.md carried-over
        item: a rate, not the backlog snapshot ``queued_delay`` sees)."""
        self._check_class(cls)
        now = time.perf_counter()
        occ = 0.0
        with self._lock:
            for c in TRAFFIC_CLASSES:
                if c == cls:
                    continue
                last = self._arr_last_t[c]
                rate = self._arr_rate_bps[c]
                if last <= 0.0 or rate <= 0.0:
                    continue
                rate *= float(np.exp(-max(now - last, 0.0)
                                     / ARRIVAL_TAU_S))
                mean = self._arr_mean_bytes[c] or 1.0
                spb = self._est_seconds(int(mean)) / mean
                occ += rate * spb
        return min(max(occ, 0.0), 0.95)

    def queued_bytes(self, cls: str) -> int:
        """Bytes sitting in ``cls``'s queues right now — the backlog the
        simulator prices via :meth:`queued_delay`, exposed as a gauge."""
        self._check_class(cls)
        with self._lock:
            return sum(e.nbytes for k in (SWAP_OUT, SWAP_IN)
                       for e in self._pending[(cls, k)])

    def backlog_snapshot(self) -> Dict[str, dict]:
        """One consistent per-class view of the live link backlog —
        what an :class:`~repro.adapt.AdaptSnapshot` freezes so the
        background variant search prices the contention that existed
        when drift settled, not whatever the engine is doing later.
        ``queued_delay`` here is the same estimate :meth:`queued_delay`
        returns, computed for every class under a single lock hold."""
        out: Dict[str, dict] = {}
        now = time.perf_counter()
        with self._lock:
            est = {c: sum(self._est_seconds(e.nbytes)
                          for e in self._pending[(c, SWAP_OUT)])
                   for c in TRAFFIC_CLASSES}
            heads = {c: (self._est_seconds(self._pending[(c, SWAP_OUT)][0].nbytes)
                         if self._pending[(c, SWAP_OUT)] else 0.0)
                     for c in TRAFFIC_CLASSES}
            # per-class link occupancy (arrival-rate EWMA × seconds/byte),
            # decayed to now — frozen alongside the backlog so adaptation
            # prices sustained contention, not just the point-in-time queue
            load = {}
            for c in TRAFFIC_CLASSES:
                last, rate = self._arr_last_t[c], self._arr_rate_bps[c]
                if last <= 0.0 or rate <= 0.0:
                    load[c] = (0.0, 0.0)
                    continue
                rate *= float(np.exp(-max(now - last, 0.0) / ARRIVAL_TAU_S))
                mean = self._arr_mean_bytes[c] or 1.0
                load[c] = (rate, rate * self._est_seconds(int(mean)) / mean)
            for cls in TRAFFIC_CLASSES:
                pri = PRIORITY[cls]
                ahead = sum(est[c] for c in TRAFFIC_CLASSES
                            if PRIORITY[c] <= pri)
                hol = max((heads[c] for c in TRAFFIC_CLASSES
                           if PRIORITY[c] > pri), default=0.0)
                occ = sum(load[c][1] for c in TRAFFIC_CLASSES if c != cls)
                out[cls] = {
                    "queued_delay": ahead + hol,
                    "queue_depth": sum(len(self._pending[(cls, k)])
                                       for k in (SWAP_OUT, SWAP_IN)),
                    "queued_bytes": sum(e.nbytes for k in (SWAP_OUT, SWAP_IN)
                                        for e in self._pending[(cls, k)]),
                    "arrival_bps": load[cls][0],
                    "occupancy": min(max(occ, 0.0), 0.95),
                }
        return out

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        tput = lambda b, s: b / s / 1e9 if s > 0 else 0.0   # noqa: E731
        with self._lock:
            classes = {}
            total_queued = 0
            for c, cc in self.by_class.items():
                d = cc.as_dict()
                # live backlog gauges: depth (transfers) and bytes queued —
                # queued_delay prices this backlog into the simulator, the
                # gauges make it visible to stats consumers too
                d["queue_depth"] = sum(
                    len(self._pending[(c, k)]) for k in (SWAP_OUT, SWAP_IN))
                d["queued_bytes"] = sum(
                    e.nbytes for k in (SWAP_OUT, SWAP_IN)
                    for e in self._pending[(c, k)])
                d["arrival_bps"] = self._arr_rate_bps[c]
                total_queued += d["queued_bytes"]
                classes[c] = d
            return {
                "n_out": self.n_out, "n_in": self.n_in,
                "bytes_out": self.bytes_out, "bytes_in": self.bytes_in,
                "time_out_s": self.time_out_s, "time_in_s": self.time_in_s,
                "gbps_out": tput(self.bytes_out, self.time_out_s),
                "gbps_in": tput(self.bytes_in, self.time_in_s),
                "in_flight": self.in_flight,
                "queued_bytes": total_queued,
                "forced_retires": self.forced_retires,
                "planned_releases": len(self._planned_release),
                "current_op": self.current_op,
                "retries": self.n_retries,
                "timeouts": self.n_timeouts,
                "failed_out": self.n_failed_out,
                "failed_in": self.n_failed_in,
                "sync_fallback_in": self.n_sync_fallback_in,
                "hbm_fallback_in": self.n_hbm_fallback_in,
                "health": self.health.stats(),
                "classes": classes,
            }
