"""Aggregated host-memory tier counters.

One dict, stable keys, cheap to collect — surfaced through
``ChameleonRuntime.stats()["hostmem"]`` and ``Server.stats()["hostmem"]``
so dashboards and the benchmark read the same numbers.
"""
from __future__ import annotations

from typing import Optional


def collect(tier) -> dict:
    """Snapshot every component of a :class:`~repro.hostmem.HostMemTier`."""
    out = {
        "pool": tier.pool.stats(),
        "engine": tier.engine.stats(),
        "bwmodel": {
            "calibrated": tier.bwmodel.is_calibrated,
            "constant_gbps": tier.bwmodel.constant_gbps,
            "points": len(tier.bwmodel.curve()),
        },
    }
    if tier.kvspill is not None:
        out["kvspill"] = tier.kvspill.stats()
    return out


def format_summary(stats: dict) -> str:
    """Human-readable tier summary.  Tolerant of partial snapshots: a
    cold-start tier (engine with no classes populated yet, bwmodel with
    zero points, missing kvspill) must format, not crash — the summary is
    printed from CLI ``finally`` blocks where a raise would mask the real
    error."""
    p = stats.get("pool") or {}
    e = stats.get("engine") or {}
    lines = [
        f"pool: {p.get('bytes_in_use', 0) / 2**20:.1f} MiB live "
        f"(hwm {p.get('peak_bytes_in_use', 0) / 2**20:.1f} MiB) / "
        f"{p.get('bytes_reserved', 0) / 2**20:.1f} MiB reserved, "
        f"hit-rate {p.get('hit_rate', 0.0):.1%}, "
        f"frag {p.get('fragmentation', 0.0):.1%}",
        f"engine: {e.get('n_out', 0)} out "
        f"({e.get('bytes_out', 0) / 2**20:.1f} MiB, "
        f"{e.get('gbps_out', 0.0):.2f} GB/s), {e.get('n_in', 0)} in "
        f"({e.get('bytes_in', 0) / 2**20:.1f} MiB, "
        f"{e.get('gbps_in', 0.0):.2f} GB/s)",
    ]
    for cls, c in (e.get("classes") or {}).items():
        queued = c.get("queued_bytes", 0)
        if not (c.get("n_out") or c.get("n_in") or queued):
            continue
        line = (
            f"  {cls}: {c.get('n_out', 0)} out / {c.get('n_in', 0)} in, "
            f"{(c.get('bytes_out', 0) + c.get('bytes_in', 0)) / 2**20:.1f}"
            f" MiB, stall {c.get('stall_s', 0.0) * 1e3:.1f} ms "
            f"({c.get('stall_transfers', 0)} waits), "
            f"released@op {c.get('released_at_op', 0)}")
        if queued:
            line += (f", queued {c.get('queue_depth', 0)} "
                     f"({queued / 2**20:.1f} MiB)")
        if c.get("hwm_queued_bytes"):
            line += (f", backlog hwm "
                     f"{c['hwm_queued_bytes'] / 2**20:.1f} MiB")
        lines.append(line)
    bw = stats.get("bwmodel") or {}
    points = bw.get("points", 0)
    if bw.get("calibrated") and points:
        lines.append("bwmodel: calibrated, %d points" % points)
    else:
        lines.append("bwmodel: constant %.1f GB/s"
                     % bw.get("constant_gbps", 0.0))
    if "kvspill" in stats:
        k = stats["kvspill"]
        lines.append(f"kvspill: {k.get('n_spills', 0)} spills / "
                     f"{k.get('n_restores', 0)} restores, "
                     f"{k.get('bytes_spilled', 0) / 2**20:.1f} MiB out, "
                     f"live {k.get('live_bytes', 0) / 2**20:.1f} MiB "
                     f"(hwm {k.get('hwm_live_bytes', 0) / 2**20:.1f} MiB)")
    return "\n".join(lines)
