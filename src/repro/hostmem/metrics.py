"""Aggregated host-memory tier counters.

One dict, stable keys, cheap to collect — surfaced through
``ChameleonRuntime.stats()["hostmem"]`` and ``Server.stats()["hostmem"]``
so dashboards and the benchmark read the same numbers.
"""
from __future__ import annotations

from typing import Optional


def collect(tier) -> dict:
    """Snapshot every component of a :class:`~repro.hostmem.HostMemTier`."""
    out = {
        "pool": tier.pool.stats(),
        "engine": tier.engine.stats(),
        "bwmodel": {
            "calibrated": tier.bwmodel.is_calibrated,
            "constant_gbps": tier.bwmodel.constant_gbps,
            "points": len(tier.bwmodel.curve()),
        },
    }
    if tier.kvspill is not None:
        out["kvspill"] = tier.kvspill.stats()
    return out


def format_summary(stats: dict) -> str:
    p, e = stats["pool"], stats["engine"]
    lines = [
        f"pool: {p['bytes_in_use'] / 2**20:.1f} MiB live / "
        f"{p['bytes_reserved'] / 2**20:.1f} MiB reserved, "
        f"hit-rate {p['hit_rate']:.1%}, frag {p['fragmentation']:.1%}",
        f"engine: {e['n_out']} out ({e['bytes_out'] / 2**20:.1f} MiB, "
        f"{e['gbps_out']:.2f} GB/s), {e['n_in']} in "
        f"({e['bytes_in'] / 2**20:.1f} MiB, {e['gbps_in']:.2f} GB/s)",
    ]
    for cls, c in e.get("classes", {}).items():
        if not (c["n_out"] or c["n_in"]):
            continue
        lines.append(
            f"  {cls}: {c['n_out']} out / {c['n_in']} in, "
            f"{(c['bytes_out'] + c['bytes_in']) / 2**20:.1f} MiB, "
            f"stall {c['stall_s'] * 1e3:.1f} ms "
            f"({c['stall_transfers']} waits), "
            f"released@op {c['released_at_op']}")
    bw = stats["bwmodel"]
    lines.append("bwmodel: " + ("calibrated, %d points" % bw["points"]
                                if bw["calibrated"] else
                                "constant %.1f GB/s" % bw["constant_gbps"]))
    if "kvspill" in stats:
        k = stats["kvspill"]
        lines.append(f"kvspill: {k['n_spills']} spills / "
                     f"{k['n_restores']} restores, "
                     f"{k['bytes_spilled'] / 2**20:.1f} MiB out")
    return "\n".join(lines)
