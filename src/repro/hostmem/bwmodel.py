"""Measured host-link bandwidth model (replaces the Eq. 3 constant).

The paper's simulator prices every transfer at ``T = S / B`` with one
scalar ``B`` (``ChameleonConfig.host_link_gbps``).  Real host links are
nothing like that: small copies are latency-bound (fixed setup cost
dominates), large copies approach asymptotic bandwidth, and the knee is
platform-specific.  This model measures the actual curve:

  * **calibration** runs a sweep of real H2D/D2H copies across sizes and
    records the median time per size — a piecewise curve in log-size;
  * **online observation** lets the transfer engine keep refreshing the
    curve with an EMA as production swaps retire;
  * :meth:`transfer_time` interpolates the curve log-log between measured
    points, extends latency-flat below the smallest point and
    bandwidth-flat above the largest;
  * with **zero samples** it degrades to exactly the old constant —
    ``nbytes / (host_link_gbps * 1e9)`` — so an uncalibrated system
    behaves byte-for-byte like the paper baseline.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.config import HOSTMEM_CALIBRATION_SIZES

# default calibration sweep: 64 KiB .. 64 MiB (the candidate-size range —
# candidates below 64 KiB are filtered by §5.3's MIN_SWAP_BYTES anyway)
CALIBRATION_SIZES: Tuple[int, ...] = HOSTMEM_CALIBRATION_SIZES
EMA = 0.2                        # weight of a new online observation


class BandwidthModel:
    def __init__(self, constant_gbps: float = 32.0,
                 link_efficiency: float = 1.0):
        self.constant_gbps = constant_gbps
        # achieved-vs-peak host-link efficiency measured by the kernel
        # autotuner (repro.kernels.autotune).  It scales ONLY the
        # uncalibrated constant fallback: the calibrated curve is already
        # a measurement, so applying it there would double-count.  1.0
        # reproduces the paper's nominal-link pricing byte-for-byte.
        self.link_efficiency = min(max(link_efficiency, 1e-3), 1.0)
        # log2-size bucket -> (representative size, ema seconds, n samples)
        self._buckets: Dict[int, Tuple[int, float, int]] = {}
        self._curve_cache: Optional[List[Tuple[int, float]]] = None
        # observe() runs on the training thread while the adaptation
        # worker (repro.adapt) prices variants concurrently — bucket
        # writes and curve reads take the same lock; transfer_time reads
        # an immutable curve list so interpolation runs unlocked
        self._lock = threading.Lock()

    # ---------------------------------------------------------- sampling
    def observe(self, nbytes: int, seconds: float) -> None:
        if nbytes <= 0 or seconds <= 0:
            return
        b = int(math.log2(nbytes))
        with self._lock:
            size, ema, n = self._buckets.get(b, (nbytes, seconds, 0))
            ema = seconds if n == 0 else (1 - EMA) * ema + EMA * seconds
            self._buckets[b] = (max(size, nbytes), ema, n + 1)
            self._curve_cache = None

    def calibrate(self, sizes: Sequence[int] = CALIBRATION_SIZES, *,
                  iters: int = 3,
                  device_put: Optional[Callable] = None) -> "BandwidthModel":
        """Run real round-trip copies and take the per-size median."""
        if device_put is None:
            import jax
            device_put = lambda a: jax.block_until_ready(jax.device_put(a))  # noqa: E731
        for size in sizes:
            host = np.empty(size, np.uint8)
            ts = []
            for _ in range(max(iters, 1)):
                t0 = time.perf_counter()
                dev = device_put(host)          # H2D
                np.asarray(dev)                 # D2H readback
                ts.append((time.perf_counter() - t0) / 2)   # per direction
            ts.sort()
            self.observe(size, ts[len(ts) // 2])
        return self

    # ------------------------------------------------------------- query
    @property
    def is_calibrated(self) -> bool:
        return len(self._buckets) >= 2

    def _curve(self) -> List[Tuple[int, float]]:
        # the cached list is built under the lock and never mutated in
        # place, so readers may keep using a reference that a concurrent
        # observe() invalidated — they just see the previous curve
        curve = self._curve_cache
        if curve is None:
            with self._lock:
                curve = self._curve_cache = sorted(
                    (size, ema) for size, ema, _ in self._buckets.values())
        return curve

    def transfer_time(self, nbytes: int) -> float:
        """Seconds to move ``nbytes`` one way across the host link."""
        if nbytes <= 0:
            return 0.0
        if not self.is_calibrated:
            # Eq. 3 fallback, derated by the measured link efficiency
            return nbytes / (self.constant_gbps * 1e9 * self.link_efficiency)
        curve = self._curve()
        lo_s, lo_t = curve[0]
        hi_s, hi_t = curve[-1]
        if nbytes <= lo_s:
            return lo_t                    # latency floor below the sweep
        if nbytes >= hi_s:
            return hi_t * nbytes / hi_s    # asymptotic bandwidth above it
        for (s0, t0), (s1, t1) in zip(curve, curve[1:]):
            if s0 <= nbytes <= s1:
                f = ((math.log(nbytes) - math.log(s0))
                     / (math.log(s1) - math.log(s0)))
                return math.exp((1 - f) * math.log(t0) + f * math.log(t1))
        return nbytes / (self.constant_gbps * 1e9)          # unreachable

    def bandwidth_gbps(self, nbytes: int) -> float:
        t = self.transfer_time(nbytes)
        return nbytes / t / 1e9 if t > 0 else self.constant_gbps

    # ----------------------------------------------------- serialization
    def curve(self) -> List[Tuple[int, float, float]]:
        """[(size, seconds, effective GB/s)] — for reports and docs."""
        return [(s, t, s / t / 1e9) for s, t in self._curve()]

    def set_link_efficiency(self, eff: float) -> None:
        self.link_efficiency = min(max(float(eff), 1e-3), 1.0)

    def to_dict(self) -> dict:
        with self._lock:
            return {"constant_gbps": self.constant_gbps,
                    "link_efficiency": self.link_efficiency,
                    "samples": [(s, t, n)
                                for s, t, n in self._buckets.values()]}

    def snapshot(self) -> "BandwidthModel":
        """Immutable-by-convention copy for background adaptation
        (repro.adapt): the worker prices every variant of one search
        against the same frozen curve instead of chasing the live EMA."""
        return BandwidthModel.from_dict(self.to_dict())

    @classmethod
    def from_dict(cls, d: dict) -> "BandwidthModel":
        m = cls(d.get("constant_gbps", 32.0),
                link_efficiency=d.get("link_efficiency", 1.0))
        for s, t, n in d.get("samples", []):
            b = int(math.log2(s))
            m._buckets[b] = (int(s), float(t), int(n))
        m._curve_cache = None
        return m
