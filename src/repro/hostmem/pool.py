"""Pinned-host slab pool (ProTrain-style chunked host memory, arXiv
2406.08334 §4.1; Pie pooled CPU memory, arXiv 2411.09317).

Host staging buffers are grabbed once, bucketed into power-of-two size
classes, and recycled through per-class free lists so steady-state swap
traffic performs **zero** fresh allocations: every swap-out lands in a
recycled slab.  On CPU-only JAX the "pinned" property is modeled by
page-aligned numpy slabs (an `over-allocate + offset` trick); on real
backends the same free-list logic fronts `cudaHostAlloc`/TPU pinned
arenas — only `_raw_slab` changes.

Accounting invariants (enforced, property-tested):
  * a byte is never double-booked — each slab is either on exactly one
    free list or owned by exactly one live block;
  * `free()` always returns the slab to its class free list;
  * `bytes_in_use + bytes_free == bytes_reserved`.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro import faults

PAGE = 4096                      # host page size used for alignment
DEFAULT_MIN_CLASS = 1 << 12      # 4 KiB smallest slab class


class HostMemError(RuntimeError):
    """Pool misuse (double free / foreign block) or capacity exhaustion."""


def size_class(nbytes: int, min_class: int = DEFAULT_MIN_CLASS) -> int:
    """Round a request up to its power-of-two slab class."""
    c = min_class
    while c < nbytes:
        c <<= 1
    return c


def _raw_slab(class_bytes: int) -> np.ndarray:
    """Page-aligned uint8 slab — the pinned-allocation stand-in."""
    buf = np.empty(class_bytes + PAGE, np.uint8)
    off = (-buf.ctypes.data) % PAGE
    return buf[off:off + class_bytes]


@dataclass
class HostBlock:
    """A live reservation: ``data[:nbytes]`` is the caller's staging area."""
    bid: int
    nbytes: int                  # requested size
    class_bytes: int             # slab class actually reserved
    data: np.ndarray = field(repr=False)
    tag: str = ""
    freed: bool = False
    # payload descriptor — set by write(); None until then so read() can
    # give a real diagnostic instead of a bare AttributeError
    shape: Optional[tuple] = None
    dtype: Optional[np.dtype] = None

    def view(self) -> np.ndarray:
        return self.data[: self.nbytes]

    def write(self, arr) -> "HostBlock":
        """Stage a host copy of ``arr`` (any dtype/shape) into the slab —
        one copy: device->host via asarray, then a zero-copy byte view
        into the slab assignment."""
        src = np.ascontiguousarray(np.asarray(arr))
        self.view()[:] = src.view(np.uint8).ravel()
        self.shape, self.dtype = src.shape, src.dtype
        return self

    def read(self) -> np.ndarray:
        """Recover the staged array (copy — the slab stays reusable)."""
        if self.shape is None or self.dtype is None:
            raise HostMemError(
                f"block {self.bid} ({self.tag!r}) read before write: "
                "no payload has been staged, shape/dtype unknown")
        return self.view().copy().view(self.dtype).reshape(self.shape)


class PinnedSlabPool:
    """Slab/free-list allocator with size-class bucketing and reuse stats."""

    def __init__(self, capacity_bytes: Optional[int] = None,
                 min_class_bytes: int = DEFAULT_MIN_CLASS):
        self.capacity = capacity_bytes
        self.min_class = min_class_bytes
        self._free: Dict[int, List[np.ndarray]] = {}
        self._live: Dict[int, HostBlock] = {}
        self._ids = itertools.count()
        # alloc/free are called from both the training thread and the
        # checkpoint writer thread (which recycles staged slabs)
        self._lock = threading.Lock()
        # ---- stats ----
        self.bytes_reserved = 0          # total slab bytes grabbed from host
        self.bytes_in_use = 0            # requested bytes of live blocks
        self.class_bytes_in_use = 0      # slab bytes of live blocks
        self.peak_reserved = 0
        self.peak_bytes_in_use = 0       # resident-bytes high-water mark
        self.bytes_alloc_total = 0       # cumulative requested bytes allocated
        self.bytes_freed_total = 0       # cumulative requested bytes freed
        self.alloc_count = 0
        self.reuse_hits = 0              # allocs served from a free list
        self.slab_allocs = 0             # allocs that created a fresh slab
        self.free_count = 0
        self._class_in_use: Dict[int, int] = {}   # per-class resident bytes
        self._class_peaks: Dict[int, int] = {}    # per-class resident HWM

    # ------------------------------------------------------------- alloc
    def alloc(self, nbytes: int, tag: str = "") -> HostBlock:
        if nbytes <= 0:
            raise HostMemError(f"invalid allocation size {nbytes}")
        if faults.inject("pool.alloc", key=tag) is not None:
            raise HostMemError(f"injected pinned-alloc failure ({tag!r})")
        cb = size_class(nbytes, self.min_class)
        with self._lock:
            self.alloc_count += 1
            bucket = self._free.get(cb)
            if bucket:
                slab = bucket.pop()
                self.reuse_hits += 1
            else:
                # host-memory pressure: recycled slabs still serve, but a
                # fresh reservation from the host allocator is denied
                if faults.inject("pool.pressure", key=tag) is not None:
                    raise HostMemError(
                        f"injected host-memory pressure: fresh {cb}-byte "
                        f"slab denied ({tag!r})")
                if (self.capacity is not None
                        and self.bytes_reserved + cb > self.capacity):
                    raise HostMemError(
                        f"host pool exhausted: {self.bytes_reserved + cb} "
                        f"> capacity {self.capacity}")
                slab = _raw_slab(cb)
                self.slab_allocs += 1
                self.bytes_reserved += cb
                self.peak_reserved = max(self.peak_reserved,
                                         self.bytes_reserved)
            blk = HostBlock(next(self._ids), nbytes, cb, slab, tag)
            self._live[blk.bid] = blk
            self.bytes_in_use += nbytes
            self.bytes_alloc_total += nbytes
            self.peak_bytes_in_use = max(self.peak_bytes_in_use,
                                         self.bytes_in_use)
            self.class_bytes_in_use += cb
            cu = self._class_in_use.get(cb, 0) + cb
            self._class_in_use[cb] = cu
            if cu > self._class_peaks.get(cb, 0):
                self._class_peaks[cb] = cu
        return blk

    def free(self, blk: HostBlock) -> None:
        with self._lock:
            if blk.freed or blk.bid not in self._live:
                raise HostMemError(f"double free / foreign block {blk.bid}")
            del self._live[blk.bid]
            blk.freed = True
            self.bytes_in_use -= blk.nbytes
            self.bytes_freed_total += blk.nbytes
            self.class_bytes_in_use -= blk.class_bytes
            self._class_in_use[blk.class_bytes] -= blk.class_bytes
            self._free.setdefault(blk.class_bytes, []).append(blk.data)
            self.free_count += 1

    # ------------------------------------------------------------- stats
    @property
    def bytes_free(self) -> int:
        return sum(cb * len(v) for cb, v in self._free.items())

    @property
    def hit_rate(self) -> float:
        """Fraction of allocs served without touching the host allocator."""
        return self.reuse_hits / self.alloc_count if self.alloc_count else 0.0

    @property
    def fragmentation(self) -> float:
        """Internal fragmentation of live blocks: wasted / reserved-live."""
        if not self.class_bytes_in_use:
            return 0.0
        return 1.0 - self.bytes_in_use / self.class_bytes_in_use

    @property
    def live_blocks(self) -> int:
        return len(self._live)

    def stats(self) -> dict:
        return {
            "bytes_reserved": self.bytes_reserved,
            "bytes_in_use": self.bytes_in_use,
            "bytes_free": self.bytes_free,
            "peak_reserved": self.peak_reserved,
            "peak_bytes_in_use": self.peak_bytes_in_use,
            "bytes_alloc_total": self.bytes_alloc_total,
            "bytes_freed_total": self.bytes_freed_total,
            "class_peaks": dict(self._class_peaks),
            "live_blocks": self.live_blocks,
            "alloc_count": self.alloc_count,
            "reuse_hits": self.reuse_hits,
            "slab_allocs": self.slab_allocs,
            "free_count": self.free_count,
            "hit_rate": self.hit_rate,
            "fragmentation": self.fragmentation,
        }

    def check(self) -> None:
        """Book-keeping invariant — used by tests and the benchmark."""
        assert self.bytes_in_use == sum(b.nbytes for b in self._live.values())
        assert (self.class_bytes_in_use + self.bytes_free
                == self.bytes_reserved), "slab bytes leaked"
        # byte conservation: every requested byte is either still resident
        # or has been explicitly freed
        assert (self.bytes_alloc_total - self.bytes_freed_total
                == self.bytes_in_use), "alloc/free byte ledger imbalance"
        assert self.class_bytes_in_use == sum(
            v for v in self._class_in_use.values()), "class ledger imbalance"
