"""KV-cache spill/restore: park a decode slot's state in the host pool.

A serving slot is one batch row of the model's ``DecodeState`` (stacked
``(L, B, ...)`` arrays plus a ``pos`` scalar).  Spilling extracts row
``slot`` of every populated field and stages it into recycled pinned
slabs through the transfer engine; the HBM row is then free to be
overwritten by a new request.  Restoring copies the staged rows back
into (any) slot and resumes decoding exactly where the request left
off — the Pie-style "CPU memory as cache extension" move (arXiv
2411.09317), applied to continuous batching so admission can exceed
HBM-resident slots.

Round-trip is exact: slabs stage raw bytes, so restore reproduces the
kv/conv/ssd rows bit-for-bit and decode continues deterministically.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.hostmem.engine import TransferEngine, TransferEvent
from repro.hostmem.pool import PinnedSlabPool

STATE_FIELDS = ("attn_k", "attn_v", "ssm_conv", "ssm_ssd",
                "cross_k", "cross_v")


@dataclass
class SpilledSlot:
    """Host-resident image of one decode slot."""
    tag: str
    pos: int
    events: Dict[str, TransferEvent] = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return sum(e.nbytes for e in self.events.values())


class KVSpillManager:
    def __init__(self, pool: PinnedSlabPool, engine: TransferEngine):
        self.pool = pool
        self.engine = engine
        self.n_spills = self.n_restores = 0
        self.bytes_spilled = self.bytes_restored = 0

    # -------------------------------------------------------------- spill
    def spill(self, state, slot: int, tag: str = "") -> SpilledSlot:
        """Queue D2H copies of batch row ``slot`` of every state field."""
        sp = SpilledSlot(tag, pos=int(state.pos[slot]))
        for name in STATE_FIELDS:
            arr = getattr(state, name, None)
            if arr is None:
                continue
            ev = self.engine.submit_swap_out(arr[:, slot], f"{tag}/{name}")
            sp.events[name] = ev
        self.n_spills += 1
        self.bytes_spilled += sp.nbytes
        return sp

    # ------------------------------------------------------------ restore
    def restore(self, state, sp: SpilledSlot, slot: int):
        """Swap a spilled slot image back into HBM row ``slot``."""
        import jax.numpy as jnp
        upd = {}
        for name, ev_out in sp.events.items():
            self.engine.wait(ev_out)                 # staging must retire
            ev_in = self.engine.wait(
                self.engine.submit_swap_in(ev_out, f"{sp.tag}/{name}"))
            cur = getattr(state, name)
            row = jnp.asarray(ev_in.result).astype(cur.dtype)
            upd[name] = cur.at[:, slot].set(row)
        upd["pos"] = state.pos.at[slot].set(sp.pos)
        self.n_restores += 1
        self.bytes_restored += sp.nbytes
        return state._replace(**upd)

    def discard(self, sp: SpilledSlot) -> None:
        """Drop a spill image (request cancelled) — slabs go back to the
        pool without an H2D copy."""
        for ev in sp.events.values():
            self.engine.wait(ev)
            self.pool.free(ev.block)
        sp.events.clear()

    def stats(self) -> dict:
        return {"n_spills": self.n_spills, "n_restores": self.n_restores,
                "bytes_spilled": self.bytes_spilled,
                "bytes_restored": self.bytes_restored}
