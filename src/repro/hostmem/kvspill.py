"""KV-cache spill/restore: park a decode slot's state in the host pool.

A serving slot is one batch row of the model's ``DecodeState`` (stacked
``(L, B, ...)`` arrays plus a ``pos`` scalar).  Spilling gathers row
``slot`` of every populated field into **one contiguous packed buffer**
and stages it through a single ``kv_spill``-class transfer — one pool
slab and one engine copy per spill instead of one per field, so the slab
pool sees one size class per slot shape and the strict-priority engine
sees one queue entry per preemption.  The HBM row is then free to be
overwritten by a new request.  Restoring swaps the packed image back,
slices each field out of it, and resumes decoding exactly where the
request left off — the Pie-style "CPU memory as cache extension" move
(arXiv 2411.09317), applied to continuous batching so admission can
exceed HBM-resident slots.

Round-trip is exact by default: slabs stage raw bytes, so restore
reproduces the kv/conv/ssd rows bit-for-bit and decode continues
deterministically.  With ``compression="int8"``
(``HostMemConfig.spill_compression``) float rows big enough to matter
instead cross the link as row-quantized int8 payloads plus f32 scales
(the ``quant_offload`` kernels — the same path
``offload_mode="compressed"`` uses for activations), cutting staged
bytes 2-4x at <=0.4% per-row relative error; integer fields and small
rows stay raw.  ``compression="auto"`` makes raw-vs-int8 a *priced*
decision: a :class:`~repro.kernels.autotune.advisor.CompressionAdvisor`
compares measured link time for the raw row against quantize + smaller
transfer + dequantize at the tuned kernel rates, per row shape (falls
back to the static int8 rule when no advisor/tuned rates exist).

Lifetime rules (regression-tested): ``restore`` *consumes* the spill
image (the staged event is cleared, its slab freed by the H2D copy), and
``discard`` is idempotent — discarding a restored or already-discarded
image is a no-op, never a double free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.hostmem.engine import TC_KV_SPILL, TransferEngine, TransferEvent
from repro.hostmem.pool import HostMemError, PinnedSlabPool

STATE_FIELDS = ("attn_k", "attn_v", "ssm_conv", "ssm_ssd",
                "cross_k", "cross_v")

SPILL_COMPRESSIONS = ("none", "int8", "auto")


@dataclass
class FieldSlice:
    """Where one state field's row lives inside the packed image."""
    name: str
    offset: int
    nbytes: int
    shape: Tuple[int, ...]
    dtype: Any
    kind: str = "raw"              # raw | int8 (row-quantized payload)
    scale_offset: int = 0          # int8 only: f32 row scales in the image
    scale_nbytes: int = 0


@dataclass
class SpilledSlot:
    """Host-resident packed image of one decode slot."""
    tag: str
    pos: int
    layout: List[FieldSlice] = field(default_factory=list)
    nbytes: int = 0
    event: Optional[TransferEvent] = None   # None once restored/discarded

    @property
    def consumed(self) -> bool:
        return self.event is None


class KVSpillManager:
    def __init__(self, pool: PinnedSlabPool, engine: TransferEngine,
                 compression: str = "none",
                 compress_min_bytes: int = 1 << 12,
                 advisor=None):
        if compression not in SPILL_COMPRESSIONS:
            raise ValueError(f"unknown spill compression {compression!r}; "
                             f"expected one of {SPILL_COMPRESSIONS}")
        self.pool = pool
        self.engine = engine
        self.compression = compression
        self.compress_min_bytes = compress_min_bytes
        # "auto": a repro.kernels.autotune.advisor.CompressionAdvisor that
        # prices raw-vs-int8 per row from the tuned kernel rates and the
        # measured link curve; without one, auto degrades to "int8"
        self.advisor = advisor
        self.n_spills = self.n_restores = self.n_discards = 0
        self.bytes_spilled = self.bytes_restored = 0
        self.live_bytes = 0          # spill images currently host-resident
        self.hwm_live_bytes = 0      # ... and their high-water mark
        self.bytes_raw = 0             # pre-compression row bytes

    # -------------------------------------------------- int8 field packing
    def _compressible(self, arr, row_nbytes: int, row_shape=()) -> bool:
        import jax.numpy as jnp
        if (self.compression not in ("int8", "auto")
                or row_nbytes < self.compress_min_bytes
                or not jnp.issubdtype(arr.dtype, jnp.floating)
                or jnp.dtype(arr.dtype).itemsize <= 1):
            return False
        if self.compression == "int8" or self.advisor is None:
            return True              # static rule (auto w/o advisor too)
        from repro.kernels.autotune.advisor import COMPRESS_INT8
        itemsize = int(jnp.dtype(arr.dtype).itemsize)
        rows = int(np.prod(row_shape[:-1])) if len(row_shape) > 1 else 1
        choice, _ = self.advisor.decide(row_nbytes, itemsize, rows,
                                        cls=TC_KV_SPILL, tag="kvspill")
        return choice == COMPRESS_INT8

    @staticmethod
    def _quantize_row(row: np.ndarray):
        """(int8 payload, f32 per-row scales) via the quant_offload
        kernels (interpret mode off-TPU)."""
        import jax.numpy as jnp
        from repro.kernels.quant_offload import ops as Q
        q, s = Q.quantize(jnp.asarray(row))
        return (np.ascontiguousarray(np.asarray(q)),
                np.ascontiguousarray(np.asarray(s, np.float32)))

    # -------------------------------------------------------------- spill
    def spill(self, state, slot: int, tag: str = "") -> SpilledSlot:
        """Gather batch row ``slot`` of every state field into one packed
        buffer and queue a single kv_spill-class D2H copy."""
        with obs.tracer().span(obs.LANE_KV_SPILL, "kv.pack",
                               arg=(tag or "kvslot", slot)):
            return self._spill(state, slot, tag)

    def _spill(self, state, slot: int, tag: str = "") -> SpilledSlot:
        sp = SpilledSlot(tag, pos=int(state.pos[slot]))
        chunks: List[np.ndarray] = []
        off = 0
        for name in STATE_FIELDS:
            arr = getattr(state, name, None)
            if arr is None:
                continue
            row = np.ascontiguousarray(np.asarray(arr[:, slot]))
            self.bytes_raw += row.nbytes
            if self._compressible(arr, row.nbytes, row.shape):
                q, s = self._quantize_row(row)
                sp.layout.append(FieldSlice(
                    name, off, q.nbytes, q.shape, q.dtype, kind="int8",
                    scale_offset=off + q.nbytes, scale_nbytes=s.nbytes))
                chunks.extend([q.view(np.uint8).ravel(),
                               s.view(np.uint8).ravel()])
                off += q.nbytes + s.nbytes
                continue
            sp.layout.append(FieldSlice(name, off, row.nbytes,
                                        row.shape, row.dtype))
            chunks.append(row.view(np.uint8).ravel())
            off += row.nbytes
        sp.nbytes = off
        if off:
            packed = np.concatenate(chunks)
            sp.event = self.engine.submit_swap_out(
                packed, tag or "kvslot", cls=TC_KV_SPILL)
        self.n_spills += 1
        self.bytes_spilled += sp.nbytes
        self.live_bytes += sp.nbytes
        self.hwm_live_bytes = max(self.hwm_live_bytes, self.live_bytes)
        return sp

    # ------------------------------------------------------------ restore
    def restore(self, state, sp: SpilledSlot, slot: int):
        """Swap a spilled slot image back into HBM row ``slot``.  Consumes
        the image: the staged event is cleared so a later ``discard`` is a
        no-op rather than a double free."""
        with obs.tracer().span(obs.LANE_KV_SPILL, "kv.restore",
                               arg=(sp.tag, slot, sp.nbytes)):
            return self._restore(state, sp, slot)

    def _restore(self, state, sp: SpilledSlot, slot: int):
        import jax.numpy as jnp
        if sp.nbytes and sp.event is None:
            raise HostMemError(
                f"restore of consumed spill image {sp.tag!r}: it was "
                "already restored or discarded")
        upd = {}
        if sp.nbytes:
            # auto-chains if the swap-out is still queued; frees the slab
            ev_in = self.engine.wait(self.engine.submit_swap_in(
                sp.event, sp.tag, cls=TC_KV_SPILL))
            sp.event = None                       # consumed
            packed = np.asarray(ev_in.result).view(np.uint8).ravel()
            for fs in sp.layout:
                raw = packed[fs.offset:fs.offset + fs.nbytes]
                cur = getattr(state, fs.name)
                if fs.kind == "int8":
                    from repro.kernels.quant_offload import ops as Q
                    q = jnp.asarray(raw.view(np.int8).reshape(fs.shape))
                    sb = packed[fs.scale_offset:
                                fs.scale_offset + fs.scale_nbytes]
                    s = jnp.asarray(sb.view(np.float32).reshape(
                        fs.shape[:-1] + (1,)))
                    row = Q.dequantize(q, s, cur.dtype)
                else:
                    row = jnp.asarray(raw.view(fs.dtype).reshape(fs.shape))
                upd[fs.name] = cur.at[:, slot].set(row.astype(cur.dtype))
        upd["pos"] = state.pos.at[slot].set(sp.pos)
        self.n_restores += 1
        self.bytes_restored += sp.nbytes
        self.live_bytes = max(self.live_bytes - sp.nbytes, 0)
        return state._replace(**upd)

    def discard(self, sp: SpilledSlot) -> None:
        """Drop a spill image (request cancelled) — the slab goes back to
        the pool without an H2D copy.  Idempotent: discarding a restored
        or already-discarded image is a no-op."""
        ev, sp.event = sp.event, None
        if ev is None:
            return
        self.engine.wait(ev)                      # staging must retire
        self.pool.free(ev.block)
        self.n_discards += 1
        self.live_bytes = max(self.live_bytes - ev.nbytes, 0)
        # no H2D happens on a discard: tell the ledger the staged bytes
        # left the host tier so its per-class gauges stay conserved
        obs.ledger().note_release(TC_KV_SPILL, ev.tag, ev.nbytes)

    def stats(self) -> dict:
        return {"n_spills": self.n_spills, "n_restores": self.n_restores,
                "n_discards": self.n_discards,
                "bytes_spilled": self.bytes_spilled,
                "bytes_restored": self.bytes_restored,
                "live_bytes": self.live_bytes,
                "hwm_live_bytes": self.hwm_live_bytes,
                "compression": self.compression,
                "bytes_raw": self.bytes_raw,
                "compression_ratio": (self.bytes_raw / self.bytes_spilled
                                      if self.bytes_spilled else 1.0),
                "advisor": (self.advisor.stats()
                            if self.advisor is not None else None)}
