"""repro.hostmem — the host-memory tier.

One shared substrate under both branches of the system:

  * **training** (§5.4 policy execution): the simulator prices swaps with
    the measured :class:`BandwidthModel`, the policy's free-times hand
    off to the :class:`TransferEngine`'s swap-out completion events, and
    every staged tensor recycles through the :class:`PinnedSlabPool`;
  * **serving**: :class:`KVSpillManager` parks idle decode slots in the
    same pool so admission exceeds HBM-resident slots.

``HostMemTier`` bundles the four components with consistent wiring.
"""
from __future__ import annotations

from typing import Optional

from repro.common.config import ChameleonConfig, HostMemConfig
from repro.hostmem import metrics as _metrics
from repro.hostmem.bwmodel import BandwidthModel
from repro.hostmem.engine import (TC_CHECKPOINT, TC_KV_SPILL, TC_POLICY_SWAP,
                                  TRAFFIC_CLASSES, TransferEngine,
                                  TransferEvent)
from repro.hostmem.kvspill import KVSpillManager, SpilledSlot
from repro.hostmem.pool import HostBlock, HostMemError, PinnedSlabPool

__all__ = [
    "BandwidthModel", "HostBlock", "HostMemConfig", "HostMemError",
    "HostMemTier", "KVSpillManager", "PinnedSlabPool", "SpilledSlot",
    "TC_CHECKPOINT", "TC_KV_SPILL", "TC_POLICY_SWAP", "TRAFFIC_CLASSES",
    "TransferEngine", "TransferEvent",
]


class HostMemTier:
    """Pool + engine + bandwidth model + kv-spill, wired together."""

    def __init__(self, cfg: Optional[HostMemConfig] = None, *,
                 constant_gbps: float = 32.0, resilience=None):
        self.cfg = cfg or HostMemConfig()
        self.pool = PinnedSlabPool(
            capacity_bytes=self.cfg.pool_bytes or None,
            min_class_bytes=self.cfg.min_class_bytes)
        self.bwmodel = BandwidthModel(constant_gbps)
        self.engine = TransferEngine(self.pool, depth=self.cfg.engine_depth,
                                     bwmodel=self.bwmodel,
                                     class_depths=dict(self.cfg.class_depths),
                                     resilience=resilience)
        self.autotuner = None        # set by autotune()
        advisor = None
        if self.cfg.spill_compression == "auto":
            from repro.kernels.autotune.advisor import CompressionAdvisor
            advisor = CompressionAdvisor(bwmodel=self.bwmodel)
        self.kvspill = KVSpillManager(
            self.pool, self.engine,
            compression=self.cfg.spill_compression,
            compress_min_bytes=self.cfg.spill_compress_min_bytes,
            advisor=advisor)
        if self.cfg.calibrate:
            self.calibrate()

    @classmethod
    def from_chameleon(cls, ccfg: ChameleonConfig) -> Optional["HostMemTier"]:
        """Build the tier a ChameleonConfig asks for (None when disabled)."""
        if not ccfg.hostmem.enabled:
            return None
        tier = cls(ccfg.hostmem, constant_gbps=ccfg.host_link_gbps,
                   resilience=ccfg.resilience)
        if ccfg.autotune.enabled:
            tier.autotune(ccfg.autotune)
        return tier

    def autotune(self, atcfg=None, *, device_kind=None):
        """Tune the swap-path kernels against the roofline and wire the
        results into pricing (repro.kernels.autotune).

        Loads the cache (warm restart = zero re-measurement), measures
        any missing kernels, installs winners into the process-wide tuned
        table the kernel ``ops`` wrappers consult, derates the bandwidth
        model's uncalibrated fallback by the measured link efficiency,
        points the kv-spill compression advisor at the tuned rates, and
        persists cache + bandwidth snapshot atomically.  Returns the
        :class:`~repro.kernels.autotune.tuner.Autotuner`."""
        from repro.common.config import AutotuneConfig
        from repro.kernels.autotune import (Autotuner, AutotuneCache,
                                            get_device_spec, install_cache)
        atcfg = atcfg or AutotuneConfig(enabled=True)
        kind = device_kind or atcfg.device_kind
        cache = (AutotuneCache.load(atcfg.cache_dir, device_kind=kind)
                 if atcfg.cache_dir else AutotuneCache(device_kind=kind))
        tuner = Autotuner(cache=cache, spec=get_device_spec(kind),
                          iters=atcfg.iters)
        tuner.tune_all(atcfg.kernels)
        eff = tuner.link_efficiency(self.bwmodel)
        self.bwmodel.set_link_efficiency(eff)
        cache.bwmodel = self.bwmodel.to_dict()
        if atcfg.cache_dir:
            cache.save()
        install_cache(cache)
        if self.kvspill.advisor is not None:
            self.kvspill.advisor.cache = cache
        self.autotuner = tuner
        return tuner

    def calibrate(self, sizes=None, iters=None) -> "BandwidthModel":
        """Calibration transfers through the *production* path: each size
        does real swap-out/swap-in round trips via the engine.  This
        prices exactly the copies the policy will later schedule — unlike
        a raw ``device_put`` probe, which JAX may elide on CPU.  The
        engine's per-copy EMA feed is bypassed during the sweep: per-size
        *minima* of warm runs go into the curve — min is the standard
        low-noise estimator for copy cost (the first round-trip per size
        pays slab allocation and, globally, JAX dispatch initialization —
        ~3 orders of magnitude of noise)."""
        import numpy as np
        sizes = sizes if sizes is not None else self.cfg.calibration_sizes
        iters = iters if iters is not None else self.cfg.calibration_iters
        eng = self.engine
        saved, eng.bwmodel = eng.bwmodel, None
        try:
            warm = np.zeros(1024, np.uint8)      # init JAX + tiny slab class
            eng.wait(eng.submit_swap_in(
                eng.wait(eng.submit_swap_out(warm, "warm")), "warm"))
            for size in sizes:
                arr = np.zeros(size, np.uint8)
                outs, ins = [], []
                for i in range(max(iters, 1) + 1):
                    ev = eng.wait(eng.submit_swap_out(arr, "calib"))
                    ev2 = eng.wait(eng.submit_swap_in(ev, "calib"))
                    if i:                        # drop the cold run
                        outs.append(ev.seconds)
                        ins.append(ev2.seconds)
                self.bwmodel.observe(size, (min(outs) + min(ins)) / 2)
        finally:
            eng.bwmodel = saved
        return self.bwmodel

    def stats(self) -> dict:
        return _metrics.collect(self)

    def summary(self) -> str:
        return _metrics.format_summary(self.stats())
