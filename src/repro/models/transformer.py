"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layer stacks are scanned (``lax.scan`` over stacked params) so the HLO stays
small and remat/offload policies apply per scan step.  Heterogeneous stacks
(vlm: cross-attn every k; hybrid: shared attention block every k) scan over
*segments* with the irregular block applied inside the segment body.

``policy`` threads a ``jax.checkpoint`` policy (produced by the Chameleon
executor) into every scanned block — this is how a generated swap policy is
*applied* to the training program.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core.sites import tag
from repro.distributed import sharding as shd
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib


# ===================================================================== init
def _init_dense_block(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.init_norm(cfg)
    p["attn"], a["attn"] = attn.init_attention(ks[0], cfg)
    if cross:
        p["lnx"], a["lnx"] = L.init_norm(cfg)
        p["xattn"], a["xattn"] = attn.init_attention(ks[1], cfg)
        p["xgate"] = jnp.zeros((), jnp.float32)
        a["xgate"] = ()  # rank-0: stacked form is rank-1 ("layers",)
    p["ln2"], a["ln2"] = L.init_norm(cfg)
    if cfg.family == "moe" and not cross:
        p["moe"], a["moe"] = moe_lib.init_moe(ks[2], cfg)
    else:
        p["mlp"], a["mlp"] = L.init_mlp(ks[2], cfg)
    return p, a


def _init_ssm_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p, a = {}, {}
    p["ln"], a["ln"] = L.init_norm(cfg)
    p["ssm"], a["ssm"] = ssm_lib.init_ssm(ks[0], cfg)
    return p, a


def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)  # single-layer axes; prepend the layers axis
    axes = jax.tree.map(lambda t: ("layers",) + t, axes,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, type(None))) for e in x))
    return params, axes


def init_model(cfg: ModelConfig, key) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(ks[0], cfg)
    p["ln_f"], a["ln_f"] = L.init_norm(cfg)

    fam = cfg.family
    if fam in ("dense", "moe"):
        p["blocks"], a["blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg), ks[1], cfg.num_layers)
    elif fam == "ssm":
        p["blocks"], a["blocks"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg), ks[1], cfg.num_layers)
    elif fam == "hybrid":
        p["blocks"], a["blocks"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg), ks[1], cfg.num_layers)
        # zamba2: one *shared* attention block reused at every attn position
        p["shared_attn"], a["shared_attn"] = _init_dense_block(ks[2], cfg)
    elif fam == "vlm":
        every = cfg.cross_attn_every
        n_cross = cfg.num_layers // every
        n_self = cfg.num_layers - n_cross
        p["blocks"], a["blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg), ks[1], n_self)
        p["cross_blocks"], a["cross_blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg, cross=True), ks[2], n_cross)
    else:
        raise ValueError(fam)
    return p, a


# ================================================================= blocks
def dense_block(cfg: ModelConfig, p, x, positions, cross_kv=None,
                causal: bool = True):
    """Pre-norm transformer block; returns (x, aux).

    ``ln_in`` tags the layer input ONCE and every path consumes the tagged
    value, so it *is* the scan carry for remat purposes — offloading
    ``ln_in`` offloads the per-layer residual-stream snapshot (the MaxText
    decoder_layer_input pattern; §Perf cell C iter 4)."""
    aux = jnp.zeros((), jnp.float32)
    x = tag(x, "ln_in")
    h = L.apply_norm(cfg, p["ln1"], x)
    x = x + attn.self_attention(cfg, p["attn"], h, positions, causal=causal)
    x = tag(x, "resid_mid")
    if cross_kv is not None and "xattn" in p:
        h = L.apply_norm(cfg, p["lnx"], x)
        xa = attn.cross_attention(cfg, p["xattn"], h, cross_kv)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * xa
    h = L.apply_norm(cfg, p["ln2"], x)
    if "moe" in p:
        out, aux = moe_lib.apply_moe_auto(cfg, p["moe"], h)
    else:
        out = L.apply_mlp(cfg, p["mlp"], h)
    x = x + out
    return tag(x, "resid_post"), aux


def ssm_block(cfg: ModelConfig, p, x):
    x = tag(x, "ln_in")
    h = L.apply_norm(cfg, p["ln"], x)
    x = x + ssm_lib.apply_ssm(cfg, p["ssm"], h)
    return tag(x, "resid_post")


def _maybe_ckpt(fn, policy):
    if policy is None:
        return fn
    if policy == "full_remat":
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=policy)


# ============================================================ full forward
def forward(cfg: ModelConfig, params, tokens, *, positions=None,
            memory=None, policy=None, causal: bool = True):
    """tokens (B,S) -> (logits (B,S,V), aux).  ``memory`` is the stub
    modality frontend output for vlm (image patch embeds, (B,T_img,d))."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = L.embed_tokens(cfg, params["embed"], tokens, positions)
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "moe"):
        def body(carry, lp):
            x, aux = carry
            x, a = dense_block(cfg, lp, x, positions)
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(
            _maybe_ckpt(body, policy), (x, aux_total), params["blocks"])

    elif fam == "ssm":
        def body(x, lp):
            return ssm_block(cfg, lp, x), None
        x, _ = jax.lax.scan(_maybe_ckpt(body, policy), x, params["blocks"])

    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        n_seg, rem = divmod(cfg.num_layers, every)
        seg_p = jax.tree.map(
            lambda t: t[: n_seg * every].reshape((n_seg, every) + t.shape[1:]),
            params["blocks"])
        shared = params["shared_attn"]

        def seg_body(carry, sp):
            x, aux = carry
            def inner(xc, lp):
                return ssm_block(cfg, lp, xc), None
            x, _ = jax.lax.scan(inner, x, sp)
            x, a = dense_block(cfg, shared, x, positions)
            return (x, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_ckpt(seg_body, policy), (x, aux_total), seg_p)
        if rem:
            rem_p = jax.tree.map(lambda t: t[n_seg * every:], params["blocks"])
            def inner(xc, lp):
                return ssm_block(cfg, lp, xc), None
            x, _ = jax.lax.scan(_maybe_ckpt(inner, policy), x, rem_p)

    elif fam == "vlm":
        assert memory is not None, "vlm needs image patch embeddings (stub frontend)"
        every = cfg.cross_attn_every
        n_cross = cfg.num_layers // every
        n_self = cfg.num_layers - n_cross
        inner_self = every - 1
        # project cross KV once per cross block (scanned)
        def kv_one(cp):
            return attn.project_cross_kv(cfg, cp["xattn"], memory)
        cross_kv = jax.vmap(kv_one)(params["cross_blocks"])  # stacked (n_cross, ...)
        g_self = jax.tree.map(
            lambda t: t[: n_cross * inner_self].reshape(
                (n_cross, inner_self) + t.shape[1:]), params["blocks"])

        def seg_body(carry, inp):
            x, aux = carry
            sp, cp, kv = inp
            def inner(c, lp):
                xc, auxc = c
                xc, a = dense_block(cfg, lp, xc, positions)
                return (xc, auxc + a), None
            (x, aux), _ = jax.lax.scan(inner, (x, aux), sp)
            x, a = dense_block(cfg, cp, x, positions, cross_kv=kv)
            return (x, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_ckpt(seg_body, policy), (x, aux_total),
            (g_self, params["cross_blocks"], cross_kv))
        rem = n_self - n_cross * inner_self
        if rem:
            rem_p = jax.tree.map(lambda t: t[n_cross * inner_self:], params["blocks"])
            def inner(c, lp):
                xc, auxc = c
                xc, a = dense_block(cfg, lp, xc, positions)
                return (xc, auxc + a), None
            (x, aux_total), _ = jax.lax.scan(
                _maybe_ckpt(inner, policy), (x, aux_total), rem_p)
    else:
        raise ValueError(fam)

    x = L.apply_norm(cfg, params["ln_f"], x)
    x = tag(x, "final_norm")
    logits = L.unembed(cfg, params["embed"], x)
    return logits, aux_total


def loss_fn(cfg: ModelConfig, params, batch, *, policy=None):
    logits, aux = forward(cfg, params, batch["tokens"], policy=policy,
                          memory=batch.get("memory"))
    loss = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + aux, {"xent": loss, "aux": aux}


# ============================================================ decode paths
class DecodeState(NamedTuple):
    """Per-request generation state (stacked over layers where applicable)."""
    attn_k: Optional[jnp.ndarray]    # (L_attn, B, Smax, Kh, D)
    attn_v: Optional[jnp.ndarray]
    ssm_conv: Optional[jnp.ndarray]  # (L_ssm, B, W-1, ch)
    ssm_ssd: Optional[jnp.ndarray]   # (L_ssm, B, H, P, N)
    cross_k: Optional[jnp.ndarray]   # (L_cross, B, T_mem, Kh, D)
    cross_v: Optional[jnp.ndarray]
    pos: jnp.ndarray                 # (B,) next write index


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "moe"):
        return cfg.num_layers
    if cfg.family == "vlm":
        return cfg.num_layers  # self-attn in every layer (cross layers too)
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid_attn_every
    return 0


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      memory=None, params=None) -> DecodeState:
    dt = jnp.dtype(cfg.dtype)
    n_attn = _n_attn_layers(cfg)
    ak = av = None
    if n_attn:
        shape = (n_attn, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        ak, av = jnp.zeros(shape, dt), jnp.zeros(shape, dt)
    sc = sd = None
    if cfg.family in ("ssm", "hybrid"):
        n_ssm = cfg.num_layers
        sc = jnp.zeros((n_ssm, batch, cfg.ssm_conv_width - 1,
                        cfg.ssm_d_inner + 2 * cfg.ssm_state), dt)
        sd = jnp.zeros((n_ssm, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32)
    ck = cv = None
    if cfg.family == "vlm":
        assert memory is not None and params is not None
        def kv_one(cp):
            return attn.project_cross_kv(cfg, cp["xattn"], memory)
        ck, cv = jax.vmap(kv_one)(params["cross_blocks"])
    return DecodeState(ak, av, sc, sd, ck, cv,
                       jnp.zeros((batch,), jnp.int32))


def _dense_decode_block(cfg, p, x, kv, positions, cross_kv=None):
    h = L.apply_norm(cfg, p["ln1"], x)
    a_out, kv = attn.decode_self_attention(cfg, p["attn"], h, kv, positions)
    x = x + a_out
    if cross_kv is not None and "xattn" in p:
        h = L.apply_norm(cfg, p["lnx"], x)
        xa = attn.cross_attention(cfg, p["xattn"], h, cross_kv)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * xa
    h = L.apply_norm(cfg, p["ln2"], x)
    if "moe" in p:
        out, _ = moe_lib.apply_moe(cfg, p["moe"], h)
    else:
        out = L.apply_mlp(cfg, p["mlp"], h)
    return x + out, kv


def _ssm_decode_block(cfg, p, x, state):
    h = L.apply_norm(cfg, p["ln"], x)
    out, state = ssm_lib.decode_ssm(cfg, p["ssm"], h, state)
    return x + out, state


def decode_step(cfg: ModelConfig, params, tokens, state: DecodeState):
    """tokens (B,1) -> (logits (B,1,V), new state)."""
    B = tokens.shape[0]
    positions = state.pos
    x = L.embed_tokens(cfg, params["embed"], tokens, positions[:, None])
    fam = cfg.family

    if fam in ("dense", "moe"):
        def body(x, inp):
            lp, k, v = inp
            x, (k, v) = _dense_decode_block(cfg, lp, x, (k, v), positions)
            return x, (k, v)
        x, (nk, nv) = jax.lax.scan(body, x, (params["blocks"], state.attn_k, state.attn_v))
        state = state._replace(attn_k=nk, attn_v=nv)

    elif fam == "ssm":
        def body(x, inp):
            lp, c, s = inp
            x, (c, s) = _ssm_decode_block(cfg, lp, x, (c, s))
            return x, (c, s)
        x, (nc, ns) = jax.lax.scan(body, x, (params["blocks"], state.ssm_conv, state.ssm_ssd))
        state = state._replace(ssm_conv=nc, ssm_ssd=ns)

    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        n_seg, rem = divmod(cfg.num_layers, every)
        shared = params["shared_attn"]
        seg_p = jax.tree.map(
            lambda t: t[: n_seg * every].reshape((n_seg, every) + t.shape[1:]),
            params["blocks"])
        seg_c = jax.tree.map(
            lambda t: t[: n_seg * every].reshape((n_seg, every) + t.shape[1:]),
            (state.ssm_conv, state.ssm_ssd))

        def seg_body(x, inp):
            sp, (cs, ss), k, v = inp
            def inner(xc, i2):
                lp, c, s = i2
                xc, (c, s) = _ssm_decode_block(cfg, lp, xc, (c, s))
                return xc, (c, s)
            x, (cs, ss) = jax.lax.scan(inner, x, (sp, cs, ss))
            x, (k, v) = _dense_decode_block(cfg, shared, x, (k, v), positions)
            return x, ((cs, ss), k, v)

        x, ((nc, ns), nk, nv) = jax.lax.scan(
            seg_body, x, (seg_p, seg_c, state.attn_k, state.attn_v))
        nc = nc.reshape((n_seg * every,) + nc.shape[2:])
        ns = ns.reshape((n_seg * every,) + ns.shape[2:])
        if rem:
            rem_p = jax.tree.map(lambda t: t[n_seg * every:], params["blocks"])
            def inner(xc, i2):
                lp, c, s = i2
                xc, (c, s) = _ssm_decode_block(cfg, lp, xc, (c, s))
                return xc, (c, s)
            x, (rc, rs) = jax.lax.scan(
                inner, x, (rem_p, state.ssm_conv[n_seg * every:],
                           state.ssm_ssd[n_seg * every:]))
            nc = jnp.concatenate([nc, rc], axis=0)
            ns = jnp.concatenate([ns, rs], axis=0)
        state = state._replace(ssm_conv=nc, ssm_ssd=ns, attn_k=nk, attn_v=nv)

    elif fam == "vlm":
        every = cfg.cross_attn_every
        n_cross = cfg.num_layers // every
        inner_self = every - 1
        n_self = cfg.num_layers - n_cross
        # self-attn caches: first n_cross*inner_self belong to grouped selves,
        # then n_cross cross layers, then remainder selves.
        kks, vvs = state.attn_k, state.attn_v
        g_self = jax.tree.map(
            lambda t: t[: n_cross * inner_self].reshape(
                (n_cross, inner_self) + t.shape[1:]), params["blocks"])
        ks_g = kks[: n_cross * inner_self].reshape(
            (n_cross, inner_self) + kks.shape[1:])
        vs_g = vvs[: n_cross * inner_self].reshape(
            (n_cross, inner_self) + vvs.shape[1:])
        ks_c = kks[n_cross * inner_self: n_cross * inner_self + n_cross]
        vs_c = vvs[n_cross * inner_self: n_cross * inner_self + n_cross]

        def seg_body(x, inp):
            sp, k, v, cp, kc, vc, xk, xv = inp
            def inner(xc, i2):
                lp, kk, vv = i2
                xc, (kk, vv) = _dense_decode_block(cfg, lp, xc, (kk, vv), positions)
                return xc, (kk, vv)
            x, (k, v) = jax.lax.scan(inner, x, (sp, k, v))
            x, (kc, vc) = _dense_decode_block(cfg, cp, x, (kc, vc), positions,
                                              cross_kv=(xk, xv))
            return x, (k, v, kc, vc)

        x, (nkg, nvg, nkc, nvc) = jax.lax.scan(
            seg_body, x, (g_self, ks_g, vs_g, params["cross_blocks"],
                          ks_c, vs_c, state.cross_k, state.cross_v))
        nk = jnp.concatenate([nkg.reshape((-1,) + nkg.shape[2:]), nkc], axis=0)
        nv = jnp.concatenate([nvg.reshape((-1,) + nvg.shape[2:]), nvc], axis=0)
        rem = n_self - n_cross * inner_self
        if rem:
            rem_p = jax.tree.map(lambda t: t[n_cross * inner_self:], params["blocks"])
            base = n_cross * inner_self + n_cross
            def inner(xc, i2):
                lp, kk, vv = i2
                xc, (kk, vv) = _dense_decode_block(cfg, lp, xc, (kk, vv), positions)
                return xc, (kk, vv)
            x, (rk, rv) = jax.lax.scan(inner, x, (rem_p, kks[base:], vvs[base:]))
            nk = jnp.concatenate([nk, rk], axis=0)
            nv = jnp.concatenate([nv, rv], axis=0)
        state = state._replace(attn_k=nk, attn_v=nv)
    else:
        raise ValueError(fam)

    x = L.apply_norm(cfg, params["ln_f"], x)
    logits = L.unembed(cfg, params["embed"], x)
    return logits, state._replace(pos=state.pos + 1)


def prefill(cfg: ModelConfig, params, tokens, max_len: int, memory=None,
            policy=None):
    """Run the full-sequence forward and build the decode state.

    For attention families the KV cache is materialized by re-projecting K/V
    per layer (cheap relative to the forward); SSM families carry their final
    state out of the chunked scan."""
    B, S = tokens.shape
    logits, _ = forward(cfg, params, tokens, memory=memory, policy=policy)
    state = init_decode_state(cfg, B, max_len, memory=memory, params=params)

    # Re-run a light pass to collect per-layer states.  We reuse forward's
    # block structure but only track the stateful pieces.
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = L.embed_tokens(cfg, params["embed"], tokens, positions)
    fam = cfg.family

    def attn_kv_from(h, lp):
        hn = L.apply_norm(cfg, lp["ln1"], h)
        k, v = attn._project_kv(cfg, lp["attn"], hn)
        if cfg.pos_embedding == "rope":
            cos, sin = L.rope_frequencies(cfg, positions)
            k = L.apply_rope(k, cos, sin)
        return k, v

    if fam in ("dense", "moe"):
        def body(carry, lp):
            x, _aux = carry
            k, v = attn_kv_from(x, lp)
            x, a = dense_block(cfg, lp, x, positions)
            return (x, _aux + a), (k, v)
        (_, _), (ks, vs) = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        params["blocks"])
        pad = max_len - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        state = state._replace(attn_k=ks.astype(state.attn_k.dtype),
                               attn_v=vs.astype(state.attn_v.dtype))
    elif fam == "ssm":
        def body(x, lp):
            h = L.apply_norm(cfg, lp["ln"], x)
            st = _ssm_final_state(cfg, lp["ssm"], h)
            x = ssm_block(cfg, lp, x)
            return x, st
        x, (convs, ssds) = jax.lax.scan(body, x, params["blocks"])
        state = state._replace(ssm_conv=convs.astype(state.ssm_conv.dtype),
                               ssm_ssd=ssds)
    else:
        # hybrid / vlm prefill reuse decode_step token-by-token in serving;
        # the benchmark shapes only exercise dense/moe/ssm prefill.
        pass
    return logits, state._replace(pos=jnp.full((B,), S, jnp.int32))


def _ssm_final_state(cfg, p, x):
    """Compute (conv_state, ssd_state) after consuming x (B,S,d)."""
    B, S, _ = x.shape
    di, ds, nh, hp = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads,
                      cfg.ssm_head_dim)
    proj = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])
    _, xbc, dt_raw = ssm_lib._split_proj(cfg, proj)
    W = cfg.ssm_conv_width
    conv_state = xbc[:, S - (W - 1):, :] if S >= W - 1 else jnp.pad(
        xbc, ((0, 0), (W - 1 - S, 0), (0, 0)))
    xbc_c = ssm_lib._causal_conv(cfg, p, xbc)
    xs = xbc_c[..., :di].reshape(B, S, nh, hp)
    Bm = xbc_c[..., di: di + ds]
    Cm = xbc_c[..., di + ds:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    _, final = ssm_lib.ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    return conv_state.astype(x.dtype), final
