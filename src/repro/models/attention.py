"""GQA attention: dense / chunked(online-softmax) / Pallas-flash impls,
plus the decode path over an explicit KV cache.

``chunked`` is the memory-safe pure-jnp default (lax.scan over KV blocks with
running (m, l) statistics — the same algorithm the Pallas kernel implements
natively on TPU); ``pallas`` routes to ``repro.kernels.flash_attention``.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core.sites import tag
from repro.distributed import sharding as shd
from repro.models.layers import apply_rope, dense_init, rope_frequencies

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 5)
    p = {"wq": dense_init(ks[0], cfg.d_model, cfg.q_dim, cfg),
         "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, cfg),
         "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, cfg),
         "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model, cfg)}
    a = {"wq": ("embed", "q_dim"), "wk": ("embed", "kv_dim"),
         "wv": ("embed", "kv_dim"), "wo": ("q_dim", "embed")}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), p["wq"].dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), p["wk"].dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), p["wv"].dtype)
        a["bq"], a["bk"], a["bv"] = ("q_dim",), ("kv_dim",), ("kv_dim",)
    return p, a


def _project_q(cfg, p, x):
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    B, S = q.shape[:2]
    return q.reshape(B, S, cfg.num_heads, cfg.head_dim)


def _project_kv(cfg, p, x):
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if cfg.qkv_bias:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    B, S = k.shape[:2]
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return k, v


# ------------------------------------------------------------------ core
def dense_attention(cfg: ModelConfig, q, k, v, *, causal: bool,
                    q_offset: int = 0, kv_len: Optional[jnp.ndarray] = None):
    """Reference O(S^2)-memory attention. q (B,Sq,H,D), k/v (B,Sk,Kh,D)."""
    B, Sq, H, D = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qf = q.reshape(B, Sq, Kh, G, D).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bckd->bkgqc", qf, k.astype(jnp.float32))
    scores *= 1.0 / math.sqrt(D)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Sk)[None, :]
        scores = jnp.where(qpos >= kpos, scores, NEG_INF)
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, :] < kv_len[:, None]  # (B, Sk)
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgqc,bckd->bqkgd", w, v.astype(jnp.float32))
    return ctx.reshape(B, Sq, H, D).astype(q.dtype)


@functools.partial(jax.checkpoint, static_argnums=(0, 3, 4))
def _chunked_attention_inner(cfg: ModelConfig, *args, **kw):
    """Remat boundary: flash semantics — no per-chunk probabilities are ever
    saved for backward (recomputed from q/k/v, exactly what the Pallas TPU
    kernel does natively)."""
    return _chunked_attention_raw(cfg, *args, **kw)


def chunked_attention(cfg: ModelConfig, q, k, v, *, causal: bool,
                      q_offset: int = 0, kv_len: Optional[jnp.ndarray] = None):
    if kv_len is None:
        return _chunked_attention_inner(cfg, q, k, v, causal, q_offset)
    return _chunked_attention_raw(cfg, q, k, v, causal, q_offset, kv_len)


def _chunked_attention_raw(cfg: ModelConfig, q, k, v, causal: bool,
                           q_offset: int = 0,
                           kv_len: Optional[jnp.ndarray] = None):
    """Online-softmax attention scanning KV chunks: O(Sq·chunk) memory."""
    B, Sq, H, D = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    C = min(cfg.attn_chunk, Sk)
    if Sk % C:  # pad KV to a chunk multiple with masked tail
        pad = C - Sk % C
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        base_len = jnp.full((B,), Sk, jnp.int32)
        kv_len = base_len if kv_len is None else jnp.minimum(kv_len, base_len)
        Sk = Sk + pad
    n_chunks = Sk // C
    qf = q.reshape(B, Sq, Kh, G, D).astype(jnp.float32) / math.sqrt(D)
    kc = k.reshape(B, n_chunks, C, Kh, D)
    vc = v.reshape(B, n_chunks, C, Kh, D)
    qpos = jnp.arange(Sq) + q_offset

    def step(carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp
        kpos = idx * C + jnp.arange(C)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kb.astype(jnp.float32))
        if causal:
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
        if kv_len is not None:
            valid = kpos[None, :] < kv_len[:, None]
            s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Kh, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kh, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Kh, G, Sq, D), jnp.float32)
    xs = (jnp.arange(n_chunks),
          jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0))
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), xs)
    ctx = acc / jnp.maximum(l, 1e-30)[..., None]
    ctx = jnp.moveaxis(ctx, 3, 1)  # (B, Sq, Kh, G, D)
    return ctx.reshape(B, Sq, H, D).astype(q.dtype)


def _attend(cfg: ModelConfig, q, k, v, *, causal: bool, q_offset: int = 0,
            kv_len=None):
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        if kv_len is None and q.shape[1] > 1:
            return fa_ops.flash_attention(q, k, v, causal=causal)
    if cfg.attn_impl == "dense" and kv_len is None:
        return dense_attention(cfg, q, k, v, causal=causal, q_offset=q_offset)
    return chunked_attention(cfg, q, k, v, causal=causal, q_offset=q_offset,
                             kv_len=kv_len)


# -------------------------------------------------------------- fwd paths
def self_attention(cfg: ModelConfig, p, x, positions, *, causal: bool = True):
    """Full-sequence self-attention (train / prefill). x (B,S,d)."""
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    if cfg.pos_embedding == "rope":
        cos, sin = rope_frequencies(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = tag(q, "qkv_proj")
    k = tag(k, "qkv_proj")
    v = tag(v, "qkv_proj")
    q = shd.constrain(q, ("batch", "seq", "act_heads", None))
    ctx = _attend(cfg, q, k, v, causal=causal)
    ctx = tag(ctx, "attn_ctx")
    return _out_proj(cfg, p, ctx)


def _out_proj(cfg, p, ctx):
    B, S = ctx.shape[:2]
    out = jnp.einsum("bsq,qd->bsd", ctx.reshape(B, S, cfg.q_dim), p["wo"])
    out = shd.constrain(out, ("batch", "seq", "act_embed"))
    return tag(out, "attn_out")


def cross_attention(cfg: ModelConfig, p, x, kv_cache: Tuple[jnp.ndarray, jnp.ndarray]):
    """Cross-attention against precomputed encoder/image KV. x (B,S,d)."""
    q = _project_q(cfg, p, x)
    q = tag(q, "qkv_proj")
    k, v = kv_cache
    ctx = _attend(cfg, q, k, v, causal=False)
    ctx = tag(ctx, "cross_ctx")
    return _out_proj(cfg, p, ctx)


def project_cross_kv(cfg: ModelConfig, p, memory):
    """Precompute cross-attn K/V from encoder output / image embeds."""
    k, v = _project_kv(cfg, p, memory)
    return tag(k, "cross_kv"), tag(v, "cross_kv")


# ------------------------------------------------------------ decode path
class KVCache(NamedTuple):
    k: jnp.ndarray      # (B, Smax, Kh, D)
    v: jnp.ndarray      # (B, Smax, Kh, D)
    length: jnp.ndarray  # (B,) int32 — tokens already in cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
                  layers: Optional[int] = None) -> KVCache:
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = layers if layers is not None else cfg.num_layers
    shape = (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32))


def decode_self_attention(cfg: ModelConfig, p, x, layer_cache, positions):
    """One-token decode. x (B,1,d); layer_cache (k,v) (B,Smax,Kh,D);
    positions (B,) current index. Returns (out, (k,v) updated)."""
    ck, cv = layer_cache
    q = _project_q(cfg, p, x)
    k_new, v_new = _project_kv(cfg, p, x)
    if cfg.pos_embedding == "rope":
        cos, sin = rope_frequencies(cfg, positions[:, None])
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
    B = x.shape[0]
    # write the new kv at position[b] per batch row
    oh = jax.nn.one_hot(positions, ck.shape[1], dtype=ck.dtype)  # (B, Smax)
    ck = ck * (1.0 - oh)[..., None, None] + oh[..., None, None] * k_new.astype(ck.dtype)
    cv = cv * (1.0 - oh)[..., None, None] + oh[..., None, None] * v_new.astype(cv.dtype)
    ck = shd.constrain(ck, ("batch", "kv_seq", "act_kv_heads", None))
    cv = shd.constrain(cv, ("batch", "kv_seq", "act_kv_heads", None))
    ctx = _attend(cfg, q, ck, cv, causal=False, kv_len=positions + 1)
    ctx = tag(ctx, "attn_ctx")
    return _out_proj(cfg, p, ctx), (ck, cv)
