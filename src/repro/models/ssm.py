"""Mamba-2 (SSD — state-space duality) block, pure-JAX chunked formulation.

The chunked algorithm (arXiv:2405.21060 §6): within a chunk the output is a
masked quadratic form (maps to the MXU); across chunks a low-rank state
(B, H, P, N) is carried through a sequential ``lax.scan`` — the same
structure the Pallas ``ssd_scan`` kernel implements with the grid's
sequential dimension carrying state in VMEM scratch.

Decode is the O(1) recurrence over the persistent (conv, ssd) state.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core.sites import tag
from repro.distributed import sharding as shd
from repro.models.layers import apply_norm, dense_init


def init_ssm(key, cfg: ModelConfig):
    d, di, ds = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    # fused in-projection: [z (di), x (di), B (ds), C (ds), dt (nh)]
    proj_out = 2 * di + 2 * ds + nh
    p = {
        "in_proj": dense_init(ks[0], d, proj_out, cfg),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, di + 2 * ds))
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((di + 2 * ds,), dt),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], di, d, cfg),
    }
    a = {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": ("conv", "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "D": ("ssm_heads",),
        "norm_scale": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }
    return p, a


def _split_proj(cfg: ModelConfig, proj):
    di, ds, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di: 2 * di + 2 * ds]
    dt = proj[..., 2 * di + 2 * ds:]
    return z, xbc, dt


def _causal_conv(cfg: ModelConfig, p, xbc):
    """Depthwise causal conv over (B, S, C_channels)."""
    W = cfg.ssm_conv_width
    pads = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pads[:, i: i + xbc.shape[1], :] * p["conv_w"][i][None, None, :]
              for i in range(W))
    return jax.nn.silu(out + p["conv_b"][None, None, :].astype(out.dtype))


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int,
                init_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan. x (B,S,H,P), dt (B,S,H) [post-softplus], A (H,) negative,
    Bm/Cm (B,S,N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    cl = min(chunk, S)
    if S % cl:
        pad = cl - S % cl
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // cl

    xc = x.reshape(B, nc, cl, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(B, nc, cl, H).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(B, nc, cl, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(B, nc, cl, N).transpose(1, 0, 2, 3)

    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    @jax.checkpoint
    def step(state, inp):
        # Remat boundary: intra-chunk (cl x cl) matrices are recomputed in
        # the backward (the SSD kernel does the same on TPU); the carried
        # chunk states are tagged so the swap policy can offload them —
        # they are the dominant residual of SSM training.
        xb, dtb, Bb, Cb = inp          # (B,cl,H,P) (B,cl,H) (B,cl,N) (B,cl,N)
        dA = dtb * A[None, None, :]     # (B,cl,H) negative increments
        cs = jnp.cumsum(dA, axis=1)     # (B,cl,H)
        # --- intra-chunk quadratic term
        CB = jnp.einsum("bin,bjn->bij", Cb.astype(jnp.float32),
                        Bb.astype(jnp.float32))                     # (B,cl,cl)
        seg = cs[:, :, None, :] - cs[:, None, :, :]                  # (B,i,j,H)
        ii, jj = jnp.arange(cl)[:, None], jnp.arange(cl)[None, :]
        mask = (ii >= jj)[None, :, :, None]
        L = jnp.where(mask, jnp.exp(seg), 0.0)                       # (B,i,j,H)
        M = CB[:, :, :, None] * L * dtb[:, None, :, :]               # (B,i,j,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, xb.astype(jnp.float32))
        # --- contribution of carried state
        y_inter = jnp.einsum("bin,bhpn->bihp", Cb.astype(jnp.float32), state)
        y_inter = y_inter * jnp.exp(cs)[..., None]
        # --- state update
        decay_to_end = jnp.exp(cs[:, -1:, :] - cs)                   # (B,cl,H)
        xw = xb.astype(jnp.float32) * (dtb * decay_to_end)[..., None]
        new_state = (state * jnp.exp(cs[:, -1, :])[:, :, None, None]
                     + jnp.einsum("bjhp,bjn->bhpn", xw, Bb.astype(jnp.float32)))
        new_state = tag(new_state, "ssm_state")
        return new_state, (y_intra + y_inter)

    final_state, ys = jax.lax.scan(step, init_state, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, P)[:, :S]
    return y, final_state


class SSMState(NamedTuple):
    conv: jnp.ndarray   # (B, W-1, di + 2*ds)
    ssd: jnp.ndarray    # (B, H, P, N) f32


def init_ssm_state(cfg: ModelConfig, batch: int, layers=None) -> SSMState:
    di, ds = cfg.ssm_d_inner, cfg.ssm_state
    L = layers if layers is not None else cfg.num_layers
    return SSMState(
        jnp.zeros((L, batch, cfg.ssm_conv_width - 1, di + 2 * ds), jnp.dtype(cfg.dtype)),
        jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_head_dim, ds), jnp.float32))


def apply_ssm(cfg: ModelConfig, p, x):
    """Full-sequence Mamba-2 block. x (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    di, ds, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])
    proj = tag(proj, "ssm_in")
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(cfg, p, xbc)
    xbc = tag(xbc, "ssm_conv")
    xs = xbc[..., :di].reshape(B, S, nh, hp)
    Bm = xbc[..., di: di + ds]
    Cm = xbc[..., di + ds:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xs = shd.constrain(xs, ("batch", "seq", "ssm_heads", None))
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y.astype(x.dtype)
    y = y + xs.astype(jnp.float32).astype(x.dtype) * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(tag(z, "ssm_gate"))
    # grouped RMSNorm over d_inner
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
         ).astype(x.dtype) * p["norm_scale"].astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    out = shd.constrain(out, ("batch", "seq", "act_embed"))
    return tag(out, "ssm_out")


def decode_ssm(cfg: ModelConfig, p, x, state: Tuple[jnp.ndarray, jnp.ndarray]):
    """One-token decode. x (B,1,d); state (conv (B,W-1,ch), ssd (B,H,P,N))."""
    B = x.shape[0]
    di, ds, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_state, ssd_state = state
    proj = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = xbc[:, 0]                                    # (B, ch)
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B, W, ch)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    new_conv = window[:, 1:].astype(conv_state.dtype)
    xs = conv_out[..., :di].reshape(B, nh, hp)
    Bm = conv_out[..., di: di + ds]
    Cm = conv_out[..., di + ds:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                      # (B,nh)
    new_ssd = (ssd_state * dA[:, :, None, None]
               + jnp.einsum("bhp,bn->bhpn", xs * dt[..., None], Bm))
    y = jnp.einsum("bhpn,bn->bhp", new_ssd, Cm)
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
         ).astype(x.dtype) * p["norm_scale"].astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, (new_conv, new_ssd)
