"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the task spec: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d_model) directly.  Encoder blocks
are non-causal self-attention; decoder blocks are causal self-attention +
cross-attention into the encoder output.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core.sites import tag
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.transformer import (_init_dense_block, _stack_init,
                                      dense_block, _maybe_ckpt,
                                      _dense_decode_block)


def init_model(cfg: ModelConfig, key):
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(ks[0], cfg)
    p["enc_pos"] = (jax.random.normal(ks[1], (cfg.encoder_seq, cfg.d_model))
                    * 0.02).astype(jnp.dtype(cfg.param_dtype))
    a["enc_pos"] = ("pos", "embed")
    p["enc_blocks"], a["enc_blocks"] = _stack_init(
        lambda k: _init_dense_block(k, cfg), ks[2], cfg.encoder_layers)
    p["dec_blocks"], a["dec_blocks"] = _stack_init(
        lambda k: _init_dense_block(k, cfg, cross=True), ks[3], cfg.num_layers)
    p["ln_enc"], a["ln_enc"] = L.init_norm(cfg)
    p["ln_f"], a["ln_f"] = L.init_norm(cfg)
    return p, a


def encode(cfg: ModelConfig, params, frames, *, policy=None):
    """frames (B, S_enc, d) stub embeddings -> encoder output (B, S_enc, d)."""
    B, S, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][:S][None].astype(cfg.dtype)
    x = tag(x, "embed_out")

    def body(carry, lp):
        x, aux = carry
        x, a = dense_block(cfg, lp, x, pos, causal=False)
        return (x, aux + a), None

    (x, _), _ = jax.lax.scan(_maybe_ckpt(body, policy),
                             (x, jnp.zeros((), jnp.float32)),
                             params["enc_blocks"])
    return L.apply_norm(cfg, params["ln_enc"], x)


def forward(cfg: ModelConfig, params, tokens, *, memory=None, positions=None,
            policy=None, **_):
    """memory = precomputed frame embeddings (stub frontend).  Returns
    (logits (B,S,V), aux)."""
    assert memory is not None, "encdec needs frame embeddings via `memory`"
    enc = encode(cfg, params, memory, policy=policy)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = L.embed_tokens(cfg, params["embed"], tokens, positions)

    def body(carry, lp):
        x, aux = carry
        kv = attn.project_cross_kv(cfg, lp["xattn"], enc)
        x, a = dense_block(cfg, lp, x, positions, cross_kv=kv)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(_maybe_ckpt(body, policy),
                               (x, jnp.zeros((), jnp.float32)),
                               params["dec_blocks"])
    x = L.apply_norm(cfg, params["ln_f"], x)
    x = tag(x, "final_norm")
    return L.unembed(cfg, params["embed"], x), aux


def loss_fn(cfg: ModelConfig, params, batch, *, policy=None):
    logits, aux = forward(cfg, params, batch["tokens"],
                          memory=batch["memory"], policy=policy)
    loss = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + aux, {"xent": loss, "aux": aux}


class EncDecState(NamedTuple):
    attn_k: jnp.ndarray    # (L, B, Smax, Kh, D) decoder self KV
    attn_v: jnp.ndarray
    cross_k: jnp.ndarray   # (L, B, S_enc, Kh, D) static
    cross_v: jnp.ndarray
    pos: jnp.ndarray


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      memory=None, params=None) -> EncDecState:
    assert memory is not None and params is not None
    enc = encode(cfg, params, memory)
    def kv_one(lp):
        return attn.project_cross_kv(cfg, lp["xattn"], enc)
    ck, cv = jax.vmap(kv_one)(params["dec_blocks"])
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return EncDecState(jnp.zeros(shape, dt), jnp.zeros(shape, dt), ck, cv,
                       jnp.zeros((batch,), jnp.int32))


def decode_step(cfg: ModelConfig, params, tokens, state: EncDecState):
    B = tokens.shape[0]
    positions = state.pos
    x = L.embed_tokens(cfg, params["embed"], tokens, positions[:, None])

    def body(x, inp):
        lp, k, v, ck, cv = inp
        x, (k, v) = _dense_decode_block(cfg, lp, x, (k, v), positions,
                                        cross_kv=(ck, cv))
        return x, (k, v)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_blocks"], state.attn_k, state.attn_v,
                  state.cross_k, state.cross_v))
    x = L.apply_norm(cfg, params["ln_f"], x)
    logits = L.unembed(cfg, params["embed"], x)
    return logits, state._replace(attn_k=nk, attn_v=nv, pos=state.pos + 1)
