"""Token-choice top-k Mixture-of-Experts with capacity-based sort dispatch.

TPU-idiomatic formulation: no (T, E, C) one-hot dispatch tensor (T5X-style
memory blow-up at 32k sequences); instead tokens are argsorted by expert id,
ranked within their expert group, and gathered into a dense (E, C, d) batch
whose expert dim shards on the ``model`` mesh axis (expert parallelism).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core.sites import tag
from repro.distributed import sharding as shd
from repro.models.layers import dense_init, _act


def init_moe(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    std = 1.0 / math.sqrt(d)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "router": dense_init(ks[0], d, E, cfg),
        "wi_gate": (jax.random.normal(ks[1], (E, d, f)) * std).astype(dt),
        "wi_up": (jax.random.normal(ks[2], (E, d, f)) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, f, d)) * (1.0 / math.sqrt(f))).astype(dt),
    }
    a = {
        "router": ("embed", "experts"),
        "wi_gate": ("experts", "embed", "expert_mlp"),
        "wi_up": ("experts", "embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "embed"),
    }
    return p, a


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(math.ceil(num_tokens * cfg.experts_per_token
                      * cfg.moe_capacity_factor / cfg.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


# ------------------------------------------------------------ EP fast path
def apply_moe_ep(cfg: ModelConfig, p, x):
    """Expert-parallel MoE via shard_map (hillclimb optimization, see
    EXPERIMENTS.md §Perf cell A).

    The naive pjit lowering of the sort-based dispatch produced ~21 TB/chip
    of all-reduce per step (XLA replicates the scatter/gather chain).  Under
    shard_map, routing + dispatch are *local* to each (pod, data) shard —
    tokens never cross the data axis — and each model rank computes only
    its E/tp experts over its local tokens; the only communication is one
    psum of the (tokens_local, d) combine over the ``model`` axis per layer
    (268 MB/chip/layer at qwen3-moe train_4k vs ~450 GB before).
    """
    mesh = shd.current_mesh()
    assert mesh is not None and "model" in mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    from jax.sharding import PartitionSpec as P

    tp = mesh.shape["model"]
    E = cfg.num_experts
    assert E % tp == 0, (E, tp)

    def local_moe(xl, router, wg, wu, wo):
        # xl (B_loc, S, d); wg/wu/wo lead with E_loc = E/tp
        Bl, S, d = xl.shape
        E_loc = wg.shape[0]
        K = cfg.experts_per_token
        T = Bl * S
        C = capacity(cfg, T)
        xf = xl.reshape(T, d)
        r_idx = jax.lax.axis_index("model")
        e_lo = r_idx * E_loc

        logits = jnp.einsum("td,de->te", xf, router).astype(jnp.float32)
        logits = tag(logits, "router_logits")
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (T,K)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(
            jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1), axis=0)
        aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

        # local slice of the assignment: experts in [e_lo, e_lo + E_loc)
        N = T * K
        e_flat = expert_idx.reshape(N) - e_lo
        mine = (e_flat >= 0) & (e_flat < E_loc)
        e_local = jnp.where(mine, e_flat, E_loc)                  # E_loc=drop
        sort_idx = jnp.argsort(e_local, stable=True)
        sorted_e = e_local[sort_idx]
        first = jnp.searchsorted(sorted_e, jnp.arange(E_loc), side="left")
        pos = jnp.arange(N) - first[jnp.minimum(sorted_e, E_loc - 1)]
        keep = (sorted_e < E_loc) & (pos < C)
        slot = jnp.where(keep, sorted_e * C + pos, E_loc * C)
        tok = sort_idx // K
        slot_tok = jnp.zeros((E_loc * C + 1,), jnp.int32).at[slot].set(
            tok.astype(jnp.int32) + 1, mode="drop")[: E_loc * C]
        expert_in = (xf[jnp.maximum(slot_tok - 1, 0)]
                     * (slot_tok > 0)[:, None].astype(xl.dtype))
        expert_in = tag(expert_in.reshape(E_loc, C, d), "moe_dispatch")

        gate = jnp.einsum("ecd,edf->ecf", expert_in, wg)
        up = jnp.einsum("ecd,edf->ecf", expert_in, wu)
        h = tag(_act(cfg, gate) * up, "moe_act")
        expert_out = jnp.einsum("ecf,efd->ecd", h, wo)

        out_flat = jnp.concatenate(
            [expert_out.reshape(E_loc * C, d),
             jnp.zeros((1, d), expert_out.dtype)], axis=0)
        y_sorted = out_flat[slot]
        inv = jnp.argsort(sort_idx, stable=True)
        y = y_sorted[inv].reshape(T, K, d)
        out = jnp.sum(y * gate_vals[..., None].astype(y.dtype), axis=1)
        # combine partial expert outputs across the model axis
        out = jax.lax.psum(out, "model")
        out = tag(out.reshape(Bl, S, d), "moe_out")
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out, aux

    sm = shd.shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(batch_axes or None, None, None),   # x
                  P(None, None),                        # router
                  P("model", None, None),               # wi_gate
                  P("model", None, None),               # wi_up
                  P("model", None, None)),              # wo
        out_specs=(P(batch_axes or None, None, None), P()),
        check_vma=False)
    return sm(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])


def apply_moe_auto(cfg: ModelConfig, p, x):
    """EP fast path when the active rules put experts on the model axis,
    else the portable gather implementation (also used under dp_only
    rules, where experts are data-local)."""
    mesh = shd.current_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.num_experts % mesh.shape["model"] == 0
            and tuple(shd.spec(("experts",)))[:1] == ("model",)):
        return apply_moe_ep(cfg, p, x)
    return apply_moe(cfg, p, x)


def apply_moe(cfg: ModelConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = capacity(cfg, T)
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    logits = tag(logits, "router_logits")
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)           # (T,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1), axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- sort-based dispatch
    N = T * K
    e_flat = expert_idx.reshape(N)
    sort_idx = jnp.argsort(e_flat, stable=True)                # (N,)
    sorted_e = e_flat[sort_idx]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos = jnp.arange(N) - first[sorted_e]
    keep = pos < C
    slot = jnp.where(keep, sorted_e * C + pos, E * C)          # E*C = drop slot
    tok = sort_idx // K
    # slot -> token map (0 = empty)
    slot_tok = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(
        tok.astype(jnp.int32) + 1, mode="drop")
    slot_tok = slot_tok[: E * C]
    expert_in = xf[jnp.maximum(slot_tok - 1, 0)] * (slot_tok > 0)[:, None].astype(x.dtype)
    expert_in = expert_in.reshape(E, C, d)
    expert_in = shd.constrain(expert_in, ("experts", None, "act_embed"))
    expert_in = tag(expert_in, "moe_dispatch")

    # ---- expert computation (E sharded on `model`)
    gate = jnp.einsum("ecd,edf->ecf", expert_in, p["wi_gate"])
    up = jnp.einsum("ecd,edf->ecf", expert_in, p["wi_up"])
    h = _act(cfg, gate) * up
    h = shd.constrain(h, ("experts", None, "expert_mlp"))
    h = tag(h, "moe_act")
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    expert_out = shd.constrain(expert_out, ("experts", None, "act_embed"))

    # ---- combine
    out_flat = jnp.concatenate(
        [expert_out.reshape(E * C, d),
         jnp.zeros((1, d), expert_out.dtype)], axis=0)
    y_sorted = out_flat[slot]                                  # (N, d)
    inv = jnp.argsort(sort_idx, stable=True)
    y = y_sorted[inv].reshape(T, K, d)
    out = jnp.sum(y * gate_vals[..., None].astype(y.dtype), axis=1)
    out = tag(out.reshape(B, S, d), "moe_out")
    out = shd.constrain(out, ("batch", "seq", "act_embed"))
    return out, aux.astype(jnp.float32)
