"""Family dispatch: one uniform API over the model zoo."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.common.config import ModelConfig
from repro.models import transformer, whisper


class ModelApi(NamedTuple):
    init: Callable          # (cfg, key) -> (params, axes)
    forward: Callable       # (cfg, params, tokens, **kw) -> (logits, aux)
    loss_fn: Callable       # (cfg, params, batch, *, policy) -> (loss, metrics)
    decode_step: Callable   # (cfg, params, tokens, state) -> (logits, state)
    init_decode_state: Callable


def get_api(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "encdec":
        return ModelApi(whisper.init_model, whisper.forward, whisper.loss_fn,
                        whisper.decode_step, whisper.init_decode_state)
    return ModelApi(transformer.init_model, transformer.forward,
                    transformer.loss_fn, transformer.decode_step,
                    transformer.init_decode_state)
