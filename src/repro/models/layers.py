"""Shared building blocks: init, norms, RoPE, MLP, embeddings.

Parameters are plain nested dicts of jnp arrays.  Each ``init_*`` returns
``(params, axes)`` where ``axes`` mirrors the params pytree with tuples of
*logical* axis names consumed by ``distributed.sharding``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core.sites import tag
from repro.distributed import sharding as shd


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, in_dim: int, out_dim: int, cfg: ModelConfig, scale: float = 1.0):
    std = scale / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * std).astype(_dtype(cfg))


# ----------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), _dtype(cfg))}, {"scale": ("embed",)}
    return ({"scale": jnp.ones((d,), _dtype(cfg)),
             "bias": jnp.zeros((d,), _dtype(cfg))},
            {"scale": ("embed",), "bias": ("embed",)})


def apply_norm(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ RoPE
def rope_frequencies(cfg: ModelConfig, positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (..., S) -> cos/sin of shape (..., S, head_dim/2), f32."""
    half = cfg.head_dim // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, hd); cos/sin (..., S, hd/2). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.glu:
        p = {"wi_gate": dense_init(ks[0], cfg.d_model, d_ff, cfg),
             "wi_up": dense_init(ks[1], cfg.d_model, d_ff, cfg),
             "wo": dense_init(ks[2], d_ff, cfg.d_model, cfg)}
        a = {"wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"),
             "wo": ("mlp", "embed")}
    else:
        p = {"wi_up": dense_init(ks[1], cfg.d_model, d_ff, cfg),
             "wo": dense_init(ks[2], d_ff, cfg.d_model, cfg)}
        a = {"wi_up": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return p, a


def _act(cfg: ModelConfig, x):
    if cfg.act == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x)


def apply_mlp(cfg: ModelConfig, p, x):
    """x (B, S, d) -> (B, S, d)."""
    up = tag(jnp.einsum("bsd,df->bsf", x, p["wi_up"]), "ffn_pre")
    if cfg.glu:
        gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
        h = tag(gate, "ffn_pre")
        h = _act(cfg, h) * up
    else:
        h = _act(cfg, up)
    h = shd.constrain(h, ("batch", "seq", "act_mlp"))
    h = tag(h, "ffn_act")
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    out = shd.constrain(out, ("batch", "seq", "act_embed"))
    return tag(out, "ffn_out")


# ------------------------------------------------------------- embedding
def init_embedding(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02
                 ).astype(_dtype(cfg))}
    a = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, cfg)
        a["unembed"] = ("embed", "vocab")
    if cfg.pos_embedding == "learned":
        p["pos"] = (jax.random.normal(ks[2], (cfg.max_position, cfg.d_model)) * 0.02
                    ).astype(_dtype(cfg))
        a["pos"] = ("pos", "embed")
    return p, a


def embed_tokens(cfg: ModelConfig, p, tokens, positions=None):
    x = jnp.take(p["tok"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.pos_embedding == "learned":
        assert positions is not None
        x = x + jnp.take(p["pos"], positions, axis=0).astype(x.dtype)
    x = shd.constrain(x, ("batch", "seq", "act_embed"))
    return tag(x, "embed_out")


def unembed(cfg: ModelConfig, p, x):
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    logits = shd.constrain(logits, ("batch", "seq", "act_vocab"))
    if cfg.logits_softcap:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return logits


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Stable softmax-xent; logits (B,S,V) possibly vocab-sharded."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
