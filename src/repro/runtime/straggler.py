"""Straggler detection (large-scale posture).

Per-step wall times feed an EWMA mean/variance; a step (or a host, when
per-host timings are reported by the launcher's heartbeat channel) whose
time exceeds ``mean + k·std`` is flagged.  Mitigation hooks:
  * report   — structured event for the orchestrator
  * rebalance — shrink the flagged host's data shard (skew map)
  * evict    — request elastic restart without the host (checkpoint+resume)
On this single-host container the detector is exercised by tests with
injected delays; the mitigation callbacks are the integration surface.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class StragglerEvent:
    step: int
    host: int
    t: float
    mean: float
    std: float


@dataclass
class StragglerDetector:
    threshold_sigma: float = 3.0
    alpha: float = 0.05                  # EWMA decay
    warmup: int = 5                      # ignore first steps (compiles)
    on_straggler: Optional[Callable[[StragglerEvent], None]] = None
    _mean: Dict[int, float] = field(default_factory=dict)
    _var: Dict[int, float] = field(default_factory=dict)
    _n: Dict[int, int] = field(default_factory=dict)
    events: List[StragglerEvent] = field(default_factory=list)

    def observe(self, step: int, t: float, host: int = 0) -> bool:
        n = self._n.get(host, 0)
        self._n[host] = n + 1
        if n == 0:
            self._mean[host], self._var[host] = t, 0.0
            return False
        mean, var = self._mean[host], self._var[host]
        std = math.sqrt(var)
        is_straggler = (n >= self.warmup and std > 0
                        and t > mean + self.threshold_sigma * std)
        if is_straggler:
            ev = StragglerEvent(step, host, t, mean, std)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
            # don't poison the EWMA with the outlier
            return True
        d = t - mean
        self._mean[host] = mean + self.alpha * d
        self._var[host] = (1 - self.alpha) * (var + self.alpha * d * d)
        return False

    def skew_map(self, host_times: Dict[int, float]) -> Dict[int, float]:
        """Relative data-shard weights inversely proportional to speed."""
        inv = {h: 1.0 / max(t, 1e-9) for h, t in host_times.items()}
        z = sum(inv.values())
        return {h: v / z for h, v in inv.items()}
