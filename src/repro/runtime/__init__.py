from repro.runtime.trainer import Trainer  # noqa: F401
from repro.runtime.straggler import StragglerDetector  # noqa: F401
from repro.runtime.server import Server  # noqa: F401
