"""Trainer — eager-style dispatch loop with the Chameleon runtime in-line.

Faithful to the paper's setting: each iteration dispatches *separate* jitted
programs (grad step; optimizer step only when gradients are finite; optional
on-the-fly validation), so the per-iteration operator sequence genuinely
varies — loss-scale skips shorten it, eval extends it — and the Chameleon
runtime tracks it exactly as §4 describes.

Fault tolerance: async sharded checkpoints on a cadence, emergency
checkpoint on exception, ``resume()`` from the latest step (optionally onto
a different mesh — elastic restart), straggler detection on step times.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults, obs
from repro.checkpointing.manager import CheckpointManager
from repro.common.config import (AdaptConfig, ChameleonConfig, ModelConfig,
                                 TrainConfig)
from repro.core.runtime import ChameleonRuntime
from repro.data.synthetic import SyntheticTokens
from repro.distributed import sharding as shd
from repro.distributed import steps as S
from repro.models.registry import get_api
from repro.optim.adamw import adamw_init
from repro.optim.loss_scale import (LossScaleState, init_loss_scale,
                                    update_loss_scale)
from repro.runtime.straggler import StragglerDetector


@dataclass
class TrainReport:
    losses: List[float] = field(default_factory=list)
    times: List[float] = field(default_factory=list)
    # full critical-path latency per step: ``times`` plus the
    # ``end_iteration`` bookkeeping/adaptation that runs before the next
    # dispatch — what a drift stall actually costs wall-clock
    wall_times: List[float] = field(default_factory=list)
    skipped_steps: List[int] = field(default_factory=list)
    eval_losses: Dict[int, float] = field(default_factory=dict)
    stages: List[str] = field(default_factory=list)
    checkpoints: List[str] = field(default_factory=list)
    failures: List[str] = field(default_factory=list)
    # repro.policystore: per-tier hit counters + adaptation latencies
    # (None when the runtime has no store attached)
    policystore: Optional[dict] = None
    # repro.adapt: service counters (jobs/published/discarded/failed/
    # installed/speculative) — populated by train() for every mode
    adapt: Optional[dict] = None

    @property
    def genpolicy_steps(self) -> int:
        return sum(1 for s in self.stages if s == "GenPolicy")


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 cham: Optional[ChameleonConfig] = None,
                 mesh=None, data: Optional[SyntheticTokens] = None,
                 eval_data: Optional[SyntheticTokens] = None,
                 metrics_out: Optional[str] = None,
                 metrics_every: int = 25,
                 adapt_mode: Optional[str] = None):
        self.cfg, self.tcfg = cfg, tcfg
        self.cham = cham or ChameleonConfig(enabled=False)
        if adapt_mode is not None and adapt_mode != self.cham.adapt.mode:
            # placement override (--adapt-mode): inline keeps the paper's
            # measured GenPolicy iterations; async/speculative move the
            # variant search onto the repro.adapt background worker
            self.cham = dataclasses.replace(
                self.cham,
                adapt=dataclasses.replace(self.cham.adapt, mode=adapt_mode))
        self.mesh = mesh
        self.api = get_api(cfg)
        self.data = data or SyntheticTokens(cfg.vocab_size, 128, 8,
                                            seed=tcfg.seed)
        self.eval_data = eval_data or SyntheticTokens(
            cfg.vocab_size, self.data.seq_len, self.data.global_batch,
            seed=tcfg.seed + 1)
        self.params, _ = self.api.init(cfg, jax.random.PRNGKey(tcfg.seed))
        self.opt_state = adamw_init(self.params)
        self.loss_scale = init_loss_scale(tcfg.loss_scale)
        self.step = 0
        self.straggler = StragglerDetector(on_straggler=self._on_straggler)
        self.report = TrainReport()

        def step_builder(policy):
            return jax.jit(S.make_grad_step(cfg, tcfg, policy))

        self.rt = ChameleonRuntime(self.cham, step_builder)
        # checkpoint drains share the host link with policy swaps: route
        # them through the engine's lowest-priority checkpoint stream so
        # swap traffic preempts the drain instead of queueing behind it
        # resilience posture: a lost async checkpoint write degrades (one
        # fewer restore point, audited) instead of killing the train loop
        self.ckpt = CheckpointManager(
            tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints,
            engine=self.rt.hostmem.engine if self.rt.hostmem else None,
            on_error="degrade" if self.cham.resilience.enabled else "raise")
        self._apply = jax.jit(S.make_apply_step(cfg, tcfg))
        self._eval = jax.jit(S.make_eval_step(cfg))
        self._prepared = False
        # repro.obs: scattered stats() dicts register as lazy providers so
        # one registry snapshot carries the whole picture; with metrics_out
        # set, a JSONL snapshot is appended every metrics_every steps
        self.metrics_out = metrics_out
        self.metrics_every = max(1, int(metrics_every))
        reg = obs.metrics()
        if self.rt.hostmem is not None:
            reg.register_provider("hostmem", self.rt.hostmem.stats)
        reg.register_provider("runtime", self._runtime_provider)
        # via a lambda: set_ledger may swap the default between snapshots
        reg.register_provider("memory", lambda: obs.ledger().stats())

    def _on_straggler(self, ev) -> None:
        """Mitigation hook: structured evidence for the orchestrator."""
        obs.audit().event("straggler.flagged", step=ev.step, host=ev.host,
                          wall=round(ev.t, 6), mean=round(ev.mean, 6),
                          std=round(ev.std, 6))
        obs.metrics().counter("straggler_flagged")

    def _runtime_provider(self) -> dict:
        return {
            "step": self.step,
            "stage": self.rt.machine.stage.value,
            "profiling_overhead_s": self.rt.profiling_overhead_s,
            "adaptation_overhead_s": self.rt.adaptation_overhead_s,
            "adaptations": len(self.rt.adaptations),
            "adapt": self.rt.service.stats(),
        }

    # ------------------------------------------------------------- utils
    def _device_batch(self, batch: Dict[str, np.ndarray]):
        out = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.cfg.family == "vlm":
            B = out["tokens"].shape[0]
            out["memory"] = jnp.zeros((B, self.cfg.image_tokens,
                                       self.cfg.d_model),
                                      jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "encdec":
            B = out["tokens"].shape[0]
            out["memory"] = jnp.zeros((B, self.cfg.encoder_seq,
                                       self.cfg.d_model),
                                      jnp.dtype(self.cfg.dtype))
        return out

    # ------------------------------------------------------------ resume
    def resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        restored, extra = self.ckpt.restore(
            latest, {"params": self.params, "opt": self.opt_state})
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = int(extra["step"])
        self.loss_scale = LossScaleState(
            jnp.float32(extra["loss_scale"]), jnp.int32(extra["growth"]))
        self.data.restore(extra["data"])
        return True

    def _checkpoint(self, block: bool = False):
        path = self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"step": self.step,
                   "loss_scale": float(self.loss_scale.scale),
                   "growth": int(self.loss_scale.growth_count),
                   "data": self.data.state()},
            block=block)
        self.report.checkpoints.append(path)

    # -------------------------------------------------------------- train
    def train(self, steps: Optional[int] = None,
              fault_hook: Optional[Callable[[int], None]] = None
              ) -> TrainReport:
        steps = steps if steps is not None else self.tcfg.steps
        batch = self._device_batch(self.data.get())
        if not self._prepared:
            self.rt.prepare((self.params, batch, self.loss_scale.scale))
            self._prepared = True
        end = self.step + steps
        while self.step < end:
            try:
                self._one_step(batch, fault_hook)
                batch = self._device_batch(self.data.get())
            except (KeyboardInterrupt, Exception) as e:  # noqa: BLE001
                self.report.failures.append(f"step {self.step}: {e!r}")
                self.ckpt.wait()
                self._checkpoint(block=True)   # emergency checkpoint
                raise
        self.ckpt.wait()
        self.report.policystore = self.rt.policystore_stats()
        self.report.adapt = self.rt.service.stats()
        return self.report

    def _one_step(self, batch, fault_hook=None):
        faults.tick(self.step)   # armed fault plans key off the iteration
        t0 = time.perf_counter()
        fn = self.rt.step_fn()
        with obs.tracer().span(obs.LANE_COMPUTE, "train_step",
                               arg=self.step):
            loss, grads, finite = fn(self.params, batch,
                                     self.loss_scale.scale)
            jax.block_until_ready(loss)
        self.rt.record_dispatch("train", fn,
                                (self.params, batch, self.loss_scale.scale))
        finite_h = bool(finite)
        if finite_h:
            with obs.tracer().span(obs.LANE_COMPUTE, "apply_step",
                                   arg=self.step):
                self.params, self.opt_state, _m = self._apply(
                    self.params, self.opt_state, grads)
                jax.block_until_ready(self.params)
            self.rt.record_dispatch("apply", self._apply,
                                    (self.params, self.opt_state, grads))
        else:
            self.report.skipped_steps.append(self.step)
        self.loss_scale = update_loss_scale(self.loss_scale, finite_h)

        if (self.tcfg.eval_every
                and self.step > 0
                and self.step % self.tcfg.eval_every == 0):
            ebatch = self._device_batch(self.eval_data.next_batch())
            with obs.tracer().span(obs.LANE_COMPUTE, "eval_step",
                                   arg=self.step):
                el = self._eval(self.params, ebatch)
                jax.block_until_ready(el)
            self.rt.record_dispatch("eval", self._eval, (self.params, ebatch))
            self.report.eval_losses[self.step] = float(el)

        dt = time.perf_counter() - t0
        stage = self.rt.end_iteration(dt)
        # flag on the full critical-path latency (compute + end_iteration
        # bookkeeping): a degraded host link or a drift stall shows up in
        # the wall time even when the jitted step itself is healthy
        wall = time.perf_counter() - t0
        self.straggler.observe(self.step, wall)
        self.report.losses.append(float(loss))
        self.report.times.append(dt)
        self.report.wall_times.append(wall)
        self.report.stages.append(stage.value)
        self.step += 1
        # step is incremented BEFORE any failure can be raised for this
        # iteration: the emergency checkpoint then records post-step state
        # under step N+1 and resume does not replay an applied update.
        if fault_hook is not None:
            fault_hook(self.step - 1)

        if (self.tcfg.checkpoint_every
                and self.step % self.tcfg.checkpoint_every == 0):
            self._checkpoint()

        if self.metrics_out and self.step % self.metrics_every == 0:
            obs.metrics().write_jsonl(self.metrics_out)
