"""Batched serving: slot-based continuous batching over the decode step.

Requests prefill into a free slot of the shared decode state (batch-dim
scatter), then every ``tick()`` advances all active slots by one token.
Completed slots free immediately and the admission queue backfills them —
the standard continuous-batching loop, minimal but real.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models.registry import get_api


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False


class Server:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 512, memory=None):
        assert cfg.family in ("dense", "moe", "ssm"), \
            "server prefill path covers dense/moe/ssm; others serve via decode-only"
        self.cfg, self.params = cfg, params
        self.api = get_api(cfg)
        self.max_batch, self.max_len = max_batch, max_len
        self.memory = memory
        self.state = self.api.init_decode_state(cfg, max_batch, max_len,
                                                memory=memory, params=params)
        self.free_slots = list(range(max_batch))
        self.active: Dict[int, Request] = {}
        self.completed: Dict[int, Request] = {}
        self.queue: collections.deque = collections.deque()
        self._rid = 0
        self._decode = jax.jit(
            lambda p, t, s: self.api.decode_step(cfg, p, t, s))
        from repro.models import transformer as T
        self._prefill = jax.jit(
            lambda p, toks: T.prefill(cfg, p, toks, max_len))
        self.ticks = 0

    # ----------------------------------------------------------- admission
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        self._rid += 1
        self.queue.append(Request(self._rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id))
        self._admit()
        return self._rid

    def _admit(self):
        while self.queue and self.free_slots:
            req = self.queue.popleft()
            slot = self.free_slots.pop()
            req.slot = slot
            logits, pstate = self._prefill(self.params, req.prompt[None, :])
            # scatter single-request prefill state into the shared slots
            self.state = self._write_slot(self.state, pstate, slot,
                                          len(req.prompt))
            first = int(jnp.argmax(logits[0, -1]))
            req.generated.append(first)
            self.active[req.rid] = req

    def _write_slot(self, state, pstate, slot: int, plen: int):
        upd = {}
        for name in state._fields:
            cur = getattr(state, name)
            new = getattr(pstate, name, None)
            if cur is None or new is None:
                upd[name] = cur
                continue
            if name == "pos":
                upd[name] = cur.at[slot].set(plen)
            else:
                # (L, B, ...) — write batch row `slot`
                upd[name] = cur.at[:, slot].set(new[:, 0].astype(cur.dtype))
        return type(state)(**upd)

    # ---------------------------------------------------------------- tick
    def tick(self) -> Dict[int, int]:
        """Advance all active slots one token; returns {rid: token}."""
        if not self.active:
            return {}
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for req in self.active.values():
            tokens[req.slot, 0] = req.generated[-1]
        logits, self.state = self._decode(self.params, jnp.asarray(tokens),
                                          self.state)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        out = {}
        finished = []
        for req in self.active.values():
            tok = int(nxt[req.slot])
            req.generated.append(tok)
            out[req.rid] = tok
            if (len(req.generated) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)):
                req.done = True
                finished.append(req.rid)
        for rid in finished:
            req = self.active.pop(rid)
            self.completed[rid] = req
            self.free_slots.append(req.slot)
        self._admit()
        self.ticks += 1
        return out

    def run_until_done(self, max_ticks: int = 1000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            if not self.active and not self.queue:
                break
            self.tick()
        return {rid: req.generated for rid, req in self.completed.items()}
