"""Batched serving: slot-based continuous batching over the decode step.

Requests prefill into a free slot of the shared decode state (batch-dim
scatter), then every ``tick()`` advances all active slots by one token.
Completed slots free immediately and the admission queue backfills them —
the standard continuous-batching loop, minimal but real.

With a host-memory tier attached (``hostmem=HostMemTier()``), admission
can exceed the HBM-resident slot count: ``max_active`` requests run
concurrently over ``max_batch`` physical slots by parking preempted
slots' decode state in the pinned host pool (``repro.hostmem.kvspill``)
and rotating them back in round-robin.  Spill → restore is bit-exact, so
a request decodes the same tokens whether or not it was ever parked.
"""
from __future__ import annotations

import collections
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.common.config import ModelConfig
from repro.models.registry import get_api


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    resident_since: int = 0        # tick at which it last entered a slot
    n_spills: int = 0
    # tick-level latency bookkeeping (benchmarks/serving_bench.py)
    submit_tick: int = 0           # tick at which the request was submitted
    first_token_tick: int = -1     # tick at which prefill produced token 0
    done_tick: int = -1            # tick at which the request completed


class Server:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 512, memory=None,
                 max_active: Optional[int] = None, hostmem=None,
                 rotate_every: int = 1, policystore=None,
                 adapt_mode: str = "inline"):
        assert cfg.family in ("dense", "moe", "ssm"), \
            "server prefill path covers dense/moe/ssm; others serve via decode-only"
        self.cfg, self.params = cfg, params
        self.api = get_api(cfg)
        self.max_batch, self.max_len = max_batch, max_len
        self.max_active = max_active if max_active is not None else max_batch
        if self.max_active > max_batch and hostmem is None:
            from repro.hostmem import HostMemTier
            hostmem = HostMemTier()      # over-subscription needs the tier
        self.hostmem = hostmem
        self.memory = memory
        self.state = self.api.init_decode_state(cfg, max_batch, max_len,
                                                memory=memory, params=params)
        self.free_slots = list(range(max_batch))
        self.active: Dict[int, Request] = {}       # resident in an HBM slot
        self.spilled: Dict[int, Request] = {}      # parked in the host pool
        self._spill_images: Dict[int, object] = {} # rid -> SpilledSlot
        self.completed: Dict[int, Request] = {}
        self.queue: collections.deque = collections.deque()
        self._rid = 0
        self._decode = jax.jit(
            lambda p, t, s: self.api.decode_step(cfg, p, t, s))
        from repro.models import transformer as T
        self._prefill = jax.jit(
            lambda p, toks: T.prefill(cfg, p, toks, max_len))
        self.ticks = 0
        self.n_preemptions = 0
        # rotation quantum: swap a parked request in every k-th tick.  1 =
        # strictest fairness; larger k trades waiter latency for k-fold
        # fewer spill round trips per generated token.
        self.rotate_every = max(rotate_every, 1)
        # shared adaptation cache (repro.policystore): the serving process
        # reports cache warmth alongside its own stats.  With adapt_mode
        # async/speculative (repro.adapt), a background one-shot thread
        # periodically re-scans the store directory so records a
        # concurrently *training* process writes become visible without a
        # restart — and without ever stalling a decode tick on disk I/O.
        self.policystore = policystore
        self.adapt_mode = adapt_mode
        self._refresh_thread: Optional[threading.Thread] = None
        self._refresh_every_ticks = 256
        self.n_store_refreshes = 0
        self.n_store_refreshed = 0
        # tick-level batching log: (resident slots at decode, wall seconds,
        # tokens emitted) per tick — the serving bench derives throughput,
        # latency percentiles, and slot occupancy from this.  Bounded: a
        # long-running server keeps a sliding window, not full history
        self.tick_log: collections.deque = collections.deque(maxlen=4096)
        obs.metrics().register_provider("server", self.latency_stats)

    # ----------------------------------------------------------- admission
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        self._rid += 1
        self.queue.append(Request(self._rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id,
                                  submit_tick=self.ticks))
        self._admit()
        return self._rid

    @property
    def n_active(self) -> int:
        """Concurrently admitted requests (resident + host-parked)."""
        return len(self.active) + len(self.spilled)

    def _admit(self):
        self._restore_waiting()
        while self.queue and self.n_active < self.max_active:
            slot = self._acquire_slot()
            if slot is None:
                break
            req = self.queue.popleft()
            self._place(req, slot)

    def _acquire_slot(self) -> Optional[int]:
        if self.free_slots:
            return self.free_slots.pop()
        if self.hostmem is not None and self.active:
            return self._preempt()
        return None

    def _place(self, req: Request, slot: int) -> None:
        req.slot = slot
        req.resident_since = self.ticks
        with obs.tracer().span(obs.LANE_COMPUTE, "prefill",
                               arg=(req.rid, len(req.prompt))):
            logits, pstate = self._prefill(self.params, req.prompt[None, :])
            jax.block_until_ready(logits)
        # scatter single-request prefill state into the shared slots
        self.state = self._write_slot(self.state, pstate, slot,
                                      len(req.prompt))
        first = int(jnp.argmax(logits[0, -1]))
        req.generated.append(first)
        if req.first_token_tick < 0:
            req.first_token_tick = self.ticks
        self.active[req.rid] = req

    def _write_slot(self, state, pstate, slot: int, plen: int):
        upd = {}
        for name in state._fields:
            cur = getattr(state, name)
            new = getattr(pstate, name, None)
            if cur is None or new is None:
                upd[name] = cur
                continue
            if name == "pos":
                upd[name] = cur.at[slot].set(plen)
            else:
                # (L, B, ...) — write batch row `slot`
                upd[name] = cur.at[:, slot].set(new[:, 0].astype(cur.dtype))
        return type(state)(**upd)

    # ------------------------------------------------------- kv-cache spill
    def _preempt(self) -> int:
        """Park the longest-resident request's slot state in the host pool
        and hand its HBM slot to the caller."""
        victim = min(self.active.values(),
                     key=lambda r: (r.resident_since, r.rid))
        del self.active[victim.rid]
        self._spill_images[victim.rid] = self.hostmem.kvspill.spill(
            self.state, victim.slot, tag=f"req{victim.rid}")
        slot, victim.slot = victim.slot, -1
        victim.n_spills += 1
        self.spilled[victim.rid] = victim
        self.n_preemptions += 1
        return slot

    def _restore_one(self, req: Request, slot: int) -> None:
        sp = self._spill_images.pop(req.rid)
        del self.spilled[req.rid]
        self.state = self.hostmem.kvspill.restore(self.state, sp, slot)
        req.slot = slot
        req.resident_since = self.ticks
        self.active[req.rid] = req

    def _restore_waiting(self) -> None:
        """Oldest parked requests take any free slots before new admission."""
        while self.free_slots and self.spilled:
            req = min(self.spilled.values(), key=lambda r: r.rid)
            self._restore_one(req, self.free_slots.pop())

    def _rotate(self) -> None:
        """Round-robin: one parked request trades places with the
        longest-resident slot every ``rotate_every`` ticks, so nobody
        starves."""
        if not self.spilled or self.hostmem is None or not self.active:
            return
        if self.ticks % self.rotate_every:
            return
        waiter = min(self.spilled.values(), key=lambda r: r.rid)
        slot = self._preempt()
        self._restore_one(waiter, slot)

    # ---------------------------------------------------------------- tick
    def tick(self) -> Dict[int, int]:
        """Advance all resident slots one token; returns {rid: token}."""
        import time
        t0 = time.perf_counter()
        self._admit()
        if not self.active:
            return {}
        n_resident = len(self.active)
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for req in self.active.values():
            tokens[req.slot, 0] = req.generated[-1]
        with obs.tracer().span(obs.LANE_COMPUTE, "decode_tick",
                               arg=(self.ticks, n_resident)):
            logits, self.state = self._decode(
                self.params, jnp.asarray(tokens), self.state)
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        out = {}
        finished = []
        for req in self.active.values():
            tok = int(nxt[req.slot])
            req.generated.append(tok)
            out[req.rid] = tok
            if (len(req.generated) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)):
                req.done = True
                finished.append(req.rid)
        for rid in finished:
            req = self.active.pop(rid)
            req.done_tick = self.ticks
            self.completed[rid] = req
            self.free_slots.append(req.slot)
        self.ticks += 1
        self._admit()
        self._rotate()
        if self.ticks % self._refresh_every_ticks == 0:
            self._refresh_store()
        self.tick_log.append((n_resident, time.perf_counter() - t0, len(out)))
        return out

    def _refresh_store(self) -> None:
        """Kick one background store re-scan (never blocks the tick; a
        still-running previous scan is left to finish)."""
        if self.adapt_mode == "inline" or self.policystore is None:
            return
        if self._refresh_thread is not None and self._refresh_thread.is_alive():
            return

        def _scan():
            self.n_store_refreshed += self.policystore.refresh()
            self.n_store_refreshes += 1

        self._refresh_thread = threading.Thread(
            target=_scan, name="store-refresh", daemon=True)
        self._refresh_thread.start()

    def run_until_done(self, max_ticks: int = 1000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            if not self.active and not self.queue and not self.spilled:
                break
            self.tick()
        return {rid: req.generated for rid, req in self.completed.items()}

    # --------------------------------------------------------------- stats
    @staticmethod
    def _pct(xs: List[float], q: float) -> float:
        if not xs:
            return 0.0
        xs = sorted(xs)
        return xs[min(int(q * len(xs)), len(xs) - 1)]

    def latency_stats(self) -> dict:
        """Tick-level batching stats: per-tick wall time, slot occupancy,
        and per-request queue-wait / completion-span percentiles (in
        ticks) — the numbers ``benchmarks/serving_bench.py`` compares
        between queueing and over-subscription admission.  Tick-derived
        numbers cover the ``tick_log`` window (last 4096 ticks)."""
        done = list(self.completed.values())
        waits = [float(r.first_token_tick - r.submit_tick)
                 for r in done if r.first_token_tick >= 0]
        spans = [float(r.done_tick - r.submit_tick)
                 for r in done if r.done_tick >= 0]
        tick_s = [dt for _, dt, _ in self.tick_log]
        occ = [n / self.max_batch for n, _, _ in self.tick_log]
        toks = sum(k for _, _, k in self.tick_log)
        total_s = sum(tick_s)
        return {
            "n_completed": len(done),
            "ticks": len(self.tick_log),
            "tokens": toks,
            "tokens_per_s": toks / total_s if total_s > 0 else 0.0,
            "tokens_per_tick": toks / max(len(self.tick_log), 1),
            "slot_occupancy": float(np.mean(occ)) if occ else 0.0,
            "tick_ms": {"p50": self._pct(tick_s, 0.5) * 1e3,
                        "p95": self._pct(tick_s, 0.95) * 1e3,
                        "max": (max(tick_s) if tick_s else 0.0) * 1e3},
            "queue_wait_ticks": {"p50": self._pct(waits, 0.5),
                                 "p95": self._pct(waits, 0.95),
                                 "max": max(waits) if waits else 0.0},
            "completion_ticks": {"p50": self._pct(spans, 0.5),
                                 "p95": self._pct(spans, 0.95),
                                 "max": max(spans) if spans else 0.0},
        }

    def stats(self) -> dict:
        hm = self.hostmem.stats() if self.hostmem else None
        # surface the serving-relevant traffic class directly: spill time
        # lost to other link traffic is a tick-latency component
        kv_cls = (hm["engine"]["classes"]["kv_spill"]
                  if hm is not None else None)
        return {
            "ticks": self.ticks,
            "active": len(self.active),
            "spilled": len(self.spilled),
            "queued": len(self.queue),
            "completed": len(self.completed),
            "preemptions": self.n_preemptions,
            "kv_spill_class": kv_cls,
            "hostmem": hm,
            "latency": self.latency_stats(),
            "policystore": (self.policystore.stats()
                            if self.policystore is not None else None),
            "adapt": {"mode": self.adapt_mode,
                      "store_refreshes": self.n_store_refreshes,
                      "store_records_refreshed": self.n_store_refreshed},
        }
