"""Swap/compute overlap efficiency (repro.obs).

The paper's Fig.-7-style claim — swap traffic adds no end-to-end time
*when effectively overlapped* — becomes a measured number here:

    overlap_efficiency = hidden transfer time / total transfer time

where a transfer second is *hidden* iff it lies under the union of
compute spans in the same window.  1.0 means the link never ran while
compute was idle (perfect overlap); 0.0 means every transfer second was
exposed on the critical path.  Windows with no transfer traffic report
``None`` (nothing to hide — not the same as perfect overlap).

The computation is numpy interval arithmetic over the tracer's ring
buffer: O(n log n) in retained spans, run once per iteration boundary on
bounded input, so it honors the always-on budget.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.obs.tracer import LANE_COMPUTE, TRANSFER_LANES, SpanTracer


def interval_union(spans: np.ndarray) -> np.ndarray:
    """Merge an ``(n, 2)`` array of [t0, t1) intervals into a disjoint,
    sorted ``(m, 2)`` union."""
    if spans.size == 0:
        return spans.reshape(0, 2)
    spans = spans[np.argsort(spans[:, 0])]
    starts, ends = spans[:, 0], spans[:, 1]
    # an interval starts a new merged run iff it begins after the running
    # max end of everything before it
    run_end = np.maximum.accumulate(ends)
    new_run = np.ones(len(spans), bool)
    new_run[1:] = starts[1:] > run_end[:-1]
    run_id = np.cumsum(new_run) - 1
    m = int(run_id[-1]) + 1
    ends_out = np.full(m, -np.inf)
    np.maximum.at(ends_out, run_id, ends)
    out = np.empty((m, 2), np.float64)
    out[:, 0] = starts[new_run]
    out[:, 1] = ends_out
    return out


def _overlap_with_union(spans: np.ndarray, union: np.ndarray) -> float:
    """Total seconds of ``spans`` covered by the disjoint ``union``."""
    if spans.size == 0 or union.size == 0:
        return 0.0
    total = 0.0
    u0, u1 = union[:, 0], union[:, 1]
    for t0, t1 in spans:
        if t1 <= t0:
            continue
        lo = np.searchsorted(u1, t0, side="right")
        hi = np.searchsorted(u0, t1, side="left")
        if hi > lo:
            seg0 = np.maximum(u0[lo:hi], t0)
            seg1 = np.minimum(u1[lo:hi], t1)
            total += float(np.clip(seg1 - seg0, 0.0, None).sum())
    return total


def overlap_efficiency(compute: np.ndarray,
                       transfer: np.ndarray) -> Tuple[Optional[float], float, float]:
    """(efficiency, transfer_seconds, hidden_seconds) for explicit span
    arrays.  Efficiency is None when there was no transfer traffic."""
    total = float(np.clip(transfer[:, 1] - transfer[:, 0], 0.0, None).sum()) \
        if transfer.size else 0.0
    if total <= 0.0:
        return None, 0.0, 0.0
    hidden = _overlap_with_union(transfer, interval_union(compute))
    hidden = min(hidden, total)
    return hidden / total, total, hidden


def window_efficiency(tracer: SpanTracer, t0: float, t1: float
                      ) -> Tuple[Optional[float], float, float]:
    """Overlap efficiency over the wall-clock window [t0, t1): transfer
    spans are clipped to the window; compute spans crossing the boundary
    still hide what they cover inside it."""
    compute = tracer.spans(lanes=(LANE_COMPUTE,))
    transfer = tracer.spans(lanes=TRANSFER_LANES)
    if transfer.size:
        m = (transfer[:, 1] > t0) & (transfer[:, 0] < t1)
        transfer = np.clip(transfer[m], t0, t1)
    if compute.size:
        m = (compute[:, 1] > t0) & (compute[:, 0] < t1)
        compute = compute[m]
    return overlap_efficiency(compute, transfer)
